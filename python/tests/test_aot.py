"""AOT pipeline consistency: manifest <-> artifacts <-> model."""

import json
import os

import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_models(manifest):
    for name in model.CONFIGS:
        assert name in manifest["models"], name


def test_param_bins_match_counts(manifest):
    for name, m in manifest["models"].items():
        assert m["param_count"] == model.param_count(model.CONFIGS[name])
        params = np.fromfile(os.path.join(ART, m["params_bin"]), "<f4")
        assert params.shape == (m["param_count"],)
        # Matches a fresh deterministic init.
        fresh = model.init_params(model.CONFIGS[name], seed=manifest["seed"])
        np.testing.assert_array_equal(params, fresh)


def test_hlo_artifacts_exist_and_parse(manifest):
    for name, m in manifest["models"].items():
        for b in m["buckets"]:
            for key in ("train", "forward"):
                path = os.path.join(ART, b[key])
                assert os.path.exists(path), path
                text = open(path).read()
                assert text.startswith("HloModule"), f"{path} not HLO text"
                # Parameter arity sanity: the entry computation must
                # declare the expected number of parameters.
                n_params = 4 if key == "train" else 3
                assert text.count("parameter(") >= n_params, path


def test_train_artifact_declares_output_order(manifest):
    for m in manifest["models"].values():
        assert m["train_outputs"] == [
            "loss_sums", "grads", "emb_grad", "logits", "n_valid"
        ]


def test_bucket_shapes_sorted_and_usable(manifest):
    for name, m in manifest["models"].items():
        buckets = [(b["batch"], b["len"]) for b in m["buckets"]]
        assert buckets == sorted(buckets), "buckets must ascend"
        for _, l in buckets:
            # Kernel block sizes must divide the padded length.
            assert l % 8 == 0


def test_lowering_is_deterministic(tmp_path):
    # Same seed -> byte-identical params and manifest content.
    m1 = aot.build(str(tmp_path / "a"), models=["tiny"], seed=3)
    m2 = aot.build(str(tmp_path / "b"), models=["tiny"], seed=3)
    p1 = np.fromfile(tmp_path / "a" / "tiny_params.bin", "<f4")
    p2 = np.fromfile(tmp_path / "b" / "tiny_params.bin", "<f4")
    np.testing.assert_array_equal(p1, p2)
    assert m1["models"]["tiny"]["param_count"] == m2["models"]["tiny"]["param_count"]
