"""L1 correctness: the Pallas HSTU kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/lengths; every case asserts allclose
between the fused kernel and ``ref.hstu_attention_ref`` — the core
correctness signal for the operator-fusion contribution (§5.2).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hstu import (
    hstu_attention,
    hstu_attention_pallas,
)

jax.config.update("jax_platform_name", "cpu")


def make_inputs(B, H, L, dh, seed, dtype=jnp.float32, lengths=None):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(0, 1, (B, H, L, dh)), dtype)
    u, q, k, v = mk(), mk(), mk(), mk()
    if lengths is None:
        lengths = jnp.asarray(rng.integers(0, L + 1, (B,)), jnp.int32)
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
    return u, q, k, v, lengths


# ---------------------------------------------------------------------------
# Directed cases
# ---------------------------------------------------------------------------


def test_matches_reference_basic():
    u, q, k, v, lengths = make_inputs(2, 2, 64, 16, 0)
    out = hstu_attention_pallas(u, q, k, v, lengths)
    want = ref.hstu_attention_ref(u, q, k, v, lengths)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_full_and_zero_lengths():
    u, q, k, v, _ = make_inputs(3, 1, 32, 8, 1)
    for lengths in ([32, 32, 32], [0, 0, 0], [32, 0, 7]):
        ln = jnp.asarray(lengths, jnp.int32)
        out = hstu_attention_pallas(u, q, k, v, ln)
        want = ref.hstu_attention_ref(u, q, k, v, ln)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
        # Zero-length sequences produce exactly zero attention output
        # (U gate multiplies a zero accumulator).
        for b, l in enumerate(lengths):
            if l == 0:
                assert float(jnp.abs(out[b]).max()) == 0.0


def test_causality():
    # Changing K/V beyond position t must not change outputs at / before t.
    B, H, L, dh = 1, 2, 64, 16
    u, q, k, v, _ = make_inputs(B, H, L, dh, 2)
    ln = jnp.asarray([L], jnp.int32)
    base = hstu_attention_pallas(u, q, k, v, ln)
    k2 = k.at[:, :, 40:, :].set(7.7)
    v2 = v.at[:, :, 40:, :].set(-3.3)
    pert = hstu_attention_pallas(u, q, k2, v2, ln)
    np.testing.assert_allclose(base[:, :, :40], pert[:, :, :40],
                               rtol=1e-6, atol=1e-6)
    # ...but later positions DO change (sanity that the test can fail).
    assert float(jnp.abs(base[:, :, 40:] - pert[:, :, 40:]).max()) > 1e-3


def test_invalid_tokens_do_not_leak():
    # K/V rows beyond the true length must not affect any output.
    u, q, k, v, _ = make_inputs(1, 1, 32, 8, 3)
    ln = jnp.asarray([20], jnp.int32)
    base = hstu_attention_pallas(u, q, k, v, ln)
    k2 = k.at[:, :, 20:, :].set(1e6)
    v2 = v.at[:, :, 20:, :].set(1e6)
    pert = hstu_attention_pallas(u, q, k2, v2, ln)
    np.testing.assert_allclose(base, pert, rtol=1e-6, atol=1e-6)


def test_block_size_invariance():
    # The tiling schedule must not change the math.
    u, q, k, v, lengths = make_inputs(2, 2, 64, 16, 4)
    outs = [
        hstu_attention_pallas(u, q, k, v, lengths, blk_q=bq, blk_k=bk)
        for bq, bk in [(8, 8), (16, 32), (32, 16), (64, 64)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


def test_gradients_match_reference():
    u, q, k, v, lengths = make_inputs(2, 2, 32, 8, 5)

    def f_kernel(u, q, k, v):
        return (hstu_attention(u, q, k, v, lengths) ** 2).sum()

    def f_ref(u, q, k, v):
        return (ref.hstu_attention_ref(u, q, k, v, lengths) ** 2).sum()

    g_k = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(u, q, k, v)
    g_r = jax.grad(f_ref, argnums=(0, 1, 2, 3))(u, q, k, v)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_jit_and_vmap_compose():
    u, q, k, v, lengths = make_inputs(2, 1, 32, 8, 6)
    jitted = jax.jit(lambda *a: hstu_attention(*a))
    np.testing.assert_allclose(
        jitted(u, q, k, v, lengths),
        ref.hstu_attention_ref(u, q, k, v, lengths),
        rtol=1e-5, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 4),
    H=st.sampled_from([1, 2, 4]),
    lpow=st.sampled_from([16, 32, 64, 96]),
    dh=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shapes(B, H, lpow, dh, seed):
    u, q, k, v, lengths = make_inputs(B, H, lpow, dh, seed)
    out = hstu_attention_pallas(u, q, k, v, lengths)
    want = ref.hstu_attention_ref(u, q, k, v, lengths)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_hypothesis_dtypes(seed, dtype):
    dt = jnp.dtype(dtype)
    u, q, k, v, lengths = make_inputs(2, 2, 32, 8, seed, dtype=dt)
    out = hstu_attention_pallas(u, q, k, v, lengths)
    want = ref.hstu_attention_ref(u, q, k, v, lengths)
    assert out.dtype == dt
    tol = 1e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_hypothesis_magnitudes(seed, scale):
    u, q, k, v, lengths = make_inputs(2, 1, 32, 8, seed)
    u, q, k, v = u * scale, q * scale, k * scale, v * scale
    out = hstu_attention_pallas(u, q, k, v, lengths)
    want = ref.hstu_attention_ref(u, q, k, v, lengths)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4 * scale ** 3)
