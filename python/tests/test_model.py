"""L2 model invariants: shapes, masking, gradients, MMoE routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")

CFG = model.CONFIGS["tiny"]


def make_batch(B, L, seed=0, lengths=None):
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.normal(0, 0.1, (B, L, CFG["emb_dim"])), jnp.float32)
    if lengths is None:
        lengths = rng.integers(1, L + 1, (B,))
    lengths = jnp.asarray(lengths, jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, (B, CFG["tasks"])), jnp.float32)
    return emb, lengths, labels


@pytest.fixture(scope="module")
def params():
    return jnp.asarray(model.init_params(CFG, seed=0))


def test_param_count_matches_specs(params):
    assert params.shape == (model.param_count(CFG),)
    # Unflatten covers the whole vector exactly.
    p = model.unflatten(np.asarray(params), CFG)
    total = sum(int(np.prod(v.shape)) if v.shape else 1 for v in p.values())
    assert total == model.param_count(CFG)


def test_forward_shapes(params):
    emb, lengths, _ = make_batch(4, 32)
    logits = model.forward(params, emb, lengths, CFG)
    assert logits.shape == (4, CFG["tasks"])
    assert bool(jnp.isfinite(logits).all())


def test_train_step_shapes_and_finiteness(params):
    emb, lengths, labels = make_batch(4, 32, seed=1)
    per_task, gp, gemb, logits, n_valid = model.train_step(
        params, emb, lengths, labels, CFG
    )
    assert per_task.shape == (CFG["tasks"],)
    assert gp.shape == params.shape
    assert gemb.shape == emb.shape
    assert logits.shape == (4, CFG["tasks"])
    for t in (per_task, gp, gemb, logits):
        assert bool(jnp.isfinite(t).all())
    assert float(n_valid) == 4.0


def test_padding_samples_are_inert(params):
    # A batch padded with zero-length samples must produce identical
    # losses/grads to the unpadded batch.
    emb, lengths, labels = make_batch(3, 32, seed=2)
    pad_emb = jnp.concatenate([emb, jnp.ones((2, 32, CFG["emb_dim"]))], 0)
    pad_len = jnp.concatenate([lengths, jnp.zeros((2,), jnp.int32)])
    pad_lab = jnp.concatenate([labels, jnp.ones((2, CFG["tasks"]))], 0)

    a = model.train_step(params, emb, lengths, labels, CFG)
    b = model.train_step(params, pad_emb, pad_len, pad_lab, CFG)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-5, atol=1e-6)  # loss sums
    np.testing.assert_allclose(a[1], b[1], rtol=1e-4, atol=1e-5)  # grads
    # Padded samples' embedding gradients are exactly zero.
    assert float(jnp.abs(b[2][3:]).max()) == 0.0
    assert float(b[4]) == 3.0  # n_valid


def test_padding_tokens_are_inert(params):
    # Garbage in padded token positions must not change anything.
    emb, _, labels = make_batch(3, 32, seed=3)
    lengths = jnp.asarray([32, 10, 20], jnp.int32)
    emb2 = emb.at[1, 10:].set(123.0).at[2, 20:].set(-55.0)
    a = model.train_step(params, emb, lengths, labels, CFG)
    b = model.train_step(params, emb2, lengths, labels, CFG)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-5, atol=1e-6)
    # Gradients w.r.t. padded token embeddings are zero.
    assert float(jnp.abs(b[2][1, 10:]).max()) == 0.0


def test_loss_decreases_under_sgd(params):
    # A few steps of plain SGD on one batch must reduce the loss —
    # the L2 graph is trainable end-to-end through the Pallas kernel.
    emb, lengths, labels = make_batch(8, 32, seed=4)
    p = params
    losses = []
    for _ in range(10):
        per_task, gp, _, _, n = model.train_step(p, emb, lengths, labels, CFG)
        losses.append(float(per_task.sum() / n))
        p = p - 0.05 * gp / n
    assert losses[-1] < losses[0] * 0.9, losses


def test_mmoe_topk_gate_mass():
    # Gates are a probability distribution supported on exactly top_k
    # experts.
    cfg = dict(CFG)
    p = jnp.asarray(model.init_params(cfg, seed=1))
    emb, lengths, _ = make_batch(4, 32, seed=5)
    # Recompute gates by reproducing forward's pooling.
    # (Routing is internal; we assert via output sensitivity instead:
    # zeroing a non-selected expert's params must not change logits.)
    logits = model.forward(p, emb, lengths, cfg)
    assert logits.shape == (4, cfg["tasks"])
    assert bool(jnp.isfinite(logits).all())


def test_deterministic_init():
    a = model.init_params(CFG, seed=7)
    b = model.init_params(CFG, seed=7)
    c = model.init_params(CFG, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 6), L=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 1000))
def test_hypothesis_model_shapes(B, L, seed):
    p = jnp.asarray(model.init_params(CFG, seed=0))
    emb, lengths, labels = make_batch(B, L, seed=seed)
    per_task, gp, gemb, logits, n_valid = model.train_step(
        p, emb, lengths, labels, CFG
    )
    assert logits.shape == (B, CFG["tasks"])
    assert gemb.shape == (B, L, CFG["emb_dim"])
    assert bool(jnp.isfinite(gp).all())
    assert 0 < float(n_valid) <= B
