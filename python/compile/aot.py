"""AOT compile path: lower the L2 model (with the L1 Pallas kernel
inside) to HLO **text** artifacts for the Rust PJRT runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``--out`` (default ``../artifacts``):
  <model>_train_b<B>x<L>.hlo.txt   train_step for each (B, L) bucket
  <model>_fwd_b<B>x<L>.hlo.txt     inference forward for each bucket
  <model>_params.bin               flat f32 LE initial parameters
  manifest.json                    everything the Rust runtime needs

Run once via ``make artifacts`` (no-op when inputs are unchanged);
Python never runs on the training hot path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(name, cfg, batch, length):
    """Lower train + forward for one (batch, length) bucket."""
    p = int(model.param_count(cfg))
    d = cfg["emb_dim"]
    t = cfg["tasks"]
    params = jax.ShapeDtypeStruct((p,), jnp.float32)
    emb = jax.ShapeDtypeStruct((batch, length, d), jnp.float32)
    lengths = jax.ShapeDtypeStruct((batch,), jnp.int32)
    labels = jax.ShapeDtypeStruct((batch, t), jnp.float32)

    train_fn = model.make_train_fn(name)
    fwd_fn = model.make_forward_fn(name)
    train_hlo = to_hlo_text(
        jax.jit(train_fn).lower(params, emb, lengths, labels)
    )
    fwd_hlo = to_hlo_text(jax.jit(fwd_fn).lower(params, emb, lengths))
    return train_hlo, fwd_hlo


def build(out_dir, models=None, seed=0):
    os.makedirs(out_dir, exist_ok=True)
    models = models or list(model.CONFIGS.keys())
    manifest = {"version": 1, "seed": seed, "models": {}}
    for name in models:
        cfg = model.CONFIGS[name]
        params = model.init_params(cfg, seed=seed)
        params_bin = f"{name}_params.bin"
        params.astype("<f4").tofile(os.path.join(out_dir, params_bin))

        buckets = []
        for batch, length in model.BUCKETS[name]:
            train_hlo, fwd_hlo = lower_bucket(name, cfg, batch, length)
            train_name = f"{name}_train_b{batch}x{length}.hlo.txt"
            fwd_name = f"{name}_fwd_b{batch}x{length}.hlo.txt"
            with open(os.path.join(out_dir, train_name), "w") as f:
                f.write(train_hlo)
            with open(os.path.join(out_dir, fwd_name), "w") as f:
                f.write(fwd_hlo)
            buckets.append(
                {
                    "batch": batch,
                    "len": length,
                    "train": train_name,
                    "forward": fwd_name,
                }
            )
            print(f"lowered {name} bucket ({batch}, {length})")

        manifest["models"][name] = {
            "emb_dim": cfg["emb_dim"],
            "heads": cfg["heads"],
            "blocks": cfg["blocks"],
            "experts": cfg["experts"],
            "top_k": cfg["top_k"],
            "expert_hidden": cfg["expert_hidden"],
            "tasks": cfg["tasks"],
            "param_count": int(model.param_count(cfg)),
            "params_bin": params_bin,
            "buckets": buckets,
            # Output arity/order of the train artifact, for the runtime.
            "train_outputs": ["loss_sums", "grads", "emb_grad", "logits",
                              "n_valid"],
        }
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {path}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="", help="comma-separated subset")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    models = [m for m in args.models.split(",") if m] or None
    build(args.out, models=models, seed=args.seed)


if __name__ == "__main__":
    main()
