"""L1: fused HSTU attention as a Pallas kernel (paper §5.2 Operator
Fusion).

The paper fuses the HSTU attention path the way FlashAttention does on
CUDA: U/Q/K/V are partitioned into tiles staged through SRAM, with
causal-mask-driven skipping of unnecessary tiles. The TPU rethink (see
DESIGN.md §Hardware-Adaptation):

- BlockSpec tiles express the HBM->VMEM schedule: the grid iterates
  (batch*head, q-block); K/V are streamed block-by-block inside the
  kernel while the (blk_q, dh) accumulator stays resident in VMEM.
- HSTU uses SiLU(QK^T)*mask (no softmax), so there is **no online
  rescaling pass**: the accumulator is a plain sum over K blocks. This
  is strictly simpler than FlashAttention and maps cleanly onto the MXU
  (two matmuls per tile: QK^T and PV).
- Causal skipping: K blocks strictly above the diagonal contribute
  nothing; the kernel skips them via the loop bound (only kb with
  kb*blk_k <= q_hi are visited), the paper's "casual mask vectors to
  reduce unnecessary calculations".

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for both the pytest
oracle checks and the AOT artifacts consumed by the Rust runtime. Real
TPU performance is *estimated* from the VMEM footprint / MXU shapes in
DESIGN.md §Perf.

Backward: ``hstu_attention`` is a ``jax.custom_vjp`` whose forward runs
this kernel and whose backward differentiates the pure-jnp reference
(FlashAttention-style recomputation — the fused forward never
materializes the (L, L) score matrix).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes (tuned in the §Perf pass — see EXPERIMENTS.md).
# VMEM budget check at the default model shapes (dh = 64): a (256, 64)
# f32 tile is 64 KiB; the kernel holds q/u/acc tiles plus streamed k/v
# slices ≈ 6 tiles ≈ 0.4 MiB — far under the ~16 MiB/core VMEM budget,
# so full-length Q blocks are legal on TPU too, and they are ~6x faster
# under CPU interpret mode (fewer grid steps / loop trips). For paper-
# scale L = 3000, dh = 256 the same math gives ≈ 18 MiB, at which point
# blk_q must drop to 1024 — handled by the min() below.
DEFAULT_BLK_Q = 256
DEFAULT_BLK_K = 256


def _hstu_kernel(len_ref, u_ref, q_ref, k_ref, v_ref, o_ref, *, blk_q, blk_k, L):
    """One grid step: q-block `qi` of batch-head `bh`.

    Refs (leading (1,1) block dims squeezed by indexing):
      len_ref: (1,)           true length of this sequence
      u_ref, q_ref: (1, 1, blk_q, dh)
      k_ref, v_ref: (1, 1, L, dh)   (streamed in blk_k slices)
      o_ref: (1, 1, blk_q, dh)
    """
    qi = pl.program_id(1)
    q = q_ref[0, 0]  # (blk_q, dh)
    u = u_ref[0, 0]
    ln = len_ref[0]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))

    q_pos = qi * blk_q + jax.lax.iota(jnp.int32, blk_q)  # (blk_q,)
    denom = jnp.maximum(ln, 1).astype(q.dtype)

    # Causal tile skipping: K blocks beyond this Q block's last row can
    # never satisfy k <= q. (Also bounded by the valid length.)
    q_hi = (qi + 1) * blk_q  # exclusive upper bound of q positions + 1
    kb_max = jnp.minimum(
        pl.cdiv(q_hi, blk_k), pl.cdiv(jnp.maximum(ln, 0), blk_k)
    ).astype(jnp.int32)
    kb_max = jnp.maximum(kb_max, 0)

    def body(kb, acc):
        k_tile = jax.lax.dynamic_slice(
            k_ref[0, 0], (kb * blk_k, 0), (blk_k, dh)
        )
        v_tile = jax.lax.dynamic_slice(
            v_ref[0, 0], (kb * blk_k, 0), (blk_k, dh)
        )
        # MXU matmul #1: scores tile (blk_q, blk_k).
        s = jnp.dot(q, k_tile.T) * scale
        k_pos = kb * blk_k + jax.lax.iota(jnp.int32, blk_k)
        mask = jnp.logical_and(
            k_pos[None, :] <= q_pos[:, None],  # causal
            k_pos[None, :] < ln,  # valid
        )
        p = jax.nn.silu(s) * mask.astype(s.dtype) / denom
        # MXU matmul #2: PV tile accumulation.
        return acc + jnp.dot(p, v_tile)

    acc = jnp.zeros((blk_q, dh), dtype=q.dtype)
    acc = jax.lax.fori_loop(0, kb_max, body, acc)
    # Fused elementwise U gate (Eq. 3 input).
    o_ref[0, 0] = acc * u


def hstu_attention_pallas(u, q, k, v, lengths, *, blk_q=None, blk_k=None):
    """Fused HSTU attention via the Pallas kernel (forward only).

    Shapes: u/q/k/v (B, H, L, dh); lengths (B,) int32. L must be a
    multiple of the block sizes (the model pads to bucket sizes that
    are).
    """
    B, H, L, dh = q.shape
    blk_q = blk_q or min(DEFAULT_BLK_Q, L)
    blk_k = blk_k or min(DEFAULT_BLK_K, L)
    assert L % blk_q == 0 and L % blk_k == 0, (L, blk_q, blk_k)
    grid = (B * H, L // blk_q)

    qkv_spec = pl.BlockSpec(
        (1, 1, blk_q, dh), lambda bh, qi: (bh // H, bh % H, qi, 0)
    )
    full_spec = pl.BlockSpec(
        (1, 1, L, dh), lambda bh, qi: (bh // H, bh % H, 0, 0)
    )
    len_spec = pl.BlockSpec((1,), lambda bh, qi: (bh // H,))

    kernel = functools.partial(_hstu_kernel, blk_q=blk_q, blk_k=blk_k, L=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[len_spec, qkv_spec, qkv_spec, full_spec, full_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, L, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(lengths, u, q, k, v)


@jax.custom_vjp
def hstu_attention(u, q, k, v, lengths):
    """Differentiable fused HSTU attention.

    Forward = the Pallas kernel; backward = VJP of the jnp reference
    (recomputation, FlashAttention-style).
    """
    return hstu_attention_pallas(u, q, k, v, lengths)


def _fwd(u, q, k, v, lengths):
    out = hstu_attention_pallas(u, q, k, v, lengths)
    return out, (u, q, k, v, lengths)


def _bwd(saved, g):
    u, q, k, v, lengths = saved
    _, vjp = jax.vjp(lambda u_, q_, k_, v_: ref.hstu_attention_ref(u_, q_, k_, v_, lengths), u, q, k, v)
    du, dq, dk, dv = vjp(g)
    return du, dq, dk, dv, None


hstu_attention.defvjp(_fwd, _bwd)
