"""Pure-jnp reference oracle for the fused HSTU attention (L1 kernel).

This is the correctness ground truth the Pallas kernel is checked against
(pytest + hypothesis in ``python/tests/test_kernel.py``), and the
implementation used for the backward pass of the ``custom_vjp`` wrapper
(FlashAttention-style recomputation: the fused forward kernel does not
materialize the score matrix, so backward recomputes from the reference
formulation).

HSTU attention (paper Eq. 2 plus the elementwise U gate of Eq. 3's input):

    O = (SiLU(Q Kᵀ / sqrt(dh)) ⊙ M) V / len ⊙ U

where M is the causal-AND-valid mask (k ≤ q, k < len_b) and ``len`` is the
per-sequence true length (normalizing by the real length keeps activation
scale independent of the padded bucket size). Unlike softmax attention
there is no row-normalizer coupling K blocks, which is what makes the
tiled TPU kernel simpler than FlashAttention (see DESIGN.md
§Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp


def hstu_attention_ref(u, q, k, v, lengths):
    """Reference fused HSTU attention.

    Args:
      u, q, k, v: (B, H, L, dh) activations (already SiLU'd upstream).
      lengths: (B,) int32 true sequence lengths (<= L).

    Returns:
      (B, H, L, dh) gated attention output O * U.
    """
    _, _, L, dh = q.shape
    scores = jnp.einsum("bhld,bhmd->bhlm", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    pos = jnp.arange(L)
    causal = (pos[None, :] <= pos[:, None])[None, None]  # (1,1,L,L): k <= q
    kvalid = (pos[None, :] < lengths[:, None])[:, None, None, :]  # (B,1,1,L)
    mask = jnp.logical_and(causal, kvalid)
    denom = jnp.maximum(lengths, 1).astype(q.dtype)[:, None, None, None]
    attn = jax.nn.silu(scores) * mask.astype(q.dtype) / denom
    o = jnp.einsum("bhlm,bhmd->bhld", attn, v)
    return o * u
