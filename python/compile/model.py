"""L2: the GRM dense model (HSTU stack + MMoE, paper §2) in JAX.

Build-time only — ``aot.py`` lowers ``train_step``/``forward`` to HLO
text once; the Rust coordinator executes the artifacts via PJRT and
Python never runs on the training hot path.

Interface contract with the Rust runtime (see DESIGN.md §2):

- Dense parameters travel as ONE flat f32 vector; ``param_specs`` fixes
  the (name, shape) order and ``init_params`` produces the initial
  vector written to ``artifacts/<model>_params.bin``.
- ``train_step(params, emb, lengths, labels)`` returns
  ``(loss_sums[2], grads[P], emb_grad[B,L,D], logits[B,2], n_valid[])``
  where losses/grads are **sums over valid samples** (not means) so the
  Rust side can all-reduce sums + counts and apply the paper's weighted
  gradient averaging (§5.1) exactly.
- Padded samples have ``lengths[b] == 0`` and contribute nothing to the
  loss or gradients; padded tokens are masked inside HSTU attention and
  the mean-pool.

Model (paper Eq. 1-4):
  per block:  X' = LN(X); [U,Q,K,V] = SiLU(X' W + b)           (Eq. 1)
              O = (SiLU(QK^T)·mask) V ⊙ U   [Pallas kernel]    (Eq. 2)
              X = X + LN(O) W_o + b_o                          (Eq. 3)
  MMoE:       pooled = masked-mean(X); per task t:
              g_t = renorm-top-k softmax(pooled W_g)
              y_t = Σ_e g_te · Expert_e(pooled);  logit_t = y_t·w + b
                                                               (Eq. 4)
  Loss: CTR/CTCVR binary cross-entropy sums (§2: "cross entropy loss to
  optimize click-through rate and conversion rate").
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.hstu import hstu_attention

# ---------------------------------------------------------------------------
# Configs — MUST stay in sync with rust/src/config/presets.rs.
# ---------------------------------------------------------------------------

CONFIGS = {
    "tiny": dict(emb_dim=32, blocks=2, heads=2, experts=2, top_k=1,
                 expert_hidden=32, tasks=2),
    "small": dict(emb_dim=128, blocks=4, heads=2, experts=4, top_k=2,
                  expert_hidden=128, tasks=2),
}

# (batch, padded length) buckets compiled per model. The Rust runtime
# packs each dynamically balanced batch into the smallest fitting bucket.
BUCKETS = {
    "tiny": [(4, 32), (8, 64)],
    "small": [(8, 128), (16, 256)],
}


def param_specs(cfg):
    """Ordered (name, shape) list defining the flat parameter layout."""
    d = cfg["emb_dim"]
    h = cfg["expert_hidden"]
    specs = []
    for i in range(cfg["blocks"]):
        specs += [
            (f"blk{i}.norm1.scale", (d,)),
            (f"blk{i}.norm1.bias", (d,)),
            (f"blk{i}.uqkv.w", (d, 4 * d)),
            (f"blk{i}.uqkv.b", (4 * d,)),
            (f"blk{i}.norm2.scale", (d,)),
            (f"blk{i}.norm2.bias", (d,)),
            (f"blk{i}.out.w", (d, d)),
            (f"blk{i}.out.b", (d,)),
        ]
    for e in range(cfg["experts"]):
        specs += [
            (f"expert{e}.w1", (d, h)),
            (f"expert{e}.b1", (h,)),
            (f"expert{e}.w2", (h, d)),
            (f"expert{e}.b2", (d,)),
        ]
    for t in range(cfg["tasks"]):
        specs += [
            (f"gate{t}.w", (d, cfg["experts"])),
            (f"gate{t}.b", (cfg["experts"],)),
        ]
    for t in range(cfg["tasks"]):
        specs += [
            (f"head{t}.w", (d,)),
            (f"head{t}.b", ()),
        ]
    return specs


def param_count(cfg):
    return sum(int(np.prod(s)) for _, s in param_specs(cfg))


def init_params(cfg, seed=0):
    """Deterministic initialization of the flat parameter vector
    (LeCun-normal weights, zero biases, unit norm scales)."""
    rng = np.random.default_rng(seed)
    flat = []
    for name, shape in param_specs(cfg):
        if name.endswith(".scale"):
            flat.append(np.ones(shape, np.float32))
        elif name.endswith((".b", ".bias", ".b1", ".b2")) or shape == ():
            flat.append(np.zeros(shape, np.float32).reshape(-1))
        else:
            fan_in = shape[0] if len(shape) > 0 else 1
            w = rng.normal(0.0, 1.0 / np.sqrt(fan_in), size=shape)
            flat.append(w.astype(np.float32).reshape(-1))
    return np.concatenate([a.reshape(-1) for a in flat])


def unflatten(params, cfg):
    """Flat vector -> {name: array} (inside jit: pure slicing)."""
    out = {}
    off = 0
    for name, shape in param_specs(cfg):
        n = int(np.prod(shape)) if shape else 1
        out[name] = params[off:off + n].reshape(shape)
        off += n
    return out


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _layernorm(x, scale, bias, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _hstu_block(p, i, x, lengths):
    """One HSTU block (Eq. 1-3) with residual connection."""
    B, L, d = x.shape
    xn = _layernorm(x, p[f"blk{i}.norm1.scale"], p[f"blk{i}.norm1.bias"])
    uqkv = jax.nn.silu(xn @ p[f"blk{i}.uqkv.w"] + p[f"blk{i}.uqkv.b"])
    u, q, k, v = jnp.split(uqkv, 4, axis=-1)  # each (B, L, d)

    heads = _HEADS[0]
    dh = d // heads

    def to_heads(t):
        return t.reshape(B, L, heads, dh).transpose(0, 2, 1, 3)

    o = hstu_attention(to_heads(u), to_heads(q), to_heads(k), to_heads(v),
                       lengths)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, d)
    on = _layernorm(o, p[f"blk{i}.norm2.scale"], p[f"blk{i}.norm2.bias"])
    return x + on @ p[f"blk{i}.out.w"] + p[f"blk{i}.out.b"]


# jnp.split / reshape need static head counts; threaded via this cell to
# keep _hstu_block signature jit-friendly.
_HEADS = [2]


def forward(params, emb, lengths, cfg):
    """Logits (B, tasks) for a padded batch.

    emb: (B, L, d) pooled token embeddings from the Rust sparse side.
    lengths: (B,) int32 true lengths (0 = padded sample).
    """
    _HEADS[0] = cfg["heads"]
    p = unflatten(params, cfg)
    B, L, d = emb.shape
    x = emb
    for i in range(cfg["blocks"]):
        x = _hstu_block(p, i, x, lengths)

    # Masked mean-pool over valid tokens.
    pos = jnp.arange(L)
    tok_valid = (pos[None, :] < lengths[:, None]).astype(x.dtype)  # (B, L)
    denom = jnp.maximum(lengths, 1).astype(x.dtype)[:, None]
    pooled = (x * tok_valid[..., None]).sum(1) / denom  # (B, d)

    # Experts (shared across tasks).
    experts = []
    for e in range(cfg["experts"]):
        hdn = jax.nn.silu(pooled @ p[f"expert{e}.w1"] + p[f"expert{e}.b1"])
        experts.append(hdn @ p[f"expert{e}.w2"] + p[f"expert{e}.b2"])
    experts = jnp.stack(experts, axis=1)  # (B, E, d)

    logits = []
    for t in range(cfg["tasks"]):
        gate_logits = pooled @ p[f"gate{t}.w"] + p[f"gate{t}.b"]  # (B, E)
        # Top-k routing: keep the k largest gates, renormalize (Eq. 4 /
        # §2 "aggregate the output embeddings of the top-k expert
        # models"). Implemented as iterative max extraction: lax.top_k
        # lowers to a `topk(..., largest=true)` HLO the xla_extension
        # 0.5.1 text parser rejects, and grad-of-sort trips a
        # GatherDimensionNumbers incompatibility in this jax/xla pairing.
        # k is 1-2, and the routing threshold carries no gradient.
        kth = jax.lax.stop_gradient(_kth_largest(gate_logits, cfg["top_k"]))
        masked = jnp.where(gate_logits >= kth, gate_logits, -jnp.inf)
        g = jax.nn.softmax(masked, axis=-1)  # (B, E)
        y = jnp.einsum("be,bed->bd", g, experts)
        logits.append(y @ p[f"head{t}.w"] + p[f"head{t}.b"])
    return jnp.stack(logits, axis=1)  # (B, tasks)


def _kth_largest(x, k):
    """k-th largest value along the last axis (k small, static).

    Iterative max extraction; exact ties collapse together (fine for
    expert gating where ties have measure zero).
    """
    cur = x
    for _ in range(k - 1):
        m = cur.max(-1, keepdims=True)
        cur = jnp.where(cur >= m, -jnp.inf, cur)
    return cur.max(-1, keepdims=True)


def _bce_with_logits(z, y):
    """Numerically stable binary cross-entropy with logits."""
    return jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))


def loss_sums(params, emb, lengths, labels, cfg):
    """Per-task BCE loss *sums* over valid samples + logits."""
    logits = forward(params, emb, lengths, cfg)  # (B, T)
    valid = (lengths > 0).astype(logits.dtype)[:, None]  # (B, 1)
    per_task = (_bce_with_logits(logits, labels) * valid).sum(0)  # (T,)
    return per_task.sum(), (per_task, logits, valid.sum())


def train_step(params, emb, lengths, labels, cfg):
    """One training step's computation (no state update — the optimizer
    lives in Rust).

    Returns (loss_sums[T], grads[P], emb_grad[B,L,d], logits[B,T],
    n_valid[]).
    """
    grad_fn = jax.value_and_grad(loss_sums, argnums=(0, 1), has_aux=True)
    (_, (per_task, logits, n_valid)), (gp, gemb) = grad_fn(
        params, emb, lengths, labels, cfg
    )
    return per_task, gp, gemb, logits, n_valid


def make_train_fn(name):
    cfg = CONFIGS[name]
    return functools.partial(train_step, cfg=cfg)


def make_forward_fn(name):
    cfg = CONFIGS[name]

    def fwd(params, emb, lengths):
        return (forward(params, emb, lengths, cfg),)

    return fwd
