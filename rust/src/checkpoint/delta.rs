//! Incremental delta snapshots: the training → serving sync path.
//!
//! A full checkpoint of a production embedding table is far too large
//! to ship every few minutes; Monolith-style systems instead sync
//! **deltas** — only the rows touched since the last sync plus the ids
//! retired in between — which serving applies on top of a base
//! snapshot. This module implements that format on the trainer side:
//!
//! ```text
//! <dir>/delta_<seq:05>/meta.json    seq, world, step, base_step, model,
//!                                   dim, param_count
//!                                   [+ group_dims when > 1 merge group]
//!                                   [+ precision, hot_threshold when mixed]
//! <dir>/delta_<seq:05>/dense.bin    full dense params + Adam state
//!                                   (rank 0 — dense is tiny next to the
//!                                   sparse tables, so it ships whole)
//! <dir>/delta_<seq:05>/sparse_rank<r>_of<n>.bin         (merge group 0)
//! <dir>/delta_<seq:05>/sparse_rank<r>_of<n>_g<k>.bin    (merge group k ≥ 1)
//!         u64 n_removed | removed ids u64 × n_removed
//!         | u64 count | u64 dim | rows (id | row | m | v | t) × count
//! ```
//!
//! Heterogeneous schemas sync **one shard file per merge group** at the
//! group's dim ([`save_delta_groups`] / [`load_delta_shard_group`]);
//! a single-group save is byte-identical to the historical layout
//! (legacy file name, no `group_dims` key).
//!
//! The row wire format is byte-identical to the full checkpoint's
//! ([`super::save`]), so one codec serves both. **Reconstruction
//! contract** (tested): installing a base snapshot and applying every
//! delta in `seq` order — removals first, then upserts — yields a state
//! bit-identical to a full checkpoint taken at the same step: same row
//! set, same row values, same Adam `m`/`v`/`t`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{
    parse_sparse_file, push_row_bytes, read_sealed, rows_block_bytes, write_dense_bin,
    write_sealed, CheckpointMeta, SparseRow,
};
use crate::embedding::concurrent::ConcurrentDynamicTable;
use crate::embedding::precision::PrecisionPolicy;
use crate::embedding::GlobalId;
use crate::optim::adam::{DenseAdam, RowState, SparseAdam};
use crate::util::json::Json;

/// Metadata of one delta snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaMeta {
    /// Sync sequence number (1-based; deltas apply in ascending order).
    pub seq: u64,
    pub world: usize,
    /// Step the snapshot was taken at.
    pub step: u64,
    /// Step of the state this delta applies on top of (the previous
    /// sync point; 0 for the first delta, which applies to the empty /
    /// base state).
    pub base_step: u64,
    pub model: String,
    pub dim: usize,
    pub param_count: usize,
}

/// Directory of delta `seq` under the sync root.
pub fn delta_dir(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("delta_{seq:05}"))
}

fn sparse_delta_path(dir: &Path, seq: u64, rank: usize, world: usize) -> PathBuf {
    delta_dir(dir, seq).join(format!("sparse_rank{rank:05}_of{world}.bin"))
}

/// Merge group `group`'s shard file of delta `seq` (group 0 keeps the
/// historical single-group name). Public so the distributed
/// supervisor's recovery scan can CRC-verify every shard of a delta,
/// and so the fault harness can tear a specific shard file.
pub fn sparse_delta_group_path(
    dir: &Path,
    seq: u64,
    rank: usize,
    world: usize,
    group: usize,
) -> PathBuf {
    if group == 0 {
        sparse_delta_path(dir, seq, rank, world)
    } else {
        delta_dir(dir, seq).join(format!("sparse_rank{rank:05}_of{world}_g{group}.bin"))
    }
}

/// One merge group's payload for [`save_delta_groups`]: the group's
/// embedding dim, the rows upserted since the last sync and the ids
/// retired in between.
pub struct GroupDelta<'a> {
    pub dim: usize,
    pub upserts: &'a [SparseRow],
    pub removed: &'a [GlobalId],
    /// The precision policy the group's rows were stored under. When
    /// enabled, rank 0 records it in the snapshot meta so serving
    /// replicas and recovery replay on the same f16 grid; the disabled
    /// fp32 policy writes no keys (byte-identical historical layout).
    pub policy: PrecisionPolicy,
}

/// Write one rank's shard of a delta snapshot, one sparse file per
/// merge group (rank 0 additionally writes the metadata — including
/// `group_dims` when there are ≥ 2 groups — and the full dense
/// replica). Returns the total bytes of this rank's sparse payloads —
/// the sync volume the trainer accounts per interval. A single-group
/// call produces byte-identical files to the historical
/// [`save_delta`].
pub fn save_delta_groups(
    dir: &Path,
    meta: &DeltaMeta,
    rank: usize,
    dense: Option<(&[f32], &DenseAdam)>,
    groups: &[GroupDelta],
) -> Result<usize> {
    anyhow::ensure!(!groups.is_empty(), "delta needs at least one group");
    anyhow::ensure!(
        groups.iter().all(|g| g.policy == groups[0].policy),
        "delta groups disagree on the precision policy (the trainer \
         installs one policy for every merge group)"
    );
    let ddir = delta_dir(dir, meta.seq);
    std::fs::create_dir_all(&ddir)?;
    if rank == 0 {
        let (params, adam) =
            dense.context("rank 0 must provide the dense params + optimizer")?;
        anyhow::ensure!(params.len() == meta.param_count, "params arity");
        let mut j = Json::obj();
        j.set("seq", (meta.seq as usize).into());
        j.set("world", meta.world.into());
        j.set("step", (meta.step as usize).into());
        j.set("base_step", (meta.base_step as usize).into());
        j.set("model", meta.model.as_str().into());
        j.set("dim", meta.dim.into());
        j.set("param_count", meta.param_count.into());
        if groups.len() > 1 {
            j.set(
                "group_dims",
                Json::Arr(groups.iter().map(|g| g.dim.into()).collect()),
            );
        }
        super::set_precision_keys(&mut j, groups[0].policy);
        std::fs::write(ddir.join("meta.json"), j.pretty())?;
        write_dense_bin(&ddir, params, adam)?;
    }

    let mut total = 0usize;
    for (g, gd) in groups.iter().enumerate() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(gd.removed.len() as u64).to_le_bytes());
        for id in gd.removed {
            bytes.extend_from_slice(&id.to_le_bytes());
        }
        let mut body = Vec::new();
        for r in gd.upserts {
            anyhow::ensure!(
                r.row.len() == gd.dim,
                "row dim mismatch in delta group {g}"
            );
            push_row_bytes(&mut body, r.id, &r.row, &r.m, &r.v, r.t);
        }
        bytes.extend_from_slice(&rows_block_bytes(gd.upserts.len() as u64, gd.dim, &body));
        total += bytes.len();
        write_sealed(
            &sparse_delta_group_path(dir, meta.seq, rank, meta.world, g),
            bytes,
        )?;
    }
    Ok(total)
}

/// Write one rank's shard of a single-group delta snapshot (the
/// historical layout). Returns the bytes of this rank's sparse payload.
pub fn save_delta(
    dir: &Path,
    meta: &DeltaMeta,
    rank: usize,
    dense: Option<(&[f32], &DenseAdam)>,
    upserts: &[SparseRow],
    removed: &[GlobalId],
) -> Result<usize> {
    save_delta_groups(
        dir,
        meta,
        rank,
        dense,
        &[GroupDelta {
            dim: meta.dim,
            upserts,
            removed,
            policy: PrecisionPolicy::fp32(),
        }],
    )
}

/// Read delta `seq`'s metadata.
pub fn load_delta_meta(dir: &Path, seq: u64) -> Result<DeltaMeta> {
    let path = delta_dir(dir, seq).join("meta.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no delta meta at {}", path.display()))?;
    let j = Json::parse(&text).context("parse delta meta")?;
    Ok(DeltaMeta {
        seq: j.expect_usize("seq")? as u64,
        world: j.expect_usize("world")?,
        step: j.expect_usize("step")? as u64,
        base_step: j.expect_usize("base_step")? as u64,
        model: j.expect_str("model")?.to_string(),
        dim: j.expect_usize("dim")?,
        param_count: j.expect_usize("param_count")?,
    })
}

/// Read one rank's shard of delta `seq` (merge group 0 — the
/// historical single-group layout): `(upserted rows, removed ids)`.
pub fn load_delta_shard(
    dir: &Path,
    meta: &DeltaMeta,
    rank: usize,
) -> Result<(Vec<SparseRow>, Vec<GlobalId>)> {
    load_delta_shard_group(dir, meta, rank, 0)
}

/// Read one rank's shard of delta `seq` for merge group `group`.
pub fn load_delta_shard_group(
    dir: &Path,
    meta: &DeltaMeta,
    rank: usize,
    group: usize,
) -> Result<(Vec<SparseRow>, Vec<GlobalId>)> {
    let path = sparse_delta_group_path(dir, meta.seq, rank, meta.world, group);
    let bytes = read_sealed(&path)?;
    if bytes.len() < 8 {
        bail!("delta shard truncated header");
    }
    let n_removed = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    let rows_off = 8 + n_removed * 8;
    if bytes.len() < rows_off + 16 {
        bail!("delta shard truncated removed-ids block");
    }
    let removed: Vec<GlobalId> = bytes[8..rows_off]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let rows = parse_sparse_file(&bytes[rows_off..])?;
    Ok((rows, removed))
}

/// Per-group dims recorded in delta `seq`'s metadata; `[meta.dim]` for
/// single-group (historical) snapshots, which never write the key.
pub fn load_delta_group_dims(dir: &Path, meta: &DeltaMeta) -> Result<Vec<usize>> {
    let path = delta_dir(dir, meta.seq).join("meta.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no delta meta at {}", path.display()))?;
    let j = Json::parse(&text).context("parse delta meta")?;
    super::parse_group_dims(&j, meta.dim)
}

/// Precision policy recorded in delta `seq`'s metadata (the disabled
/// fp32 policy for snapshots that never wrote the keys).
pub fn load_delta_precision_policy(dir: &Path, seq: u64) -> Result<PrecisionPolicy> {
    let path = delta_dir(dir, seq).join("meta.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no delta meta at {}", path.display()))?;
    let j = Json::parse(&text).context("parse delta meta")?;
    super::parse_precision_keys(&j)
}

/// The smallest byte count a real snapshot `meta.json` can have; a
/// shorter (or missing) meta marks a **torn** snapshot directory — a
/// crash between `create_dir_all` and the meta write — which must never
/// be surfaced as an applyable delta.
const MIN_META_BYTES: u64 = 64;

/// Parse a canonical `<prefix><seq:05>` snapshot directory name.
/// Returns `Ok(None)` for names that don't start with `prefix`, and an
/// **error** for names that do but are not the canonical zero-padded
/// spelling: `delta_7` and `delta_007` would both alias `delta_00007`'s
/// sequence number, so a replica that accepted them could apply the
/// same delta twice (or an attacker-/tooling-mangled dir once too
/// often).
pub(crate) fn parse_canonical_seq(prefix: &str, name: &str) -> Result<Option<u64>> {
    let Some(tail) = name.strip_prefix(prefix) else {
        return Ok(None);
    };
    let seq = match tail.parse::<u64>() {
        Ok(s) if tail.bytes().all(|b| b.is_ascii_digit()) => s,
        _ => bail!(
            "`{name}` is not a canonical snapshot name (expected `{prefix}<seq:05>`)"
        ),
    };
    anyhow::ensure!(
        tail == format!("{seq:05}"),
        "`{name}` aliases seq {seq}: the canonical name is `{prefix}{seq:05}` \
         (refusing ambiguous snapshot names)"
    );
    Ok(Some(seq))
}

/// Sync sequence numbers present under `dir`, ascending.
///
/// Only canonical `delta_<seq:05>` names are accepted — a non-canonical
/// spelling (`delta_7`, `delta_007`) is an error, not a silent alias —
/// duplicates error, and torn snapshot directories (meta file missing
/// or shorter than any valid meta) error instead of being surfaced as
/// applyable deltas.
pub fn list_delta_seqs(dir: &Path) -> Result<Vec<u64>> {
    let mut seqs = Vec::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("read sync dir {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(seq) = parse_canonical_seq("delta_", &name)? else {
            continue; // bases, tmp dirs, unrelated files
        };
        let meta = entry.path().join("meta.json");
        let meta_len = std::fs::metadata(&meta).map(|m| m.len()).unwrap_or(0);
        anyhow::ensure!(
            meta_len >= MIN_META_BYTES,
            "torn delta snapshot `{name}`: meta.json {} ({meta_len} bytes) — \
             the write was interrupted; refusing to surface it as applyable",
            if meta_len == 0 { "missing" } else { "truncated" }
        );
        seqs.push(seq);
    }
    seqs.sort_unstable();
    // Canonical names make one seq ↔ one directory, but keep the
    // invariant checked so a filesystem surprise fails loudly rather
    // than double-applying a delta.
    for w in seqs.windows(2) {
        anyhow::ensure!(
            w[0] != w[1],
            "duplicate delta snapshots for seq {} under {}",
            w[0],
            dir.display()
        );
    }
    Ok(seqs)
}

/// Validate and load the delta chain that applies on top of a base at
/// (`base_seq`, `base_step`) — `(0, 0)` for the empty state. The chain
/// must be `base_seq+1 ..= newest` with **no holes**, every meta's
/// `seq` must match its directory name, each delta's `base_step` must
/// equal the previous snapshot's `step`, and `world` must not change
/// mid-chain. Returns the metas in apply order. A gap is a hard error:
/// replaying across a hole would silently reconstruct stale state, the
/// exact failure a serving replica must never ship.
pub fn validate_chain(dir: &Path, base_seq: u64, base_step: u64) -> Result<Vec<DeltaMeta>> {
    let seqs = list_delta_seqs(dir)?;
    let mut metas: Vec<DeltaMeta> = Vec::new();
    let mut prev_seq = base_seq;
    let mut prev_step = base_step;
    for seq in seqs {
        if seq <= base_seq {
            continue; // already folded into the base
        }
        anyhow::ensure!(
            seq == prev_seq + 1,
            "delta chain has a gap: delta_{:05} is missing under {} (next present \
             snapshot is delta_{seq:05}); refusing to replay across the hole",
            prev_seq + 1,
            dir.display()
        );
        let m = load_delta_meta(dir, seq)?;
        anyhow::ensure!(
            m.seq == seq,
            "delta_{seq:05}: meta says seq {} — the snapshot dir was renamed or torn",
            m.seq
        );
        anyhow::ensure!(
            m.base_step == prev_step,
            "delta_{seq:05} applies on top of step {} but the chain is at step \
             {prev_step}: the base it expects is not the state being replayed",
            m.base_step
        );
        if let Some(prev) = metas.last() {
            anyhow::ensure!(
                m.world == prev.world && m.param_count == prev.param_count,
                "delta_{seq:05} changes world/param_count mid-chain \
                 ({}/{} → {}/{})",
                prev.world,
                prev.param_count,
                m.world,
                m.param_count
            );
        }
        prev_seq = seq;
        prev_step = m.step;
        metas.push(m);
    }
    Ok(metas)
}

/// Materialize the rows for `ids` (with Adam state) from a concurrent
/// shard — the delta's upsert payload. Ids whose rows vanished between
/// tracking and snapshot (cannot happen under the trainer's quiescent
/// sync point, but cheap to guard) are skipped.
pub fn collect_rows(
    table: &ConcurrentDynamicTable,
    opt: &SparseAdam,
    ids: &[GlobalId],
) -> Vec<SparseRow> {
    let d = table.dim();
    let mut out = Vec::with_capacity(ids.len());
    for &id in ids {
        let Some(row) = table.row(id) else { continue };
        let (m, v, t) = match opt.row_state(id) {
            Some(st) => (st.m.clone(), st.v.clone(), st.t),
            None => (vec![0.0; d], vec![0.0; d], 0),
        };
        out.push(SparseRow { id, row, m, v, t });
    }
    out
}

/// Every live row of a concurrent shard (with Adam state), sorted by id
/// — the full-state witness used to verify reconstruction and to write
/// full checkpoints from concurrent tables.
pub fn snapshot_rows(table: &ConcurrentDynamicTable, opt: &SparseAdam) -> Vec<SparseRow> {
    let mut ids = table.live_ids();
    ids.sort_unstable();
    collect_rows(table, opt, &ids)
}

/// Full checkpoint of a set of concurrent shards (one per merge
/// group), byte-compatible with [`super::load_meta`] /
/// [`super::load_dense`] / [`super::load_sparse_shard_group`]. Rows are
/// written sorted by id, so the file bytes are identical for every
/// `--threads` value. With one group this is byte-identical to the
/// historical [`save_full`] layout.
pub fn save_full_groups(
    dir: &Path,
    meta: &CheckpointMeta,
    rank: usize,
    dense: Option<(&[f32], &DenseAdam)>,
    groups: &[(&ConcurrentDynamicTable, &SparseAdam)],
) -> Result<()> {
    anyhow::ensure!(!groups.is_empty(), "checkpoint needs at least one group");
    let policy = groups[0].0.precision();
    anyhow::ensure!(
        groups.iter().all(|(t, _)| t.precision() == policy),
        "checkpoint groups disagree on the precision policy (the trainer \
         installs one policy for every merge group)"
    );
    std::fs::create_dir_all(dir)?;
    if rank == 0 {
        let (params, adam) =
            dense.context("rank 0 must provide the dense params + optimizer")?;
        anyhow::ensure!(params.len() == meta.param_count, "params arity");
        let mut j = Json::obj();
        j.set("world", meta.world.into());
        j.set("step", (meta.step as usize).into());
        j.set("model", meta.model.as_str().into());
        j.set("dim", meta.dim.into());
        j.set("param_count", meta.param_count.into());
        if groups.len() > 1 {
            j.set(
                "group_dims",
                Json::Arr(groups.iter().map(|(t, _)| t.dim().into()).collect()),
            );
        }
        super::set_precision_keys(&mut j, policy);
        std::fs::write(dir.join("meta.json"), j.pretty())?;
        write_dense_bin(dir, params, adam)?;
    }
    for (g, (table, opt)) in groups.iter().enumerate() {
        let rows = snapshot_rows(table, opt);
        let mut body = Vec::new();
        for r in &rows {
            push_row_bytes(&mut body, r.id, &r.row, &r.m, &r.v, r.t);
        }
        write_sealed(
            &super::sparse_group_path(dir, rank, meta.world, g),
            rows_block_bytes(rows.len() as u64, table.dim(), &body),
        )?;
    }
    Ok(())
}

/// Full checkpoint of a single concurrent shard (the historical
/// single-group layout).
pub fn save_full(
    dir: &Path,
    meta: &CheckpointMeta,
    rank: usize,
    dense: Option<(&[f32], &DenseAdam)>,
    table: &ConcurrentDynamicTable,
    opt: &SparseAdam,
) -> Result<()> {
    anyhow::ensure!(table.dim() == meta.dim, "table dim != meta dim");
    save_full_groups(dir, meta, rank, dense, &[(table, opt)])
}

/// Install full-checkpoint rows into a concurrent shard (serving-side
/// base install). Row bits are copied verbatim ([`ConcurrentDynamicTable::set_row`]),
/// so the target's init seed is irrelevant.
pub fn install_rows_concurrent(
    rows: Vec<SparseRow>,
    table: &ConcurrentDynamicTable,
    opt: &mut SparseAdam,
) {
    let mut scratch = Vec::new();
    for r in rows {
        table.set_row_scratch(r.id, &r.row, &mut scratch);
        if r.t > 0 {
            opt.restore_row(
                r.id,
                RowState {
                    m: r.m,
                    v: r.v,
                    t: r.t,
                },
            );
        } else {
            opt.drop_row(r.id);
        }
    }
}

/// Apply one delta on top of the current state: removals first (retired
/// rows and their optimizer state disappear), then upserts (exact row +
/// Adam bits). Deltas must be applied in ascending `seq` order.
pub fn apply_delta(
    table: &ConcurrentDynamicTable,
    opt: &mut SparseAdam,
    rows: Vec<SparseRow>,
    removed: &[GlobalId],
) {
    for &id in removed {
        table.remove(id);
        opt.drop_row(id);
    }
    install_rows_concurrent(rows, table, opt);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::dynamic_table::DynamicTableConfig;
    use crate::optim::adam::AdamParams;

    const DIM: usize = 3;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "mtgr_delta_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn table(seed: u64) -> ConcurrentDynamicTable {
        ConcurrentDynamicTable::new(
            DynamicTableConfig::new(DIM).with_capacity(128).with_seed(seed),
            4,
        )
    }

    fn meta(seq: u64, step: u64) -> DeltaMeta {
        DeltaMeta {
            seq,
            world: 1,
            step,
            base_step: step.saturating_sub(5),
            model: "tiny".into(),
            dim: DIM,
            param_count: 2,
        }
    }

    #[test]
    fn delta_roundtrip_preserves_rows_and_removals() {
        let dir = tmp("rt");
        let t = table(1);
        let mut o = SparseAdam::new(DIM, AdamParams::default());
        let mut buf = vec![0.0f32; DIM];
        for id in 0..20u64 {
            t.lookup_or_insert(id, &mut buf);
        }
        let ids: Vec<u64> = (0..20).collect();
        let grads = vec![0.5f32; 20 * DIM];
        o.step_concurrent(
            &crate::util::pool::WorkerPool::new(1),
            &t,
            &ids,
            &grads,
            1.0,
        );
        let upserts = collect_rows(&t, &o, &ids);
        let removed = vec![100u64, 200];
        let m = meta(1, 5);
        let params = [0.25f32, -1.0];
        let dopt = DenseAdam::new(2, AdamParams::default());
        let bytes =
            save_delta(&dir, &m, 0, Some((&params[..], &dopt)), &upserts, &removed).unwrap();
        assert!(bytes > 16 + removed.len() * 8);

        let m2 = load_delta_meta(&dir, 1).unwrap();
        assert_eq!(m2, m);
        let (rows, rem) = load_delta_shard(&dir, &m2, 0).unwrap();
        assert_eq!(rem, removed);
        assert_eq!(rows, upserts, "rows roundtrip bit-exactly");
        assert!(rows.iter().all(|r| r.t == 1), "Adam state rides along");
        assert_eq!(list_delta_seqs(&dir).unwrap(), vec![1]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn base_plus_delta_reconstructs_exactly() {
        let dir = tmp("recon");
        // "Training" shard with churn across two intervals.
        let train = table(7);
        let mut train_opt = SparseAdam::new(DIM, AdamParams::default());
        let mut buf = vec![0.0f32; DIM];
        let pool = crate::util::pool::WorkerPool::new(1);

        // Interval 1: ids 0..30 inserted + updated → full base snapshot.
        for id in 0..30u64 {
            train.lookup_or_insert(id, &mut buf);
        }
        let ids1: Vec<u64> = (0..30).collect();
        let g1 = vec![0.1f32; 30 * DIM];
        train_opt.step_concurrent(&pool, &train, &ids1, &g1, 1.0);
        let base = snapshot_rows(&train, &train_opt);

        // Interval 2: update some, insert some, remove some.
        let ids2: Vec<u64> = (10..40).collect();
        for &id in &ids2 {
            train.lookup_or_insert(id, &mut buf);
        }
        let g2 = vec![-0.2f32; 30 * DIM];
        train_opt.step_concurrent(&pool, &train, &ids2, &g2, 0.5);
        for id in 0..5u64 {
            train.remove(id);
            train_opt.drop_row(id);
        }
        let m = meta(1, 10);
        let upserts = collect_rows(&train, &train_opt, &ids2);
        let removed: Vec<u64> = (0..5).collect();
        let params = [1.0f32, 2.0];
        let dopt = DenseAdam::new(2, AdamParams::default());
        save_delta(&dir, &m, 0, Some((&params[..], &dopt)), &upserts, &removed).unwrap();

        // Serving side: install base (different seed!), apply the delta.
        let serve = table(99);
        let mut serve_opt = SparseAdam::new(DIM, AdamParams::default());
        install_rows_concurrent(base, &serve, &mut serve_opt);
        let dm = load_delta_meta(&dir, 1).unwrap();
        let (rows, rem) = load_delta_shard(&dir, &dm, 0).unwrap();
        apply_delta(&serve, &mut serve_opt, rows, &rem);

        assert_eq!(
            snapshot_rows(&serve, &serve_opt),
            snapshot_rows(&train, &train_opt),
            "base + delta must reconstruct rows AND Adam state exactly"
        );
        assert_eq!(serve.content_checksum(), train.content_checksum());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn save_full_is_readable_by_the_standard_loader() {
        let dir = tmp("full");
        let t = table(3);
        let mut o = SparseAdam::new(DIM, AdamParams::default());
        let mut buf = vec![0.0f32; DIM];
        for id in 0..15u64 {
            t.lookup_or_insert(id, &mut buf);
        }
        let g = vec![0.3f32; 15 * DIM];
        o.step_concurrent(
            &crate::util::pool::WorkerPool::new(1),
            &t,
            &(0..15).collect::<Vec<_>>(),
            &g,
            1.0,
        );
        let cm = CheckpointMeta {
            world: 1,
            step: 9,
            model: "tiny".into(),
            dim: DIM,
            param_count: 2,
        };
        let params = [0.5f32, 0.25];
        let dopt = DenseAdam::new(2, AdamParams::default());
        save_full(&dir, &cm, 0, Some((&params[..], &dopt)), &t, &o).unwrap();

        let m2 = super::super::load_meta(&dir).unwrap();
        assert_eq!(m2.step, 9);
        let (p, _) = super::super::load_dense(&dir, 2).unwrap();
        assert_eq!(p, params);
        let rows = super::super::load_sparse_shard(&dir, &m2, 1, 0).unwrap();
        assert_eq!(rows.len(), 15);
        assert_eq!(rows, snapshot_rows(&t, &o), "sorted full snapshot");
        std::fs::remove_dir_all(dir).ok();
    }

    /// Write a minimal-but-complete delta snapshot (world 1, empty
    /// payload) so listing/chain tests can build arbitrary chains.
    fn write_delta(dir: &Path, seq: u64, step: u64, base_step: u64) {
        let m = DeltaMeta {
            seq,
            world: 1,
            step,
            base_step,
            model: "tiny".into(),
            dim: DIM,
            param_count: 2,
        };
        let dopt = DenseAdam::new(2, crate::optim::adam::AdamParams::default());
        save_delta(dir, &m, 0, Some((&[0.0, 0.0][..], &dopt)), &[], &[]).unwrap();
    }

    #[test]
    fn precision_metadata_rides_deltas_and_full_checkpoints() {
        let dir = tmp("prec");
        // fp32 snapshots write no keys, keeping their meta bytes
        // byte-identical to the historical layout.
        write_delta(&dir, 1, 5, 0);
        let text =
            std::fs::read_to_string(delta_dir(&dir, 1).join("meta.json")).unwrap();
        assert!(!text.contains("precision"), "fp32 meta stays keyless: {text}");
        assert!(!text.contains("hot_threshold"), "{text}");
        assert_eq!(
            load_delta_precision_policy(&dir, 1).unwrap(),
            PrecisionPolicy::fp32()
        );

        // A mixed delta records the policy; the loader round-trips it.
        let m = meta(2, 10);
        let dopt = DenseAdam::new(2, AdamParams::default());
        save_delta_groups(
            &dir,
            &m,
            0,
            Some((&[0.0, 0.0][..], &dopt)),
            &[GroupDelta {
                dim: DIM,
                upserts: &[],
                removed: &[],
                policy: PrecisionPolicy::mixed(6),
            }],
        )
        .unwrap();
        assert_eq!(
            load_delta_precision_policy(&dir, 2).unwrap(),
            PrecisionPolicy::mixed(6)
        );

        // Groups disagreeing on the policy are a writer-side error.
        let m3 = meta(3, 15);
        let err = save_delta_groups(
            &dir,
            &m3,
            0,
            Some((&[0.0, 0.0][..], &dopt)),
            &[
                GroupDelta {
                    dim: DIM,
                    upserts: &[],
                    removed: &[],
                    policy: PrecisionPolicy::mixed(6),
                },
                GroupDelta {
                    dim: DIM,
                    upserts: &[],
                    removed: &[],
                    policy: PrecisionPolicy::fp32(),
                },
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("precision"), "{err}");
        std::fs::remove_dir_all(&dir).ok();

        // Full checkpoints derive the keys from the tables themselves.
        let cdir = tmp("prec_full");
        let t = table(3).with_precision(PrecisionPolicy::mixed(4));
        let mut buf = vec![0.0f32; DIM];
        for id in 0..10u64 {
            t.lookup_or_insert(id, &mut buf);
        }
        let o = SparseAdam::new(DIM, AdamParams::default());
        let cm = CheckpointMeta {
            world: 1,
            step: 3,
            model: "tiny".into(),
            dim: DIM,
            param_count: 2,
        };
        let dopt2 = DenseAdam::new(2, AdamParams::default());
        save_full(&cdir, &cm, 0, Some((&[0.1, 0.2][..], &dopt2)), &t, &o).unwrap();
        assert_eq!(
            crate::checkpoint::load_precision_policy(&cdir).unwrap(),
            PrecisionPolicy::mixed(4)
        );
        // And the rows it wrote are the stored (f16-grid) bits verbatim:
        // installing them elsewhere reproduces the content checksum.
        let meta2 = crate::checkpoint::load_meta(&cdir).unwrap();
        let rows = crate::checkpoint::load_sparse_shard(&cdir, &meta2, 1, 0).unwrap();
        let t2 = table(99);
        let mut opt2 = SparseAdam::new(DIM, AdamParams::default());
        install_rows_concurrent(rows, &t2, &mut opt2);
        assert_eq!(t2.content_checksum(), t.content_checksum());
        std::fs::remove_dir_all(cdir).ok();
    }

    #[test]
    fn list_rejects_non_canonical_delta_names() {
        let dir = tmp("canon");
        write_delta(&dir, 7, 35, 30);
        assert_eq!(list_delta_seqs(&dir).unwrap(), vec![7]);
        // `delta_007` would alias seq 7 — listing must error, not fold
        // two directories onto one sequence number.
        std::fs::create_dir_all(dir.join("delta_007")).unwrap();
        let err = list_delta_seqs(&dir).unwrap_err().to_string();
        assert!(err.contains("delta_00007"), "names the canonical spelling: {err}");
        std::fs::remove_dir_all(&dir).ok();

        // Same for an unpadded spelling and for non-numeric tails.
        write_delta(&dir, 7, 35, 30);
        std::fs::create_dir_all(dir.join("delta_7")).unwrap();
        assert!(list_delta_seqs(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
        write_delta(&dir, 7, 35, 30);
        std::fs::create_dir_all(dir.join("delta_+0007")).unwrap();
        assert!(list_delta_seqs(&dir).is_err(), "sign prefixes are not canonical");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn list_ignores_unrelated_names_and_accepts_wide_seqs() {
        let dir = tmp("wide");
        write_delta(&dir, 1, 5, 0);
        // Bases, tmp dirs and stray files are not deltas.
        std::fs::create_dir_all(dir.join("base_00001")).unwrap();
        std::fs::create_dir_all(dir.join("base_00002.tmp")).unwrap();
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        // Seqs past 5 digits have no padding to get wrong.
        write_delta(&dir, 123456, 617280, 617275);
        assert_eq!(list_delta_seqs(&dir).unwrap(), vec![1, 123456]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn list_rejects_torn_snapshot_dirs() {
        let dir = tmp("torn");
        write_delta(&dir, 1, 5, 0);
        // Crash after create_dir_all, before the meta write.
        std::fs::create_dir_all(delta_dir(&dir, 2)).unwrap();
        let err = list_delta_seqs(&dir).unwrap_err().to_string();
        assert!(err.contains("torn"), "{err}");
        assert!(err.contains("missing"), "{err}");
        // Crash mid-meta-write: a short meta is equally torn.
        std::fs::write(delta_dir(&dir, 2).join("meta.json"), "{\"seq\":").unwrap();
        let err = list_delta_seqs(&dir).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn validate_chain_accepts_contiguous_and_rejects_gaps() {
        let dir = tmp("chain");
        for seq in 1..=4u64 {
            write_delta(&dir, seq, seq * 5, (seq - 1) * 5);
        }
        // Full chain from the empty state.
        let metas = validate_chain(&dir, 0, 0).unwrap();
        assert_eq!(metas.iter().map(|m| m.seq).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        // From a base at seq 2 / step 10: only the suffix applies.
        let metas = validate_chain(&dir, 2, 10).unwrap();
        assert_eq!(metas.iter().map(|m| m.seq).collect::<Vec<_>>(), vec![3, 4]);
        // Punch a hole: replay must fail loudly, not reconstruct stale
        // state from the surviving suffix.
        std::fs::remove_dir_all(delta_dir(&dir, 2)).unwrap();
        let err = validate_chain(&dir, 0, 0).unwrap_err().to_string();
        assert!(err.contains("gap"), "{err}");
        assert!(err.contains("delta_00002"), "names the missing seq: {err}");
        // A base past the hole is fine again.
        assert_eq!(validate_chain(&dir, 2, 10).unwrap().len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn validate_chain_rejects_step_discontinuity() {
        let dir = tmp("steps");
        write_delta(&dir, 1, 5, 0);
        // Seq is contiguous but the step lineage is not: delta 2 claims
        // to apply on top of step 7, the chain is at step 5.
        write_delta(&dir, 2, 12, 7);
        let err = validate_chain(&dir, 0, 0).unwrap_err().to_string();
        assert!(err.contains("step"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corrupt_delta_errors() {
        let dir = tmp("bad");
        let ddir = delta_dir(&dir, 2);
        std::fs::create_dir_all(&ddir).unwrap();
        std::fs::write(sparse_delta_path(&dir, 2, 0, 1), [0u8; 4]).unwrap();
        let m = meta(2, 1);
        assert!(load_delta_shard(&dir, &m, 0).is_err());
        assert!(load_delta_meta(&dir, 2).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
