//! Checkpoint save/resume with world-size resharding (§5.2).
//!
//! "MTGRBoost implements a novel approach where each device independently
//! preserves its own checkpoint. During loading, new devices locate
//! required checkpoint files through modulo operations. For instance,
//! when loading checkpoints saved from 8 GPUs onto 16 GPUs, both GPU 0
//! and GPU 8 load parameters from the checkpoint saved on the original
//! GPU 0. This design is grounded in the insight that distributed
//! cluster scaling typically follows powers of two."
//!
//! Layout:
//! ```text
//! <dir>/meta.json                 world, step, model, dim, param_count
//! <dir>/dense.bin                 params f32[P] ++ DenseAdam state (rank 0 writes)
//! <dir>/sparse_rank<r>_of<n>.bin  rows owned by rank r: per row
//!                                 id u64 | row f32[d] | m f32[d] | v f32[d] | t u64
//! ```
//!
//! Sharding uses `shard_owner(id, world) = hash(id) % world` with
//! power-of-two worlds, so `hash % 2n` refines `hash % n`: a new rank
//! `r'` under world `n'` reads exactly the old files
//! `{r | r ≡ r' (mod min(n, n'))}` picked by [`files_to_read`], then
//! keeps the ids it now owns — no device ever scans the whole
//! checkpoint (the flaw the paper calls out in prior systems).
//!
//! Every binary file (sparse shards, delta shards, `dense.bin`) is
//! *sealed* with a trailing CRC-32 footer ([`crate::util::crc32`]):
//! loaders verify integrity before parsing, so truncation, torn writes
//! and bit rot are loud errors — the property the distributed
//! supervisor's recovery scan relies on to pick the last fully-valid
//! delta. `meta.json` stays plain JSON (human-inspectable; its parse
//! already rejects truncation).

pub mod delta;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::embedding::dynamic_table::DynamicEmbeddingTable;
use crate::embedding::precision::PrecisionPolicy;
use crate::embedding::sharded::shard_owner;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::optim::adam::{DenseAdam, RowState, SparseAdam};
use crate::util::json::Json;

/// Checkpoint metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    pub world: usize,
    pub step: u64,
    pub model: String,
    pub dim: usize,
    pub param_count: usize,
}

/// One sparse row as stored on disk.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseRow {
    pub id: GlobalId,
    pub row: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

/// Which old-world sparse files a new rank must read (the modulo rule).
/// Requires both world sizes to be powers of two (the paper's stated
/// scaling discipline); panics otherwise so misconfigurations surface
/// loudly.
pub fn files_to_read(old_world: usize, new_world: usize, new_rank: usize) -> Vec<usize> {
    assert!(
        old_world.is_power_of_two() && new_world.is_power_of_two(),
        "checkpoint resharding requires power-of-two world sizes \
         (got {old_world} -> {new_world})"
    );
    assert!(new_rank < new_world);
    if new_world >= old_world {
        // Scale-up: exactly one file (GPU 0 and GPU 8 both read old 0).
        vec![new_rank % old_world]
    } else {
        // Scale-down: all old ranks congruent to new_rank mod new_world.
        (0..old_world)
            .filter(|r| r % new_world == new_rank)
            .collect()
    }
}

fn meta_path(dir: &Path) -> std::path::PathBuf {
    dir.join("meta.json")
}

/// Write `bytes` to `path` with the CRC-32 integrity footer appended.
pub(crate) fn write_sealed(path: &Path, bytes: Vec<u8>) -> Result<()> {
    std::fs::write(path, crate::util::crc32::seal(bytes))
        .with_context(|| format!("write {}", path.display()))
}

/// Read `path`, verify its CRC-32 footer and return the payload.
pub(crate) fn read_sealed(path: &Path) -> Result<Vec<u8>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    crate::util::crc32::unseal_vec(bytes)
        .with_context(|| format!("integrity check failed for {}", path.display()))
}

/// Verify the CRC-32 footer of `path` without keeping the payload —
/// the supervisor's recovery scan uses this to decide whether a delta
/// snapshot survived a crash intact.
pub fn verify_sealed(path: &Path) -> Result<()> {
    read_sealed(path).map(|_| ())
}

fn sparse_path(dir: &Path, rank: usize, world: usize) -> std::path::PathBuf {
    dir.join(format!("sparse_rank{rank:05}_of{world}.bin"))
}

/// Merge group `group`'s sparse shard file (group 0 keeps the
/// historical single-group name, so homogeneous checkpoints are
/// byte-identical to pre-multi-group builds).
pub(crate) fn sparse_group_path(
    dir: &Path,
    rank: usize,
    world: usize,
    group: usize,
) -> std::path::PathBuf {
    if group == 0 {
        sparse_path(dir, rank, world)
    } else {
        dir.join(format!("sparse_rank{rank:05}_of{world}_g{group}.bin"))
    }
}

/// Parse the optional `group_dims` key of a checkpoint/delta meta JSON;
/// absent (historical single-group snapshots) ⇒ `[default_dim]`.
pub(crate) fn parse_group_dims(j: &Json, default_dim: usize) -> Result<Vec<usize>> {
    match j.get("group_dims").as_arr() {
        None => Ok(vec![default_dim]),
        Some(arr) => {
            let mut dims = Vec::with_capacity(arr.len());
            for v in arr {
                dims.push(
                    v.as_usize()
                        .context("group_dims entries must be integers")?,
                );
            }
            anyhow::ensure!(!dims.is_empty(), "group_dims must not be empty");
            Ok(dims)
        }
    }
}

/// Append the optional mixed-precision keys to a snapshot meta JSON.
/// fp32 snapshots never write them — the same absent-key discipline as
/// `group_dims` — so fp32 meta files stay byte-identical to pre-policy
/// builds. The policy is uniform across merge groups (the trainer
/// installs one `--precision`/`--hot-threshold` pair for every group),
/// so scalar keys suffice.
pub(crate) fn set_precision_keys(j: &mut Json, policy: PrecisionPolicy) {
    if policy.enabled {
        j.set("precision", "mixed".into());
        j.set("hot_threshold", (policy.hot_threshold as usize).into());
    }
}

/// Parse the optional precision keys of a checkpoint/delta meta JSON;
/// absent (fp32 or historical snapshots) ⇒ the disabled policy. A
/// present-but-malformed key is a hard error, never a silent fp32
/// fallback — a replica that dropped the policy would misreport what
/// grid its cold rows live on.
pub(crate) fn parse_precision_keys(j: &Json) -> Result<PrecisionPolicy> {
    match j.get("precision") {
        Json::Null => Ok(PrecisionPolicy::fp32()),
        v => match v.as_str() {
            Some("fp32") => Ok(PrecisionPolicy::fp32()),
            Some("mixed") => {
                let t = j.expect_usize("hot_threshold")?;
                anyhow::ensure!(
                    (1..=u32::MAX as usize).contains(&t),
                    "snapshot meta: hot_threshold must be in 1..=u32::MAX, got {t}"
                );
                Ok(PrecisionPolicy::mixed(t as u32))
            }
            _ => bail!("snapshot meta: invalid `precision` (expected \"fp32\"|\"mixed\")"),
        },
    }
}

/// Precision policy recorded in the checkpoint at `dir` (the disabled
/// fp32 policy for snapshots that never wrote the keys).
pub fn load_precision_policy(dir: &Path) -> Result<PrecisionPolicy> {
    let text = std::fs::read_to_string(meta_path(dir))
        .with_context(|| format!("no checkpoint at {}", dir.display()))?;
    let j = Json::parse(&text).context("parse checkpoint meta")?;
    parse_precision_keys(&j)
}

/// Per-group dims of the checkpoint at `dir` (`[meta.dim]` when the
/// snapshot predates multi-group or has one group).
pub fn load_group_dims(dir: &Path, meta: &CheckpointMeta) -> Result<Vec<usize>> {
    let text = std::fs::read_to_string(meta_path(dir))
        .with_context(|| format!("no checkpoint at {}", dir.display()))?;
    let j = Json::parse(&text).context("parse checkpoint meta")?;
    parse_group_dims(&j, meta.dim)
}

/// Save one rank's checkpoint shard. Rank 0 additionally writes the
/// metadata and the replicated dense parameters + optimizer state.
pub fn save(
    dir: &Path,
    meta: &CheckpointMeta,
    rank: usize,
    dense: Option<(&[f32], &DenseAdam)>,
    table: &DynamicEmbeddingTable,
    opt: &SparseAdam,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let d = table.dim();
    anyhow::ensure!(d == meta.dim, "table dim != meta dim");

    if rank == 0 {
        let (params, adam) =
            dense.context("rank 0 must provide the dense params + optimizer")?;
        anyhow::ensure!(params.len() == meta.param_count, "params arity");
        let mut j = Json::obj();
        j.set("world", meta.world.into());
        j.set("step", (meta.step as usize).into());
        j.set("model", meta.model.as_str().into());
        j.set("dim", meta.dim.into());
        j.set("param_count", meta.param_count.into());
        std::fs::write(meta_path(dir), j.pretty())?;
        write_dense_bin(dir, params, adam)?;
    }

    // Sparse shard: every live row this rank owns, with optimizer state
    // (zeros when the row was never updated).
    let zero = RowState {
        m: vec![0.0; d],
        v: vec![0.0; d],
        t: 0,
    };
    let mut count = 0u64;
    let mut body = Vec::new();
    for (id, row) in table.iter_rows() {
        let st = opt.row_state(id).unwrap_or(&zero);
        push_row_bytes(&mut body, id, row, &st.m, &st.v, st.t);
        count += 1;
    }
    write_sealed(
        &sparse_path(dir, rank, meta.world),
        rows_block_bytes(count, d, &body),
    )?;
    Ok(())
}

/// Serialize one sparse row (id | row | m | v | t, all little-endian)
/// onto `body` — the wire format shared by full checkpoints and delta
/// snapshots.
pub(crate) fn push_row_bytes(
    body: &mut Vec<u8>,
    id: GlobalId,
    row: &[f32],
    m: &[f32],
    v: &[f32],
    t: u64,
) {
    body.extend_from_slice(&id.to_le_bytes());
    for x in row.iter().chain(m.iter()).chain(v.iter()) {
        body.extend_from_slice(&x.to_le_bytes());
    }
    body.extend_from_slice(&t.to_le_bytes());
}

/// Frame a serialized row body with its `count | dim` header.
pub(crate) fn rows_block_bytes(count: u64, d: usize, body: &[u8]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(16 + body.len());
    bytes.extend_from_slice(&count.to_le_bytes());
    bytes.extend_from_slice(&(d as u64).to_le_bytes());
    bytes.extend_from_slice(body);
    bytes
}

/// Write `dense.bin` (replicated params + DenseAdam state).
pub(crate) fn write_dense_bin(dir: &Path, params: &[f32], adam: &DenseAdam) -> Result<()> {
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    bytes.extend_from_slice(&adam.state_bytes());
    write_sealed(&dir.join("dense.bin"), bytes)?;
    Ok(())
}

/// Read checkpoint metadata.
pub fn load_meta(dir: &Path) -> Result<CheckpointMeta> {
    let text = std::fs::read_to_string(meta_path(dir))
        .with_context(|| format!("no checkpoint at {}", dir.display()))?;
    let j = Json::parse(&text).context("parse checkpoint meta")?;
    Ok(CheckpointMeta {
        world: j.expect_usize("world")?,
        step: j.expect_usize("step")? as u64,
        model: j.expect_str("model")?.to_string(),
        dim: j.expect_usize("dim")?,
        param_count: j.expect_usize("param_count")?,
    })
}

/// Load the replicated dense parameters + optimizer state.
pub fn load_dense(dir: &Path, param_count: usize) -> Result<(Vec<f32>, Vec<u8>)> {
    let bytes = read_sealed(&dir.join("dense.bin")).context("read dense.bin")?;
    let p_bytes = param_count * 4;
    if bytes.len() < p_bytes {
        bail!("dense.bin truncated");
    }
    let params: Vec<f32> = bytes[..p_bytes]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((params, bytes[p_bytes..].to_vec()))
}

pub(crate) fn parse_sparse_file(bytes: &[u8]) -> Result<Vec<SparseRow>> {
    if bytes.len() < 16 {
        bail!("sparse shard truncated header");
    }
    let count = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    let d = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let row_bytes = 8 + 3 * d * 4 + 8;
    anyhow::ensure!(
        bytes.len() == 16 + count * row_bytes,
        "sparse shard size mismatch"
    );
    let mut rows = Vec::with_capacity(count);
    let mut off = 16;
    let read_f32s = |bytes: &[u8], off: usize, n: usize| -> Vec<f32> {
        bytes[off..off + n * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    for _ in 0..count {
        let id = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        off += 8;
        let row = read_f32s(bytes, off, d);
        off += d * 4;
        let m = read_f32s(bytes, off, d);
        off += d * 4;
        let v = read_f32s(bytes, off, d);
        off += d * 4;
        let t = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        off += 8;
        rows.push(SparseRow { id, row, m, v, t });
    }
    Ok(rows)
}

/// Load the sparse rows a new rank owns under the new world size,
/// reading only the modulo-selected files (merge group 0 — the
/// historical single-group layout).
pub fn load_sparse_shard(
    dir: &Path,
    meta: &CheckpointMeta,
    new_world: usize,
    new_rank: usize,
) -> Result<Vec<SparseRow>> {
    load_sparse_shard_group(dir, meta, new_world, new_rank, 0)
}

/// [`load_sparse_shard`] for merge group `group` of a multi-group
/// checkpoint — the same modulo-selected resharding, one physical table
/// per group.
pub fn load_sparse_shard_group(
    dir: &Path,
    meta: &CheckpointMeta,
    new_world: usize,
    new_rank: usize,
    group: usize,
) -> Result<Vec<SparseRow>> {
    let mut out = Vec::new();
    for old_rank in files_to_read(meta.world, new_world, new_rank) {
        let path = sparse_group_path(dir, old_rank, meta.world, group);
        let bytes = read_sealed(&path)?;
        for row in parse_sparse_file(&bytes)? {
            if shard_owner(row.id, new_world) == new_rank {
                out.push(row);
            }
        }
    }
    Ok(out)
}

/// Install loaded sparse rows into a table + optimizer (resume path).
pub fn install_rows(
    rows: Vec<SparseRow>,
    table: &mut DynamicEmbeddingTable,
    opt: &mut SparseAdam,
) {
    let d = table.dim();
    let mut buf = vec![0.0f32; d];
    for r in rows {
        table.lookup_or_insert(r.id, &mut buf);
        if let Some(slot) = table.row_mut(r.id) {
            slot.copy_from_slice(&r.row);
        }
        if r.t > 0 {
            opt.restore_row(
                r.id,
                RowState {
                    m: r.m,
                    v: r.v,
                    t: r.t,
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::dynamic_table::DynamicTableConfig;
    use crate::optim::adam::AdamParams;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "mtgr_ckpt_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn modulo_rule_matches_paper_example() {
        // Save on 8, load on 16: new GPU 0 and GPU 8 both read old 0.
        assert_eq!(files_to_read(8, 16, 0), vec![0]);
        assert_eq!(files_to_read(8, 16, 8), vec![0]);
        assert_eq!(files_to_read(8, 16, 11), vec![3]);
        // Same world: identity.
        assert_eq!(files_to_read(8, 8, 5), vec![5]);
        // Scale down 8 → 4: new rank 1 reads old {1, 5}.
        assert_eq!(files_to_read(8, 4, 1), vec![1, 5]);
        // Scale down to 1: rank 0 reads everything.
        assert_eq!(files_to_read(8, 1, 0), (0..8).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_world_rejected() {
        files_to_read(8, 6, 0);
    }

    #[test]
    fn modulo_rule_covers_every_id_exactly_once() {
        // For random ids: across all new ranks, each id owned by some
        // old rank is loaded exactly once.
        let mut rng = crate::util::rng::Xoshiro256::new(4);
        for &(old_w, new_w) in &[(4usize, 8usize), (8, 4), (8, 8), (2, 16), (16, 2)] {
            for _ in 0..200 {
                let id = rng.next_u64() >> 1;
                let old_owner = shard_owner(id, old_w);
                let mut loads = 0;
                for new_rank in 0..new_w {
                    let reads = files_to_read(old_w, new_w, new_rank);
                    if reads.contains(&old_owner) && shard_owner(id, new_w) == new_rank {
                        loads += 1;
                    }
                }
                assert_eq!(loads, 1, "id {id} old_w {old_w} new_w {new_w}");
            }
        }
    }

    fn build_world(world: usize, dim: usize, n_ids: u64) -> Vec<(DynamicEmbeddingTable, SparseAdam)> {
        let mut shards: Vec<(DynamicEmbeddingTable, SparseAdam)> = (0..world)
            .map(|_| {
                (
                    DynamicEmbeddingTable::new(
                        DynamicTableConfig::new(dim).with_capacity(64).with_seed(9),
                    ),
                    SparseAdam::new(dim, AdamParams::default()),
                )
            })
            .collect();
        let mut buf = vec![0.0f32; dim];
        for id in 0..n_ids {
            let owner = shard_owner(id, world);
            let (t, o) = &mut shards[owner];
            t.lookup_or_insert(id, &mut buf);
            // A couple of optimizer steps so state is nontrivial.
            let g: Vec<f32> = (0..dim).map(|j| 0.1 * (id + j as u64 + 1) as f32).collect();
            o.step(t, &[id], &g, 1.0);
            o.step(t, &[id], &g, 0.5);
        }
        shards
    }

    #[test]
    fn save_reshard_load_roundtrip_8_to_16_and_back() {
        let dim = 4;
        let dir = tmp("rt");
        let old_world = 4;
        let shards = build_world(old_world, dim, 300);

        // Reference content: id → row.
        let mut reference = std::collections::HashMap::new();
        for (t, _) in &shards {
            for (id, row) in t.iter_rows() {
                reference.insert(id, row.to_vec());
            }
        }

        let meta = CheckpointMeta {
            world: old_world,
            step: 77,
            model: "tiny".into(),
            dim,
            param_count: 3,
        };
        let params = [1.0f32, -2.0, 3.0];
        let dense_opt = DenseAdam::new(3, AdamParams::default());
        for (rank, (t, o)) in shards.iter().enumerate() {
            let dense = (rank == 0).then_some((&params[..], &dense_opt));
            save(&dir, &meta, rank, dense, t, o).unwrap();
        }

        for &new_world in &[8usize, 2, 4] {
            let meta2 = load_meta(&dir).unwrap();
            assert_eq!(meta2.step, 77);
            let (p, _state) = load_dense(&dir, meta2.param_count).unwrap();
            assert_eq!(p, params);

            let mut seen = std::collections::HashMap::new();
            for new_rank in 0..new_world {
                let rows = load_sparse_shard(&dir, &meta2, new_world, new_rank).unwrap();
                for r in rows {
                    assert_eq!(shard_owner(r.id, new_world), new_rank);
                    assert!(r.t > 0, "optimizer state preserved");
                    assert!(seen.insert(r.id, r.row).is_none(), "dup id {}", r.id);
                }
            }
            assert_eq!(seen.len(), reference.len(), "world {new_world}");
            for (id, row) in &reference {
                assert_eq!(seen.get(id).unwrap(), row, "id {id}");
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn install_rows_restores_table_and_optimizer() {
        let dim = 3;
        let dir = tmp("install");
        let shards = build_world(1, dim, 20);
        let meta = CheckpointMeta {
            world: 1,
            step: 1,
            model: "tiny".into(),
            dim,
            param_count: 1,
        };
        let dense_opt = DenseAdam::new(1, AdamParams::default());
        save(&dir, &meta, 0, Some((&[0.5], &dense_opt)), &shards[0].0, &shards[0].1).unwrap();

        let rows = load_sparse_shard(&dir, &meta, 1, 0).unwrap();
        let mut table = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(dim).with_capacity(64).with_seed(1234),
        );
        let mut opt = SparseAdam::new(dim, AdamParams::default());
        install_rows(rows, &mut table, &mut opt);

        assert_eq!(table.len(), shards[0].0.len());
        let mut a = vec![0.0; dim];
        let mut b = vec![0.0; dim];
        for (id, _) in shards[0].0.iter_rows() {
            shards[0].0.lookup(id, &mut a);
            assert!(table.lookup(id, &mut b));
            assert_eq!(a, b, "row {id} content restored despite different seed");
            assert!(opt.row_state(id).is_some());
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corrupt_files_error() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(sparse_path(&dir, 0, 1), [1u8; 10]).unwrap();
        let meta = CheckpointMeta {
            world: 1,
            step: 0,
            model: "x".into(),
            dim: 4,
            param_count: 0,
        };
        assert!(load_sparse_shard(&dir, &meta, 1, 0).is_err());
        assert!(load_meta(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    /// Satellite: fuzz the CRC seal — random byte flips and random
    /// truncations of real checkpoint files must all be loud load
    /// errors, never silently-wrong rows.
    #[test]
    fn fuzz_corruption_is_always_detected() {
        let dim = 4;
        let dir = tmp("fuzz");
        let shards = build_world(1, dim, 40);
        let meta = CheckpointMeta {
            world: 1,
            step: 5,
            model: "tiny".into(),
            dim,
            param_count: 2,
        };
        let dense_opt = DenseAdam::new(2, AdamParams::default());
        save(&dir, &meta, 0, Some((&[0.1, 0.2], &dense_opt)), &shards[0].0, &shards[0].1)
            .unwrap();

        // Both loaders succeed on the pristine files.
        assert!(load_sparse_shard(&dir, &meta, 1, 0).is_ok());
        assert!(load_dense(&dir, meta.param_count).is_ok());

        let mut rng = crate::util::rng::Xoshiro256::new(0xC0FFEE);
        for target in ["sparse", "dense"] {
            let path = match target {
                "sparse" => sparse_path(&dir, 0, 1),
                _ => dir.join("dense.bin"),
            };
            let pristine = std::fs::read(&path).unwrap();
            assert!(pristine.len() > 16);
            for trial in 0..60 {
                let mut bad = pristine.clone();
                if trial % 3 == 2 {
                    // Random truncation (torn write).
                    let keep = (rng.next_u64() as usize) % bad.len();
                    bad.truncate(keep);
                } else {
                    // Random single-byte corruption.
                    let pos = (rng.next_u64() as usize) % bad.len();
                    let flip = (rng.next_u64() % 255 + 1) as u8;
                    bad[pos] ^= flip;
                }
                std::fs::write(&path, &bad).unwrap();
                let res = match target {
                    "sparse" => load_sparse_shard(&dir, &meta, 1, 0).map(|_| ()),
                    _ => load_dense(&dir, meta.param_count).map(|_| ()),
                };
                assert!(
                    res.is_err(),
                    "{target} trial {trial}: corruption of {} -> {} bytes went undetected",
                    pristine.len(),
                    bad.len()
                );
            }
            std::fs::write(&path, &pristine).unwrap();
        }
        std::fs::remove_dir_all(dir).ok();
    }
}
