//! Unbounded streaming data source for online learning.
//!
//! Offline runs pull a fixed number of steps from the generator; an
//! online learner consumes an **endless, time-stamped** stream in which
//! new feature IDs keep arriving (new users sign up, merchants rotate
//! menus). [`StreamingSource`] adapts [`WorkloadGenerator`] into that
//! shape: a background producer (the same drop-joined
//! [`Prefetcher`] the offline path uses, so I/O masking and stream
//! order are identical) emits [`StreamChunk`]s forever, advancing the
//! generator's *day* every `day_every` chunks so each day mints a fresh
//! slice of the ID space — the workload that exercises feature
//! admission and TTL expiry.
//!
//! The stream is a pure function of `(GeneratorConfig, chunk_size,
//! day_every)`: chunk `k` has stamp `k` and identical contents on every
//! replay, so online runs stay bit-reproducible.

use crate::data::generator::{GeneratorConfig, WorkloadGenerator};
use crate::data::prefetch::Prefetcher;
use crate::data::schema::{Schema, Sequence};

/// One time-stamped slice of the endless stream.
#[derive(Clone, Debug)]
pub struct StreamChunk {
    /// Logical arrival stamp (chunk index since stream start).
    pub stamp: u64,
    /// Generator day the chunk was drawn from.
    pub day: u64,
    pub sequences: Vec<Sequence>,
}

/// Endless prefetched sequence stream with day-driven ID arrival.
pub struct StreamingSource {
    prefetch: Prefetcher<StreamChunk>,
}

impl StreamingSource {
    /// Spawn the producer. `day_every == 0` never advances the day —
    /// the stream is then byte-identical to the offline generator path
    /// (the trainer uses that setting for `--mode offline`).
    pub fn spawn(
        cfg: GeneratorConfig,
        schema: Schema,
        chunk_size: usize,
        depth: usize,
        day_every: usize,
    ) -> Self {
        assert!(chunk_size >= 1);
        let mut gen = WorkloadGenerator::new(cfg);
        let mut stamp = 0u64;
        let prefetch = Prefetcher::spawn(depth.max(1), move || {
            if day_every > 0 && stamp > 0 && stamp % day_every as u64 == 0 {
                gen.advance_day();
            }
            let chunk = StreamChunk {
                stamp,
                day: gen.day(),
                sequences: gen.batch(&schema, chunk_size),
            };
            stamp += 1;
            Some(chunk)
        });
        StreamingSource { prefetch }
    }

    /// Blocking fetch of the next chunk (the stream never ends).
    pub fn next_chunk(&mut self) -> StreamChunk {
        self.prefetch.next().expect("streaming source is endless")
    }

    /// Mean prefetch-queue occupancy observed at fetch time.
    pub fn depth_occupancy(&self) -> f64 {
        self.prefetch.depth_occupancy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GeneratorConfig {
        GeneratorConfig {
            len_mu: 2.0,
            len_sigma: 0.4,
            min_len: 2,
            max_len: 20,
            num_users: 200,
            num_items: 100,
            new_user_rate: 0.5,
            new_item_rate: 0.5,
            ..Default::default()
        }
    }

    #[test]
    fn stamps_are_sequential_and_replays_are_identical() {
        let schema = Schema::meituan_like(4, 1);
        let mut a = StreamingSource::spawn(cfg(), schema.clone(), 8, 2, 4);
        let mut b = StreamingSource::spawn(cfg(), schema, 8, 2, 4);
        for k in 0..12u64 {
            let ca = a.next_chunk();
            let cb = b.next_chunk();
            assert_eq!(ca.stamp, k);
            assert_eq!(ca.stamp, cb.stamp);
            assert_eq!(ca.day, cb.day);
            assert_eq!(ca.sequences, cb.sequences, "chunk {k} must replay exactly");
        }
    }

    #[test]
    fn days_advance_and_mint_new_ids() {
        let schema = Schema::meituan_like(4, 1);
        let base_users = cfg().num_users;
        let mut s = StreamingSource::spawn(cfg(), schema, 16, 2, 2);
        let mut max_day = 0;
        let mut saw_new = false;
        for _ in 0..20 {
            let c = s.next_chunk();
            max_day = max_day.max(c.day);
            if c.sequences.iter().any(|q| q.user_id >= base_users) {
                saw_new = true;
            }
        }
        assert!(max_day >= 5, "day must advance every 2 chunks: {max_day}");
        assert!(saw_new, "later days must mint new user ids");
    }

    #[test]
    fn day_every_zero_matches_plain_generator() {
        let schema = Schema::meituan_like(4, 1);
        let mut s = StreamingSource::spawn(cfg(), schema.clone(), 8, 2, 0);
        let mut gen = WorkloadGenerator::new(cfg());
        for k in 0..6 {
            let c = s.next_chunk();
            assert_eq!(c.day, 0);
            assert_eq!(
                c.sequences,
                gen.batch(&schema, 8),
                "chunk {k}: stream must equal the offline generator path"
            );
        }
    }
}
