//! Feature admission for streaming training (Monolith-style
//! probabilistic/frequency filtering).
//!
//! Production ID streams are dominated by a long tail of IDs that occur
//! once or twice and never again; allocating an embedding row (plus
//! Adam state) for each would blow the memory budget without moving the
//! loss. [`FeatureAdmission`] keeps a seeded **count-min sketch** of
//! how often each not-yet-admitted ID has been requested and admits a
//! row only when
//!
//! 1. the estimated count reaches `threshold` (frequency filtering), or
//! 2. a deterministic per-(seed, id, count) lottery fires with
//!    probability `admit_prob` (probabilistic filtering — lets a sample
//!    of the tail through so brand-new hot IDs are not starved for
//!    `threshold` steps).
//!
//! **Determinism contract**: [`FeatureAdmission::decide`] is a pure
//! function of `(seed, id, count)`, and the sketch state is a pure
//! function of the observation sequence. The trainer only observes IDs
//! from a serial pre-pass in server-side occurrence order, so admission
//! decisions — and therefore the entire online run — are bit-identical
//! across `--threads` values.

use crate::embedding::hash::hash_id;
use crate::embedding::GlobalId;

/// Salt mixed into the probabilistic-admission lottery hash.
const LOTTERY_SEED: u64 = 0xAD317_10;

/// Configuration for [`FeatureAdmission`].
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Estimated occurrence count at which an ID is admitted
    /// unconditionally. `1` admits on first sight (filtering
    /// effectively off).
    pub threshold: u32,
    /// Probability (per observation) that a below-threshold ID is
    /// admitted anyway; `0.0` disables the lottery.
    pub admit_prob: f64,
    /// Counters per sketch row.
    pub sketch_width: usize,
    /// Independent sketch rows (the count-min estimate is their min).
    pub sketch_depth: usize,
    /// Seed for both the sketch hashes and the admission lottery.
    pub seed: u64,
    /// Halve every sketch counter when the stream's day advances
    /// ([`FeatureAdmission::advance_day`]): yesterday's flash-sale
    /// counts stop vouching for today's IDs. Off by default (the
    /// historical behavior — counts accumulate forever).
    pub day_decay: bool,
    /// Re-admission hysteresis: an ID the TTL sweeper retired
    /// ([`FeatureAdmission::note_retired`]) must reach
    /// `threshold + readmit_margin` before re-admission, so an ID
    /// oscillating around the threshold doesn't thrash
    /// allocate/evict/allocate. `0` disables hysteresis.
    pub readmit_margin: u32,
}

impl AdmissionConfig {
    pub fn new(threshold: u32, admit_prob: f64) -> Self {
        AdmissionConfig {
            threshold,
            admit_prob,
            sketch_width: 1 << 14,
            sketch_depth: 4,
            seed: 0xAD317,
            day_decay: false,
            readmit_margin: 0,
        }
    }

    pub fn with_day_decay(mut self, on: bool) -> Self {
        self.day_decay = on;
        self
    }

    pub fn with_readmit_margin(mut self, margin: u32) -> Self {
        self.readmit_margin = margin;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.threshold >= 1, "--admit-threshold must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.admit_prob),
            "--admit-prob must be in [0, 1], got {}",
            self.admit_prob
        );
        anyhow::ensure!(self.sketch_width >= 1, "sketch width must be >= 1");
        anyhow::ensure!(
            (1..=8).contains(&self.sketch_depth),
            "sketch depth must be in 1..=8"
        );
        Ok(())
    }
}

/// Count-min-sketch frequency filter with a deterministic admission
/// lottery. See the module docs for the policy and the determinism
/// contract.
#[derive(Clone, Debug)]
pub struct FeatureAdmission {
    cfg: AdmissionConfig,
    /// `sketch_depth` rows of `sketch_width` counters, row-major.
    counters: Vec<u32>,
    /// Observations that ended in admission / rejection (cumulative).
    admitted: u64,
    rejected: u64,
    /// IDs the TTL sweeper retired; they face the hysteresis margin
    /// until re-admitted. Empty unless `readmit_margin > 0`.
    retired: std::collections::HashSet<GlobalId>,
    /// Days observed via [`FeatureAdmission::advance_day`].
    days: u64,
}

impl FeatureAdmission {
    pub fn new(cfg: AdmissionConfig) -> Self {
        let cells = cfg.sketch_width * cfg.sketch_depth;
        FeatureAdmission {
            counters: vec![0; cells],
            admitted: 0,
            rejected: 0,
            retired: std::collections::HashSet::new(),
            days: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// The stream's day advanced. With `day_decay` every sketch
    /// counter is halved — exponential decay at day granularity, so a
    /// flash-sale ID that vanished stops looking hot after a couple of
    /// days. Deterministic (pure state transform, no RNG).
    pub fn advance_day(&mut self) {
        self.days += 1;
        if self.cfg.day_decay {
            for c in &mut self.counters {
                *c >>= 1;
            }
        }
    }

    /// Days seen so far.
    pub fn days(&self) -> u64 {
        self.days
    }

    /// The TTL sweeper retired `id`'s row: with hysteresis on, future
    /// re-admission needs `threshold + readmit_margin`. No-op when the
    /// margin is 0 (keeps the legacy memory profile).
    pub fn note_retired(&mut self, id: GlobalId) {
        if self.cfg.readmit_margin > 0 {
            self.retired.insert(id);
        }
    }

    /// Read-only count-min estimate for `id` (min over its cells).
    pub fn estimate(&self, id: GlobalId) -> u32 {
        let w = self.cfg.sketch_width as u64;
        let depth = self.cfg.sketch_depth.min(8);
        let mut est = u32::MAX;
        for d in 0..depth {
            let h = hash_id(id, self.cfg.seed ^ (d as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            est = est.min(self.counters[d * self.cfg.sketch_width + (h % w) as usize]);
        }
        est
    }

    /// The pure admission decision for an ID whose estimated count just
    /// reached `count`: admit at the threshold, else run the seeded
    /// lottery. Depends on nothing but the three arguments (plus the
    /// configured probability), so replays are exact.
    pub fn decide(seed: u64, id: GlobalId, count: u32, threshold: u32, admit_prob: f64) -> bool {
        if count >= threshold {
            return true;
        }
        if admit_prob <= 0.0 {
            return false;
        }
        // 53 uniform bits from the (seed, id, count) hash → [0, 1).
        let h = hash_id(id, seed ^ LOTTERY_SEED ^ ((count as u64) << 32)) >> 11;
        (h as f64) < admit_prob * (1u64 << 53) as f64
    }

    /// Record one observation of `id` and return whether it is admitted
    /// now. Counting uses conservative-update count-min: only the
    /// minimal cells are bumped, tightening the estimate under skew.
    pub fn observe(&mut self, id: GlobalId) -> bool {
        let w = self.cfg.sketch_width as u64;
        let mut est = u32::MAX;
        let mut cells = [0usize; 8];
        let depth = self.cfg.sketch_depth.min(8);
        for d in 0..depth {
            let h = hash_id(id, self.cfg.seed ^ (d as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let idx = d * self.cfg.sketch_width + (h % w) as usize;
            cells[d] = idx;
            est = est.min(self.counters[idx]);
        }
        let count = est.saturating_add(1);
        for &idx in cells.iter().take(depth) {
            if self.counters[idx] < count {
                self.counters[idx] = count;
            }
        }
        // Retired IDs face the hysteresis margin on top of the base
        // threshold (the lottery still uses the effective threshold's
        // decision, keeping `decide` pure).
        let threshold = if self.cfg.readmit_margin > 0 && self.retired.contains(&id) {
            self.cfg.threshold.saturating_add(self.cfg.readmit_margin)
        } else {
            self.cfg.threshold
        };
        let admit = Self::decide(self.cfg.seed, id, count, threshold, self.cfg.admit_prob);
        if admit {
            self.admitted += 1;
            if self.cfg.readmit_margin > 0 {
                self.retired.remove(&id);
            }
        } else {
            self.rejected += 1;
        }
        admit
    }

    /// Cumulative (admitted, rejected) observation counts.
    pub fn totals(&self) -> (u64, u64) {
        (self.admitted, self.rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_admits_at_exact_count() {
        let mut a = FeatureAdmission::new(AdmissionConfig::new(3, 0.0));
        assert!(!a.observe(42), "count 1 < 3");
        assert!(!a.observe(42), "count 2 < 3");
        assert!(a.observe(42), "count 3 admits");
        assert!(a.observe(42), "stays admitted");
        assert_eq!(a.totals(), (2, 2));
    }

    #[test]
    fn threshold_one_admits_everything() {
        let mut a = FeatureAdmission::new(AdmissionConfig::new(1, 0.0));
        for id in 0..100u64 {
            assert!(a.observe(id));
        }
        assert_eq!(a.totals(), (100, 0));
    }

    #[test]
    fn one_shot_ids_rejected_without_lottery() {
        let mut a = FeatureAdmission::new(AdmissionConfig::new(2, 0.0));
        for id in 0..1000u64 {
            assert!(!a.observe(id), "one-shot id {id} must not allocate");
        }
        assert_eq!(a.totals(), (0, 1000));
    }

    #[test]
    fn decide_is_pure_and_seed_sensitive() {
        for id in 0..200u64 {
            for count in 1..4u32 {
                let a = FeatureAdmission::decide(7, id, count, 10, 0.25);
                let b = FeatureAdmission::decide(7, id, count, 10, 0.25);
                assert_eq!(a, b, "pure function of (seed, id, count)");
            }
        }
        // Different seeds must flip at least one decision.
        let flips = (0..500u64)
            .filter(|&id| {
                FeatureAdmission::decide(1, id, 1, 10, 0.3)
                    != FeatureAdmission::decide(2, id, 1, 10, 0.3)
            })
            .count();
        assert!(flips > 0, "lottery must depend on the seed");
    }

    #[test]
    fn lottery_rate_roughly_matches_probability() {
        let n = 20_000u64;
        let hits = (0..n)
            .filter(|&id| FeatureAdmission::decide(99, id, 1, u32::MAX, 0.2))
            .count() as f64;
        let rate = hits / n as f64;
        assert!((rate - 0.2).abs() < 0.02, "lottery rate {rate:.3} vs 0.2");
    }

    #[test]
    fn identical_observation_sequences_are_bit_identical() {
        let seq: Vec<u64> = (0..5000).map(|i| (i * i + 3) % 700).collect();
        let mut a = FeatureAdmission::new(AdmissionConfig::new(3, 0.1));
        let mut b = FeatureAdmission::new(AdmissionConfig::new(3, 0.1));
        let da: Vec<bool> = seq.iter().map(|&id| a.observe(id)).collect();
        let db: Vec<bool> = seq.iter().map(|&id| b.observe(id)).collect();
        assert_eq!(da, db);
        assert_eq!(a.totals(), b.totals());
    }

    #[test]
    fn day_decay_halves_counts_across_days() {
        // Without decay: 2 observations on day 0 + 1 on day 1 reach a
        // threshold of 3. With decay the day boundary halves the count
        // (2 → 1), so the same sequence stays below threshold.
        let mut plain = FeatureAdmission::new(AdmissionConfig::new(3, 0.0));
        let mut decay =
            FeatureAdmission::new(AdmissionConfig::new(3, 0.0).with_day_decay(true));
        for a in [&mut plain, &mut decay] {
            assert!(!a.observe(42));
            assert!(!a.observe(42));
            a.advance_day();
        }
        assert_eq!(plain.estimate(42), 2, "no decay: count survives the day");
        assert_eq!(decay.estimate(42), 1, "decay: count halved");
        assert!(plain.observe(42), "3rd observation admits without decay");
        assert!(!decay.observe(42), "decayed count 1+1=2 < 3");
        assert!(decay.observe(42), "but one more observation admits");
        assert_eq!(decay.days(), 1);
    }

    #[test]
    fn decay_is_deterministic() {
        let cfg = AdmissionConfig::new(4, 0.1).with_day_decay(true);
        let seq: Vec<u64> = (0..3000).map(|i| (i * 7 + 1) % 400).collect();
        let run = |cfg: AdmissionConfig| {
            let mut a = FeatureAdmission::new(cfg);
            let mut decisions = Vec::new();
            for (i, &id) in seq.iter().enumerate() {
                if i % 500 == 499 {
                    a.advance_day();
                }
                decisions.push(a.observe(id));
            }
            (decisions, a.totals())
        };
        assert_eq!(run(cfg.clone()), run(cfg));
    }

    #[test]
    fn readmission_hysteresis_raises_the_bar_once() {
        let mut a =
            FeatureAdmission::new(AdmissionConfig::new(2, 0.0).with_readmit_margin(2));
        assert!(!a.observe(9), "count 1 < 2");
        assert!(a.observe(9), "count 2 admits");
        // The sweeper retires the row: effective threshold is now 4.
        a.note_retired(9);
        assert!(!a.observe(9), "count 3 < 2+2 margin");
        assert!(a.observe(9), "count 4 re-admits");
        // Re-admission clears the hysteresis: back to the base bar.
        assert!(a.observe(9), "count 5 >= 2, no margin anymore");
    }

    #[test]
    fn zero_margin_keeps_legacy_behavior() {
        let mut a = FeatureAdmission::new(AdmissionConfig::new(2, 0.0));
        assert!(!a.observe(5));
        assert!(a.observe(5));
        a.note_retired(5); // no-op with margin 0
        assert!(a.observe(5), "retirement without margin changes nothing");
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        assert!(AdmissionConfig::new(0, 0.0).validate().is_err());
        assert!(AdmissionConfig::new(1, -0.1).validate().is_err());
        assert!(AdmissionConfig::new(1, 1.5).validate().is_err());
        assert!(AdmissionConfig::new(2, 0.5).validate().is_ok());
    }
}
