//! The online feature gate: admission, touch/TTL bookkeeping and delta
//! tracking layered over a [`ConcurrentDynamicTable`].
//!
//! [`OnlineTable`] implements [`EmbeddingStore`] so it drops into
//! [`crate::embedding::sharded::ShardedEmbedding`] unchanged. In
//! **passthrough** mode (offline training) every call delegates
//! directly to the inner table — byte-for-byte the pre-online behavior.
//! In **online** mode the training-time fetch runs a *serial* pre-pass
//! over the served occurrence stream that
//!
//! 1. consults [`FeatureAdmission`] for IDs not yet resident (rejected
//!    IDs are served the default row and never allocate),
//! 2. stamps every admitted ID's `last_touch` with the current step
//!    (the TTL input), and
//! 3. records the ID in the [`DeltaTracker`] (it is being trained on,
//!    so its bits are about to change).
//!
//! The pre-pass is serial and in occurrence order, so its decisions —
//! and everything downstream — are identical for every `--threads`
//! value; the actual row fetch then fans out through the inner table's
//! stripe-bucketed masked path.
//!
//! The sparse optimizer writes through the [`ConcurrentEmbeddingStore`]
//! delegation (disjoint rows, pool-parallel); because that path cannot
//! observe `&mut self`, the trainer marks the updated ids explicitly
//! via [`OnlineTable::mark_updated`] right after the optimizer applies
//! — a serial pass over the already-unique id list.
//!
//! Under a heterogeneous schema the trainer instantiates **one gate per
//! merge group** (each with its own admission sketch, touch map and
//! delta tracker over its own group table). The online knobs —
//! admission config, TTL, sync cadence — are global options applied
//! uniformly to every gate; global IDs are unique across groups
//! ([`crate::embedding::merge::GlobalIdCodec`]), so per-group sketches
//! never alias each other's ids.

use crate::embedding::concurrent::ConcurrentDynamicTable;
use crate::embedding::dedup::IdMap;
use crate::embedding::{ConcurrentEmbeddingStore, EmbeddingStore, GlobalId};
use crate::online::admission::FeatureAdmission;
use crate::online::delta::DeltaTracker;
use crate::optim::adam::SparseAdam;
use crate::util::pool::WorkerPool;

/// Admission + TTL + delta gate over a concurrent shard table.
pub struct OnlineTable {
    inner: ConcurrentDynamicTable,
    /// Online bookkeeping on/off; `false` = pure passthrough.
    tracking: bool,
    admission: Option<FeatureAdmission>,
    /// Current training step (the TTL clock), set by the trainer.
    clock: u64,
    /// Per-id last step the row was trained on.
    last_touch: IdMap<u64>,
    delta: DeltaTracker,
    /// Rows retired by TTL sweeps (cumulative).
    expired: u64,
    /// Reusable admission-mask buffer for the gated fetch (serve_reply
    /// fetches several times per micro round; no steady-state allocs).
    mask_scratch: Vec<bool>,
}

impl OnlineTable {
    /// Offline passthrough: no admission, no bookkeeping.
    pub fn passthrough(inner: ConcurrentDynamicTable) -> Self {
        OnlineTable {
            inner,
            tracking: false,
            admission: None,
            clock: 0,
            last_touch: IdMap::default(),
            delta: DeltaTracker::new(),
            expired: 0,
            mask_scratch: Vec::new(),
        }
    }

    /// Online mode: track touches/deltas; `admission` optionally gates
    /// new-row allocation.
    ///
    /// Panics if `inner` has a row budget: budgeted tables auto-evict
    /// *inside* `lookup_or_insert`, invisible to the tracker, which
    /// would silently break the base+deltas reconstruction contract.
    /// Online residency is bounded by admission + TTL instead.
    pub fn online(inner: ConcurrentDynamicTable, admission: Option<FeatureAdmission>) -> Self {
        assert!(
            !inner.has_row_budget(),
            "OnlineTable cannot track a row-budgeted table (hidden auto-evictions \
             would corrupt delta sync); bound residency with admission + TTL instead"
        );
        OnlineTable {
            inner,
            tracking: true,
            admission,
            clock: 0,
            last_touch: IdMap::default(),
            delta: DeltaTracker::new(),
            expired: 0,
            mask_scratch: Vec::new(),
        }
    }

    pub fn inner(&self) -> &ConcurrentDynamicTable {
        &self.inner
    }

    pub fn tracking(&self) -> bool {
        self.tracking
    }

    /// Set the TTL clock (the trainer calls this at the top of every
    /// step).
    pub fn set_step(&mut self, step: u64) {
        self.clock = step;
    }

    pub fn step(&self) -> u64 {
        self.clock
    }

    /// Cumulative (admitted, rejected) admission observations; (0, 0)
    /// when admission is off.
    pub fn admission_totals(&self) -> (u64, u64) {
        self.admission.as_ref().map_or((0, 0), |a| a.totals())
    }

    /// Rows retired by TTL sweeps so far.
    pub fn expired_total(&self) -> u64 {
        self.expired
    }

    /// The stream's day advanced: decay the admission sketch (when the
    /// scenario enabled `day_decay`). No-op in passthrough mode or
    /// without admission.
    pub fn advance_day(&mut self) {
        if let Some(a) = &mut self.admission {
            a.advance_day();
        }
    }

    /// Admission decision + bookkeeping for one training-time
    /// occurrence of `id`. Serial by construction (`&mut self`).
    fn admit_and_touch(&mut self, id: GlobalId) -> bool {
        let admit = match &mut self.admission {
            // Resident rows were admitted in the past; only new rows
            // consult (and count toward) the frequency filter.
            Some(a) => self.inner.contains(id) || a.observe(id),
            None => true,
        };
        if admit {
            self.last_touch.insert(id, self.clock);
            self.delta.upsert(id);
        }
        admit
    }

    /// Record optimizer updates for `ids` (already applied to the inner
    /// table through the concurrent delegation). No-op in passthrough
    /// mode, so the offline trainer can call it unconditionally.
    pub fn mark_updated(&mut self, ids: &[GlobalId]) {
        if !self.tracking {
            return;
        }
        for &id in ids {
            self.last_touch.insert(id, self.clock);
            self.delta.upsert(id);
        }
    }

    /// Remove one row (manual eviction), recording it for the next
    /// delta and dropping its optimizer state. Returns whether the row
    /// existed.
    pub fn remove_row(&mut self, id: GlobalId, opt: &mut SparseAdam) -> bool {
        let existed = self.inner.remove(id);
        opt.drop_row(id);
        self.last_touch.remove(&id);
        if self.tracking && existed {
            self.delta.remove(id);
        }
        existed
    }

    /// Retire every row untouched for at least `ttl` steps: a row last
    /// trained on at step `t` expires once `clock - t >= ttl`, so a row
    /// touched in the current step can never expire (`ttl >= 1`).
    /// Expired ids are processed in ascending order (determinism), each
    /// removal riding the inner table's striped write path; optimizer
    /// state is dropped alongside and the removal lands in the delta.
    /// Returns how many rows were retired.
    pub fn sweep_expired(&mut self, ttl: u64, opt: &mut SparseAdam) -> usize {
        if !self.tracking || ttl == 0 {
            return 0;
        }
        let now = self.clock;
        let mut expired: Vec<GlobalId> = Vec::new();
        for (&id, &t) in self.last_touch.iter() {
            if now.saturating_sub(t) >= ttl {
                expired.push(id);
            }
        }
        expired.sort_unstable();
        for &id in &expired {
            // One audited removal path: table row + optimizer state +
            // touch stamp + delta record all retire together.
            self.remove_row(id, opt);
            // Re-admission hysteresis: the sketch remembers the
            // retirement so the id must out-earn the margin to return.
            if let Some(a) = &mut self.admission {
                a.note_retired(id);
            }
        }
        self.expired += expired.len() as u64;
        expired.len()
    }

    /// Drain the rows changed since the last sync:
    /// `(upserted_ids, removed_ids)`, both sorted ascending.
    pub fn take_delta(&mut self) -> (Vec<GlobalId>, Vec<GlobalId>) {
        self.delta.take()
    }

    /// Rows pending in the next delta (upserts).
    pub fn pending_upserts(&self) -> usize {
        self.delta.pending_upserts()
    }
}

impl EmbeddingStore for OnlineTable {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len(&self) -> usize {
        ConcurrentDynamicTable::len(&self.inner)
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        if !self.tracking {
            return ConcurrentDynamicTable::lookup_or_insert(&self.inner, id, out);
        }
        if self.admit_and_touch(id) {
            ConcurrentDynamicTable::lookup_or_insert(&self.inner, id, out)
        } else {
            ConcurrentDynamicTable::lookup(&self.inner, id, out)
        }
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup(&self.inner, id, out)
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        let applied = ConcurrentDynamicTable::apply_delta(&self.inner, id, delta);
        if self.tracking && applied {
            self.last_touch.insert(id, self.clock);
            self.delta.upsert(id);
        }
        applied
    }

    fn fetch_rows(
        &mut self,
        ids: &[GlobalId],
        train: bool,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        if !self.tracking || !train {
            self.inner.fetch_rows_shared(ids, train, out, pool);
            return;
        }
        // Serial pre-pass in occurrence order: admission decisions,
        // touch stamps and delta records are identical for every pool
        // size; only the row fetch itself fans out.
        let mut admit = std::mem::take(&mut self.mask_scratch);
        admit.clear();
        admit.reserve(ids.len());
        for &id in ids {
            let a = self.admit_and_touch(id);
            admit.push(a);
        }
        self.inner.fetch_rows_masked(ids, &admit, out, pool);
        self.mask_scratch = admit;
    }

    fn memory_bytes(&self) -> usize {
        ConcurrentDynamicTable::memory_bytes(&self.inner)
    }

    // Precision composes underneath the admission gate: the policy
    // lives in the inner concurrent table and the gate just forwards
    // discovery, so precision × admission × per-group tables stack
    // without either layer knowing about the other.
    fn precision_policy(&self) -> crate::embedding::precision::PrecisionPolicy {
        self.inner.precision()
    }

    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        self.inner.row_is_hot(id)
    }
}

/// Shared-reference delegation so the pool-parallel sparse optimizer
/// ([`SparseAdam::step_concurrent`]) writes straight through to the
/// striped table. These writes bypass the tracker — the trainer calls
/// [`OnlineTable::mark_updated`] with the same id list immediately
/// after the optimizer applies.
impl ConcurrentEmbeddingStore for OnlineTable {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len(&self) -> usize {
        ConcurrentDynamicTable::len(&self.inner)
    }

    fn lookup_or_insert(&self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup_or_insert(&self.inner, id, out)
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup(&self.inner, id, out)
    }

    fn apply_delta(&self, id: GlobalId, delta: &[f32]) -> bool {
        ConcurrentDynamicTable::apply_delta(&self.inner, id, delta)
    }

    fn memory_bytes(&self) -> usize {
        ConcurrentDynamicTable::memory_bytes(&self.inner)
    }

    fn precision_policy(&self) -> crate::embedding::precision::PrecisionPolicy {
        self.inner.precision()
    }

    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        self.inner.row_is_hot(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::dynamic_table::DynamicTableConfig;
    use crate::online::admission::AdmissionConfig;
    use crate::optim::adam::AdamParams;

    const DIM: usize = 4;

    fn table() -> ConcurrentDynamicTable {
        ConcurrentDynamicTable::new(
            DynamicTableConfig::new(DIM).with_capacity(256).with_seed(5),
            8,
        )
    }

    fn opt() -> SparseAdam {
        SparseAdam::new(DIM, AdamParams::default())
    }

    #[test]
    fn passthrough_matches_bare_table() {
        let mut gate = OnlineTable::passthrough(table());
        let bare = table();
        let mut a = vec![0.0f32; DIM];
        let mut b = vec![0.0f32; DIM];
        for id in 0..300u64 {
            let ea = EmbeddingStore::lookup_or_insert(&mut gate, id, &mut a);
            let eb = ConcurrentDynamicTable::lookup_or_insert(&bare, id, &mut b);
            assert_eq!(ea, eb);
            assert_eq!(a, b, "id {id}");
        }
        assert_eq!(gate.inner().content_checksum(), bare.content_checksum());
        assert_eq!(gate.take_delta(), (vec![], vec![]), "no tracking");
    }

    #[test]
    fn admission_blocks_rare_ids_from_allocating() {
        let mut gate = OnlineTable::online(
            table(),
            Some(FeatureAdmission::new(AdmissionConfig::new(3, 0.0))),
        );
        let mut buf = vec![0.0f32; DIM];
        // Two sightings: below threshold — served the default row, no
        // allocation.
        for _ in 0..2 {
            let hit = EmbeddingStore::lookup_or_insert(&mut gate, 42, &mut buf);
            assert!(!hit);
            assert_eq!(buf, vec![0.0; DIM], "rejected id gets the default row");
        }
        assert_eq!(EmbeddingStore::len(&gate), 0);
        // Third sighting crosses the threshold: a real row appears.
        EmbeddingStore::lookup_or_insert(&mut gate, 42, &mut buf);
        assert_eq!(EmbeddingStore::len(&gate), 1);
        assert!(buf.iter().any(|&x| x != 0.0), "admitted row is initialized");
        let (ups, rem) = gate.take_delta();
        assert_eq!(ups, vec![42]);
        assert!(rem.is_empty());
    }

    #[test]
    fn ttl_sweep_expires_only_stale_rows() {
        let mut gate = OnlineTable::online(table(), None);
        let mut o = opt();
        let mut buf = vec![0.0f32; DIM];
        gate.set_step(0);
        for id in 0..10u64 {
            EmbeddingStore::lookup_or_insert(&mut gate, id, &mut buf);
        }
        // Steps 1..5: keep ids 0..3 hot.
        for step in 1..=5u64 {
            gate.set_step(step);
            for id in 0..3u64 {
                EmbeddingStore::lookup_or_insert(&mut gate, id, &mut buf);
            }
        }
        gate.take_delta();
        let n = gate.sweep_expired(5, &mut o);
        assert_eq!(n, 7, "ids 3..10 untouched for 5 steps expire");
        assert_eq!(EmbeddingStore::len(&gate), 3);
        for id in 0..3u64 {
            assert!(gate.inner().contains(id), "hot id {id} survives");
        }
        let (ups, rem) = gate.take_delta();
        assert!(ups.is_empty());
        assert_eq!(rem, (3..10).collect::<Vec<u64>>());
        assert_eq!(gate.expired_total(), 7);
    }

    #[test]
    fn ttl_never_expires_rows_touched_in_current_window() {
        let mut gate = OnlineTable::online(table(), None);
        let mut o = opt();
        let mut buf = vec![0.0f32; DIM];
        for step in 0..20u64 {
            gate.set_step(step);
            // Touch a rotating pair every step; with ttl == 1 only rows
            // touched exactly this step may survive a sweep.
            EmbeddingStore::lookup_or_insert(&mut gate, step % 4, &mut buf);
            EmbeddingStore::lookup_or_insert(&mut gate, 100 + step, &mut buf);
            gate.sweep_expired(1, &mut o);
            assert!(
                gate.inner().contains(step % 4),
                "step {step}: row touched this step must survive the sweep"
            );
            assert!(gate.inner().contains(100 + step));
            // The previous step's one-shot row is now 1 step stale.
            if step > 0 {
                assert!(!gate.inner().contains(100 + step - 1));
            }
        }
    }

    #[test]
    fn ttl_sweep_boundary_is_exactly_clock_minus_touch_geq_ttl() {
        // Pins the audited boundary semantics of `sweep_expired`
        // (`now.saturating_sub(t) >= ttl`): a row last touched at step
        // `t` expires at the FIRST sweep where `clock - t == ttl` —
        // `>=`, not `>` — and survives every sweep before that.
        let mut gate = OnlineTable::online(table(), None);
        let mut o = opt();
        let mut buf = vec![0.0f32; DIM];
        gate.set_step(10);
        EmbeddingStore::lookup_or_insert(&mut gate, 1, &mut buf);
        // clock - t == ttl - 1: one step short of stale — survives.
        gate.set_step(10 + 5 - 1);
        assert_eq!(gate.sweep_expired(5, &mut o), 0);
        assert!(gate.inner().contains(1));
        // clock - t == ttl exactly: expires on this sweep.
        gate.set_step(10 + 5);
        assert_eq!(gate.sweep_expired(5, &mut o), 1);
        assert!(!gate.inner().contains(1));
    }

    #[test]
    fn ttl_sweep_survives_clock_regression() {
        // `saturating_sub` pins the behavior when the TTL clock moves
        // backwards (a restarted trainer replaying an earlier step): a
        // row touched "in the future" must never underflow into a huge
        // age and get swept — it just reads as age 0.
        let mut gate = OnlineTable::online(table(), None);
        let mut o = opt();
        let mut buf = vec![0.0f32; DIM];
        gate.set_step(5);
        EmbeddingStore::lookup_or_insert(&mut gate, 9, &mut buf);
        gate.set_step(0); // clock went backwards past the touch stamp
        assert_eq!(gate.sweep_expired(1, &mut o), 0);
        assert!(
            gate.inner().contains(9),
            "future-touched row must read as fresh, not as u64::MAX old"
        );
        // Once the clock catches back up past touch + ttl, it expires
        // normally.
        gate.set_step(6);
        assert_eq!(gate.sweep_expired(1, &mut o), 1);
        assert!(!gate.inner().contains(9));
    }

    #[test]
    fn mark_updated_and_expiry_drop_optimizer_state() {
        let mut gate = OnlineTable::online(table(), None);
        let mut o = opt();
        let mut buf = vec![0.0f32; DIM];
        gate.set_step(0);
        EmbeddingStore::lookup_or_insert(&mut gate, 7, &mut buf);
        o.step(&mut gate, &[7], &[0.1; DIM], 1.0);
        gate.mark_updated(&[7]);
        assert!(o.row_state(7).is_some());
        gate.set_step(10);
        let n = gate.sweep_expired(5, &mut o);
        assert_eq!(n, 1);
        assert!(o.row_state(7).is_none(), "expiry must drop Adam state");
        assert!(!gate.inner().contains(7));
    }

    #[test]
    fn swept_rows_face_readmission_hysteresis() {
        // threshold 1 admits on first sight; margin 2 means a swept row
        // must climb to an estimated count of 3 before returning.
        let mut gate = OnlineTable::online(
            table(),
            Some(FeatureAdmission::new(
                AdmissionConfig::new(1, 0.0).with_readmit_margin(2),
            )),
        );
        let mut o = opt();
        let mut buf = vec![0.0f32; DIM];
        gate.set_step(0);
        EmbeddingStore::lookup_or_insert(&mut gate, 11, &mut buf);
        assert_eq!(EmbeddingStore::len(&gate), 1, "count 1 >= threshold 1");
        gate.set_step(10);
        assert_eq!(gate.sweep_expired(5, &mut o), 1);
        assert_eq!(EmbeddingStore::len(&gate), 0);
        // Count 2 < 1 + margin 2: served the default row, no realloc.
        EmbeddingStore::lookup_or_insert(&mut gate, 11, &mut buf);
        assert_eq!(EmbeddingStore::len(&gate), 0, "hysteresis blocks thrash");
        assert_eq!(buf, vec![0.0; DIM]);
        // Count 3 clears the raised bar: the row is re-admitted.
        EmbeddingStore::lookup_or_insert(&mut gate, 11, &mut buf);
        assert_eq!(EmbeddingStore::len(&gate), 1);
    }

    #[test]
    fn day_decay_propagates_through_the_gate() {
        let mut gate = OnlineTable::online(
            table(),
            Some(FeatureAdmission::new(
                AdmissionConfig::new(3, 0.0).with_day_decay(true),
            )),
        );
        let mut buf = vec![0.0f32; DIM];
        EmbeddingStore::lookup_or_insert(&mut gate, 8, &mut buf);
        EmbeddingStore::lookup_or_insert(&mut gate, 8, &mut buf);
        gate.advance_day(); // count 2 → 1
        EmbeddingStore::lookup_or_insert(&mut gate, 8, &mut buf);
        assert_eq!(
            EmbeddingStore::len(&gate),
            0,
            "decayed count 1+1=2 < 3 keeps the id out"
        );
        EmbeddingStore::lookup_or_insert(&mut gate, 8, &mut buf);
        assert_eq!(EmbeddingStore::len(&gate), 1, "count 3 admits");
    }

    #[test]
    fn fetch_rows_masked_gate_identical_across_pool_sizes() {
        // Enough occurrences to clear the parallel-fetch threshold, with
        // an admission filter active: contents and outputs must match
        // the 1-thread gate bit-for-bit.
        let ids: Vec<u64> = (0..4000u64).map(|i| (i * 7 + 1) % 900).collect();
        let run = |threads: usize| {
            let pool = WorkerPool::new(threads);
            let mut gate = OnlineTable::online(
                table(),
                Some(FeatureAdmission::new(AdmissionConfig::new(2, 0.05))),
            );
            gate.set_step(3);
            let mut out = vec![0.0f32; ids.len() * DIM];
            gate.fetch_rows(&ids, true, &mut out, Some(&pool));
            let (ups, rem) = gate.take_delta();
            (
                out,
                gate.inner().content_checksum(),
                EmbeddingStore::len(&gate),
                gate.admission_totals(),
                ups,
                rem,
            )
        };
        let reference = run(1);
        for threads in [2usize, 4] {
            assert_eq!(run(threads), reference, "{threads} threads diverged");
        }
        // The filter actually filtered something.
        assert!(reference.3 .1 > 0, "some ids must be rejected");
        assert!(reference.2 > 0, "some ids must be admitted");
    }
}
