//! Row-level change tracking between incremental sync points.
//!
//! [`DeltaTracker`] records which rows of a shard were **upserted**
//! (inserted or value-updated) and which were **removed** (TTL expiry,
//! eviction) since the last sync. At every `--sync-interval` boundary
//! the trainer drains it ([`DeltaTracker::take`]) into a delta snapshot
//! ([`crate::checkpoint::delta`]); replaying base + ordered deltas
//! reconstructs the full shard state exactly.
//!
//! Invariant: `upserts` and `removed` are disjoint at all times — a
//! remove cancels a pending upsert and vice versa, so each id appears
//! in at most one set and the *last* operation within the interval
//! wins, exactly matching the table's end-of-interval contents.

use std::collections::HashSet;

use crate::embedding::GlobalId;

/// Dirty/removed row sets for one sync interval.
#[derive(Clone, Debug, Default)]
pub struct DeltaTracker {
    upserts: HashSet<GlobalId>,
    removed: HashSet<GlobalId>,
}

impl DeltaTracker {
    pub fn new() -> Self {
        DeltaTracker::default()
    }

    /// Record an insert or value update of `id`.
    pub fn upsert(&mut self, id: GlobalId) {
        self.removed.remove(&id);
        self.upserts.insert(id);
    }

    /// Record a removal of `id` (expiry/eviction).
    pub fn remove(&mut self, id: GlobalId) {
        self.upserts.remove(&id);
        self.removed.insert(id);
    }

    pub fn pending_upserts(&self) -> usize {
        self.upserts.len()
    }

    pub fn pending_removals(&self) -> usize {
        self.removed.len()
    }

    pub fn is_empty(&self) -> bool {
        self.upserts.is_empty() && self.removed.is_empty()
    }

    /// Drain into `(upserted_ids, removed_ids)`, both **sorted
    /// ascending** so the emitted delta bytes are identical no matter
    /// what order the operations were recorded in (the cross-thread
    /// bit-identity witness rides on this).
    pub fn take(&mut self) -> (Vec<GlobalId>, Vec<GlobalId>) {
        let mut ups: Vec<GlobalId> = self.upserts.drain().collect();
        let mut rem: Vec<GlobalId> = self.removed.drain().collect();
        ups.sort_unstable();
        rem.sort_unstable();
        (ups, rem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_operation_wins() {
        let mut t = DeltaTracker::new();
        t.upsert(5);
        t.remove(5);
        assert_eq!(t.take(), (vec![], vec![5]));

        let mut t = DeltaTracker::new();
        t.remove(7);
        t.upsert(7);
        assert_eq!(t.take(), (vec![7], vec![]));
    }

    #[test]
    fn take_drains_and_sorts() {
        let mut t = DeltaTracker::new();
        for id in [9u64, 3, 7, 1] {
            t.upsert(id);
        }
        t.remove(100);
        t.remove(50);
        assert_eq!(t.pending_upserts(), 4);
        assert_eq!(t.pending_removals(), 2);
        let (ups, rem) = t.take();
        assert_eq!(ups, vec![1, 3, 7, 9]);
        assert_eq!(rem, vec![50, 100]);
        assert!(t.is_empty(), "take must reset the tracker");
    }

    #[test]
    fn sets_stay_disjoint() {
        let mut t = DeltaTracker::new();
        t.upsert(1);
        t.upsert(2);
        t.remove(2);
        t.upsert(2);
        t.remove(1);
        let (ups, rem) = t.take();
        assert_eq!(ups, vec![2]);
        assert_eq!(rem, vec![1]);
    }
}
