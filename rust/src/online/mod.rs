//! Online-learning subsystem: continuous (streaming) training with
//! feature admission, TTL expiry and incremental delta sync to serving.
//!
//! MTGenRec's deployment story is continuous operation — the trainer
//! ingests an endless log stream while serving handles hundreds of
//! millions of requests a day. This module turns the offline trainer
//! into that shape (the Monolith recipe: probabilistic/frequency
//! feature filtering, expiry of stale embeddings, periodic incremental
//! parameter sync from training to serving):
//!
//! - [`stream`] — an unbounded, time-stamped sequence stream over the
//!   workload generator; each generator *day* mints fresh ID space.
//! - [`admission`] — count-min frequency filtering with a deterministic
//!   seeded probabilistic lottery, so rare one-shot IDs never allocate
//!   embedding rows.
//! - [`table`] — [`table::OnlineTable`], the gate that layers
//!   admission, per-row touch stamps (the TTL input) and
//!   [`delta::DeltaTracker`] change tracking over the lock-striped
//!   concurrent shard table.
//! - [`delta`] — dirty/removed row sets per sync interval; drained into
//!   delta snapshots by [`crate::checkpoint::delta`], which a serving
//!   replica applies on top of a base snapshot to reconstruct the exact
//!   training state.
//!
//! Everything is deterministic: admission decisions are pure functions
//! of `(seed, id, count)`, sweeps and delta drains process ids in
//! sorted order, and the stream replays exactly — an online run is
//! bit-identical across `--threads` values, and base + deltas
//! reconstruct the full state row for row.

pub mod admission;
pub mod delta;
pub mod stream;
pub mod table;

use std::path::PathBuf;

pub use admission::{AdmissionConfig, FeatureAdmission};
pub use table::OnlineTable;

/// Knobs for an online (`--mode online`) training run.
#[derive(Clone, Debug)]
pub struct OnlineOptions {
    /// Steps per sync interval: every `sync_interval` steps the TTL
    /// sweeper runs and a delta snapshot is emitted. Must be >= 1.
    pub sync_interval: usize,
    /// Number of sync intervals to run; `0` = run until interrupted
    /// (the production shape). Tests and benches set a bound.
    pub intervals: usize,
    /// Steps a row may go untrained before the sweeper retires it;
    /// `0` = never expire. When nonzero it must be >= `sync_interval`
    /// (a TTL shorter than the sweep cadence would expire rows that
    /// never had a full interval to be touched).
    pub feature_ttl: u64,
    /// Feature admission policy; `None` admits every ID (dynamic-table
    /// default behavior).
    pub admission: Option<AdmissionConfig>,
    /// Where delta snapshots are written (the "serving" directory);
    /// `None` tracks deltas and accounts their volume without file I/O.
    pub sync_dir: Option<PathBuf>,
    /// Advance the generator's day every `day_every` stream chunks
    /// (fresh-ID arrival cadence); `0` = never.
    pub day_every: usize,
}

impl OnlineOptions {
    pub fn new(sync_interval: usize) -> Self {
        OnlineOptions {
            sync_interval,
            intervals: 0,
            feature_ttl: 0,
            admission: None,
            sync_dir: None,
            day_every: 8,
        }
    }

    /// Total steps of a bounded run; `None` when endless.
    pub fn total_steps(&self) -> Option<usize> {
        if self.intervals == 0 {
            None
        } else {
            Some(self.intervals * self.sync_interval)
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.sync_interval >= 1,
            "--sync-interval must be >= 1 (got 0): online mode syncs every N steps"
        );
        anyhow::ensure!(
            self.feature_ttl == 0 || self.feature_ttl >= self.sync_interval as u64,
            "--feature-ttl ({}) must be >= --sync-interval ({}): a shorter TTL would \
             expire rows before they complete one interval",
            self.feature_ttl,
            self.sync_interval
        );
        if let Some(a) = &self.admission {
            a.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_rejects_contradictory_knobs() {
        assert!(OnlineOptions::new(0).validate().is_err(), "zero interval");
        let mut o = OnlineOptions::new(10);
        assert!(o.validate().is_ok());
        o.feature_ttl = 5;
        assert!(o.validate().is_err(), "ttl below sync interval");
        o.feature_ttl = 10;
        assert!(o.validate().is_ok(), "ttl == interval is allowed");
        o.admission = Some(AdmissionConfig::new(0, 0.0));
        assert!(o.validate().is_err(), "invalid admission config bubbles");
    }

    #[test]
    fn total_steps_bounds() {
        let mut o = OnlineOptions::new(10);
        assert_eq!(o.total_steps(), None, "endless by default");
        o.intervals = 3;
        assert_eq!(o.total_steps(), Some(30));
    }
}
