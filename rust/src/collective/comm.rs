//! Communicator: the NCCL substitute for simulated and real devices.
//!
//! A [`CommGroup`] creates one [`CommHandle`] per rank; handles move into
//! worker threads. Since the distributed runtime landed, a handle runs on
//! one of two backends behind the same API:
//!
//! - **Local** (in-process, [`CommGroup::new`]): per-pair unbounded
//!   channels plus a shared-memory reduce — the historical simulated
//!   path, byte- and bit-identical to before.
//! - **Remote** ([`CommHandle::from_remote`]): every send/receive goes
//!   through a [`RemoteTransport`] — in production a Unix-domain-socket
//!   mesh ([`crate::dist::transport::SocketTransport`]) connecting real
//!   worker *processes*. Reductions ride a dedicated pseudo-lane
//!   ([`REDUCE_LANE`]) as an all-gather folded **in rank order**, so the
//!   floating-point result is bit-identical to the local shared-buffer
//!   fold.
//!
//! Primitives:
//! - `all_to_all` — one message to/from every rank (deterministic source
//!   order on receive);
//! - `post_all_to_all_on` / `complete_all_to_all` — the non-blocking
//!   isend/irecv-style split of the same exchange: `post` enqueues the
//!   sends immediately and returns a [`PendingAllToAll`] token;
//!   `complete` blocks for the receives. Each in-flight exchange rides a
//!   dedicated **lane** (an independent per-pair FIFO, the software
//!   analogue of a NCCL stream/tag), so an ID exchange for micro-batch
//!   *k+1* can overlap an embedding exchange for *k* without the FIFO
//!   streams interleaving mismatched payloads;
//! - `all_reduce_sum` / `all_reduce_max` — rank-order-deterministic
//!   reduction (shared-buffer epoch protocol locally, [`REDUCE_LANE`]
//!   gather remotely);
//! - `barrier`, `broadcast`, `all_gather`.
//!
//! **Failure policy**: a transport error (peer process died, socket
//! reset) is a *panic*, not a `Result` — the exchange API stays
//! infallible for the trainer hot loop, the panicking worker process
//! exits nonzero, and the supervisor's crash-recovery path takes over.
//! Transient faults are retried *inside* the transport
//! ([`crate::util::retry`]) before they ever surface here; the retry
//! count is exposed via [`CommHandle::transport_retries`].
//!
//! Every handle tracks sent-byte counts per primitive so callers can
//! charge simulated network time via [`crate::collective::NetModel`].

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// Number of independent channel lanes per pair. Lane assignments:
/// [`LANE_DEFAULT`] for ordinary collectives, [`LANE_IDS`] for posted ID
/// exchanges, [`LANE_EMB`] for embedding-row replies, and
/// [`LANE_GRAD_IDS`]/[`LANE_GRAD`] for the posted backward gradient
/// exchange — five lanes so a double-buffered round can keep micro-batch
/// *k+1*'s ID exchange, *k*'s embedding reply, and *k−1*'s gradient
/// push all in flight at once without FIFO interleaving.
pub const LANES: usize = 5;
/// Default lane used by the blocking collectives.
pub const LANE_DEFAULT: usize = 0;
/// Lane carrying posted (pipelined) ID all-to-alls.
pub const LANE_IDS: usize = 1;
/// Lane carrying embedding-row replies.
pub const LANE_EMB: usize = 2;
/// Lane carrying the backward gradient exchange's ID headers.
pub const LANE_GRAD_IDS: usize = 3;
/// Lane carrying the backward gradient payloads.
pub const LANE_GRAD: usize = 4;
/// Pseudo-lane carrying remote reductions (all-reduce / barrier). Not a
/// postable lane — [`post_all_to_all_on`](CommHandle::post_all_to_all_on)
/// rejects it — but a [`RemoteTransport`] must provision `LANES + 1`
/// FIFO streams per pair so reductions never interleave with posted
/// exchanges.
pub const REDUCE_LANE: usize = LANES;

/// Typed payloads exchanged between ranks (a tiny closed set instead of
/// generic serialization).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    Ids(Vec<u64>),
    Floats(Vec<f32>),
    Counts(Vec<u64>),
    Empty,
}

impl Message {
    /// Wire size in bytes (for cost accounting).
    pub fn bytes(&self) -> usize {
        match self {
            Message::Ids(v) => v.len() * 8,
            Message::Floats(v) => v.len() * 4,
            Message::Counts(v) => v.len() * 8,
            Message::Empty => 0,
        }
    }

    pub fn into_ids(self) -> Vec<u64> {
        match self {
            Message::Ids(v) => v,
            Message::Empty => Vec::new(),
            other => panic!("expected Ids, got {other:?}"),
        }
    }

    pub fn into_floats(self) -> Vec<f32> {
        match self {
            Message::Floats(v) => v,
            Message::Empty => Vec::new(),
            other => panic!("expected Floats, got {other:?}"),
        }
    }

    pub fn into_counts(self) -> Vec<u64> {
        match self {
            Message::Counts(v) => v,
            Message::Empty => Vec::new(),
            other => panic!("expected Counts, got {other:?}"),
        }
    }
}

/// A byte transport connecting this rank to every peer, with `LANES + 1`
/// independent FIFO streams per ordered pair (the posted lanes plus
/// [`REDUCE_LANE`]). Implementations must deliver messages per
/// `(lane, src)` in send order and must route self-sends
/// (`dst == own rank`) back to their own receive queue without touching
/// the wire. Transient failures should be retried internally
/// ([`crate::util::retry`]); an `Err` from `send`/`recv` is terminal —
/// the communicator panics on it and the worker process dies for the
/// supervisor to restart.
pub trait RemoteTransport: Send {
    /// Enqueue `msg` for `dst` on `lane`. May block only for
    /// backpressure-free internal queuing; must not wait for the peer to
    /// receive.
    fn send(&mut self, lane: usize, dst: usize, msg: Message) -> anyhow::Result<()>;
    /// Blocking receive of the next message from `src` on `lane`.
    fn recv(&mut self, lane: usize, src: usize) -> anyhow::Result<Message>;
    /// Cumulative transient-failure retries performed internally (for
    /// `TrainReport` fault accounting).
    fn retries(&self) -> u64;
}

/// Shared reduce/barrier state (epoch protocol, local backend).
struct ReduceState {
    buf: Vec<f32>,
    /// Per-rank contribution buffers (reused across epochs), folded in
    /// rank order once complete so the floating-point reduction is
    /// bitwise run-to-run deterministic (thread arrival order must not
    /// matter). Every slot is rewritten each epoch before the fold.
    contribs: Vec<Vec<f32>>,
    writers: usize,
    readers: usize,
    /// Bumped when all writers have contributed.
    write_gen: u64,
    /// Bumped when all readers have consumed (full reset).
    reset_gen: u64,
}

struct Shared {
    world: usize,
    reduce: Mutex<ReduceState>,
    cv: Condvar,
}

/// Per-primitive cumulative sent-bytes (this rank).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub all_to_all_bytes: u64,
    pub all_reduce_bytes: u64,
    pub all_to_all_ops: u64,
    pub all_reduce_ops: u64,
    /// All-to-all bytes split by lane (`all_to_all_bytes` is the sum):
    /// the per-lane wire meters behind the trainer's payload-conservation
    /// accounting for the multiplexed exchange.
    pub lane_bytes: [u64; LANES],
}

/// The communication substrate behind a handle.
enum Backend {
    /// In-process: per-pair unbounded channels + shared-memory reduce.
    Local {
        /// senders[lane][dst] — channel into dst's inbox from this rank.
        senders: Vec<Vec<Sender<Message>>>,
        /// receivers[lane][src] — this rank's inbox from src.
        receivers: Vec<Vec<Receiver<Message>>>,
        shared: Arc<Shared>,
    },
    /// Cross-process: everything rides the transport.
    Remote(Box<dyn RemoteTransport>),
}

/// One rank's endpoint.
pub struct CommHandle {
    pub rank: usize,
    pub world: usize,
    backend: Backend,
    /// Per-lane count of posted exchanges (stamps the pending token).
    posted_seq: Vec<u64>,
    /// Per-lane count of completed exchanges (checked on completion:
    /// lanes are FIFO, so completing out of post order would silently
    /// deliver the wrong payloads — instead it panics).
    completed_seq: Vec<u64>,
    pub stats: CommStats,
}

/// Token for an in-flight posted all-to-all: the sends are already
/// enqueued; [`CommHandle::complete_all_to_all`] collects the receives.
#[must_use = "a posted all-to-all must be completed or peers deadlock"]
#[derive(Debug)]
pub struct PendingAllToAll {
    lane: usize,
    seq: u64,
}

/// Factory for a communicator group.
pub struct CommGroup;

impl CommGroup {
    /// Create `world` connected in-process handles (index = rank).
    pub fn new(world: usize) -> Vec<CommHandle> {
        assert!(world >= 1);
        // txs[src][lane][dst], rxs[dst][lane][src]
        let mut txs: Vec<Vec<Vec<Option<Sender<Message>>>>> = (0..world)
            .map(|_| (0..LANES).map(|_| (0..world).map(|_| None).collect()).collect())
            .collect();
        let mut rxs: Vec<Vec<Vec<Option<Receiver<Message>>>>> = (0..world)
            .map(|_| (0..LANES).map(|_| (0..world).map(|_| None).collect()).collect())
            .collect();
        for lane in 0..LANES {
            for src in 0..world {
                for dst in 0..world {
                    let (tx, rx) = channel();
                    txs[src][lane][dst] = Some(tx);
                    rxs[dst][lane][src] = Some(rx);
                }
            }
        }
        let shared = Arc::new(Shared {
            world,
            reduce: Mutex::new(ReduceState {
                buf: Vec::new(),
                contribs: (0..world).map(|_| Vec::new()).collect(),
                writers: 0,
                readers: 0,
                write_gen: 0,
                reset_gen: 0,
            }),
            cv: Condvar::new(),
        });
        txs.into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_lanes, rx_lanes))| CommHandle {
                rank,
                world,
                backend: Backend::Local {
                    senders: tx_lanes
                        .into_iter()
                        .map(|row| row.into_iter().map(Option::unwrap).collect())
                        .collect(),
                    receivers: rx_lanes
                        .into_iter()
                        .map(|row| row.into_iter().map(Option::unwrap).collect())
                        .collect(),
                    shared: Arc::clone(&shared),
                },
                posted_seq: vec![0; LANES],
                completed_seq: vec![0; LANES],
                stats: CommStats::default(),
            })
            .collect()
    }
}

impl CommHandle {
    /// Wrap a [`RemoteTransport`] as this process's communicator
    /// endpoint: rank `rank` of `world` worker processes. The transport
    /// must already be connected to every peer.
    pub fn from_remote(rank: usize, world: usize, transport: Box<dyn RemoteTransport>) -> Self {
        assert!(rank < world);
        CommHandle {
            rank,
            world,
            backend: Backend::Remote(transport),
            posted_seq: vec![0; LANES],
            completed_seq: vec![0; LANES],
            stats: CommStats::default(),
        }
    }

    /// Cumulative transient-failure retries the transport performed (0
    /// on the local backend, which cannot fail transiently).
    pub fn transport_retries(&self) -> u64 {
        match &self.backend {
            Backend::Local { .. } => 0,
            Backend::Remote(t) => t.retries(),
        }
    }

    /// All-to-all: send `chunks[dst]` to each rank, receive one message
    /// from every rank (indexed by source). `chunks.len()` must equal
    /// `world`; the self-chunk short-circuits through the local channel
    /// (zero cost is the caller's accounting decision).
    pub fn all_to_all(&mut self, chunks: Vec<Message>) -> Vec<Message> {
        let pending = self.post_all_to_all_on(LANE_DEFAULT, chunks);
        self.complete_all_to_all(pending)
    }

    /// Non-blocking half of an all-to-all: enqueue every send on `lane`
    /// and return immediately. The matching
    /// [`complete_all_to_all`](Self::complete_all_to_all) call collects
    /// the receives. Posted exchanges on *different* lanes may be
    /// in flight simultaneously; on one lane they complete in post
    /// order (FIFO per peer pair) — every rank must post/complete in the
    /// same global order per lane, the usual collective discipline.
    pub fn post_all_to_all_on(&mut self, lane: usize, chunks: Vec<Message>) -> PendingAllToAll {
        assert_eq!(chunks.len(), self.world);
        assert!(lane < LANES, "lane {lane} out of range");
        let rank = self.rank;
        let mut sent = 0u64;
        match &mut self.backend {
            Backend::Local { senders, .. } => {
                for (dst, m) in chunks.into_iter().enumerate() {
                    if dst != rank {
                        sent += m.bytes() as u64;
                    }
                    senders[lane][dst].send(m).expect("peer hung up");
                }
            }
            Backend::Remote(t) => {
                for (dst, m) in chunks.into_iter().enumerate() {
                    if dst != rank {
                        sent += m.bytes() as u64;
                    }
                    t.send(lane, dst, m).unwrap_or_else(|e| {
                        panic!("transport send to rank {dst} on lane {lane} failed: {e:#}")
                    });
                }
            }
        }
        self.stats.all_to_all_bytes += sent;
        self.stats.lane_bytes[lane] += sent;
        self.stats.all_to_all_ops += 1;
        let seq = self.posted_seq[lane];
        self.posted_seq[lane] += 1;
        PendingAllToAll { lane, seq }
    }

    /// Blocking half: receive one message from every rank on the posted
    /// exchange's lane (indexed by source). Panics if exchanges on one
    /// lane are completed out of post order (the FIFO lane would
    /// otherwise hand back the wrong exchange's payloads).
    pub fn complete_all_to_all(&mut self, pending: PendingAllToAll) -> Vec<Message> {
        let lane = pending.lane;
        assert_eq!(
            pending.seq, self.completed_seq[lane],
            "all-to-all on lane {lane} completed out of post order"
        );
        self.completed_seq[lane] += 1;
        match &mut self.backend {
            Backend::Local { receivers, .. } => (0..self.world)
                .map(|src| receivers[lane][src].recv().expect("peer hung up"))
                .collect(),
            Backend::Remote(t) => (0..self.world)
                .map(|src| {
                    t.recv(lane, src).unwrap_or_else(|e| {
                        panic!("transport recv from rank {src} on lane {lane} failed: {e:#}")
                    })
                })
                .collect(),
        }
    }

    /// Element-wise sum all-reduce over an f32 buffer (in place).
    pub fn all_reduce_sum(&mut self, data: &mut [f32]) {
        self.reduce_with(data, |acc, x| *acc += x);
        self.stats.all_reduce_bytes += (data.len() * 4) as u64;
        self.stats.all_reduce_ops += 1;
    }

    /// Element-wise max all-reduce (used e.g. for sync'ing clocks).
    pub fn all_reduce_max(&mut self, data: &mut [f32]) {
        self.reduce_with(data, |acc, x| {
            if x > *acc {
                *acc = x
            }
        });
        self.stats.all_reduce_bytes += (data.len() * 4) as u64;
        self.stats.all_reduce_ops += 1;
    }

    /// Rank-order-deterministic reduction. Locally this is the
    /// shared-buffer epoch protocol; remotely each rank all-gathers the
    /// contributions on [`REDUCE_LANE`] and folds them in rank order —
    /// the same fold order, so the f32 result is bit-identical across
    /// backends.
    fn reduce_with(&mut self, data: &mut [f32], combine: impl Fn(&mut f32, f32)) {
        let rank = self.rank;
        let world = self.world;
        match &mut self.backend {
            Backend::Local { shared, .. } => {
                let sh = shared;
                let mut st = sh.reduce.lock().unwrap();
                // Wait out any previous operation that hasn't fully reset.
                while st.writers != 0 && st.readers != 0 {
                    st = sh.cv.wait(st).unwrap();
                }
                // Contribute. Contributions park in reusable per-rank
                // buffers; the completing writer folds them in rank order
                // so the result is independent of thread arrival order
                // (bitwise determinism across runs) with no steady-state
                // allocation.
                {
                    let contrib = &mut st.contribs[rank];
                    contrib.clear();
                    contrib.extend_from_slice(data);
                }
                st.writers += 1;
                if st.writers == sh.world {
                    let ReduceState { buf, contribs, .. } = &mut *st;
                    buf.clear();
                    buf.extend_from_slice(&contribs[0]);
                    for c in contribs.iter().skip(1) {
                        assert_eq!(c.len(), buf.len(), "all_reduce length mismatch");
                        for (acc, &x) in buf.iter_mut().zip(c.iter()) {
                            combine(acc, x);
                        }
                    }
                    st.write_gen += 1;
                    sh.cv.notify_all();
                } else {
                    let gen = st.write_gen;
                    while st.write_gen == gen {
                        st = sh.cv.wait(st).unwrap();
                    }
                }
                // Consume.
                data.copy_from_slice(&st.buf);
                st.readers += 1;
                if st.readers == sh.world {
                    st.writers = 0;
                    st.readers = 0;
                    st.reset_gen += 1;
                    sh.cv.notify_all();
                } else {
                    let gen = st.reset_gen;
                    while st.reset_gen == gen {
                        st = sh.cv.wait(st).unwrap();
                    }
                }
            }
            Backend::Remote(t) => {
                // All-gather contributions on the reduce lane, fold in
                // rank order (own contribution at its own position).
                for dst in 0..world {
                    if dst != rank {
                        t.send(REDUCE_LANE, dst, Message::Floats(data.to_vec()))
                            .unwrap_or_else(|e| {
                                panic!("transport reduce send to rank {dst} failed: {e:#}")
                            });
                    }
                }
                let mut acc: Vec<f32> = Vec::new();
                for src in 0..world {
                    let contrib: Vec<f32> = if src == rank {
                        data.to_vec()
                    } else {
                        t.recv(REDUCE_LANE, src)
                            .unwrap_or_else(|e| {
                                panic!("transport reduce recv from rank {src} failed: {e:#}")
                            })
                            .into_floats()
                    };
                    if src == 0 {
                        acc = contrib;
                    } else {
                        assert_eq!(contrib.len(), acc.len(), "all_reduce length mismatch");
                        for (a, &x) in acc.iter_mut().zip(contrib.iter()) {
                            combine(a, x);
                        }
                    }
                }
                data.copy_from_slice(&acc);
            }
        }
    }

    /// Synchronization barrier.
    pub fn barrier(&mut self) {
        let mut noop: [f32; 1] = [0.0];
        self.reduce_with(&mut noop, |_, _| {});
    }

    /// Broadcast `data` from `root` to all ranks (returns the root's
    /// message everywhere).
    pub fn broadcast(&mut self, root: usize, data: Message) -> Message {
        let chunks: Vec<Message> = (0..self.world)
            .map(|_dst| {
                if self.rank == root {
                    data.clone()
                } else {
                    Message::Empty
                }
            })
            .collect();
        let mut received = self.all_to_all(chunks);
        received.swap_remove(root)
    }

    /// All-gather: everyone contributes one message, everyone receives
    /// the full vector indexed by rank.
    pub fn all_gather(&mut self, data: Message) -> Vec<Message> {
        let chunks: Vec<Message> = (0..self.world).map(|_| data.clone()).collect();
        self.all_to_all(chunks)
    }

    /// All-gather of one u64 per rank (batch sizes for §5.1 weighted
    /// gradient averaging: "All-to-all communication to synchronize batch
    /// sizes across devices").
    pub fn all_gather_u64(&mut self, value: u64) -> Vec<u64> {
        self.all_gather(Message::Counts(vec![value]))
            .into_iter()
            .map(|m| m.into_counts()[0])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;
    use std::thread;

    /// Run `f(rank, handle)` on `world` threads, returning per-rank results.
    pub fn run_group<T: Send + 'static>(
        world: usize,
        f: impl Fn(usize, &mut CommHandle) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let handles = CommGroup::new(world);
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for (rank, mut h) in handles.into_iter().enumerate() {
            let f = Arc::clone(&f);
            joins.push(thread::spawn(move || f(rank, &mut h)));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    /// In-memory [`RemoteTransport`] mesh: per-(dst, lane, src) queues
    /// behind one mutex. Exercises the Remote backend's code paths
    /// (rank-order reduce fold, self-send routing, lane demux) without
    /// sockets; the real UDS transport lives in `dist::transport`.
    struct MockMesh {
        // queues[dst][lane][src]
        queues: Mutex<Vec<Vec<Vec<VecDeque<Message>>>>>,
        cv: Condvar,
    }

    struct MockTransport {
        rank: usize,
        mesh: Arc<MockMesh>,
    }

    impl RemoteTransport for MockTransport {
        fn send(&mut self, lane: usize, dst: usize, msg: Message) -> anyhow::Result<()> {
            let mut q = self.mesh.queues.lock().unwrap();
            q[dst][lane][self.rank].push_back(msg);
            self.mesh.cv.notify_all();
            Ok(())
        }
        fn recv(&mut self, lane: usize, src: usize) -> anyhow::Result<Message> {
            let mut q = self.mesh.queues.lock().unwrap();
            loop {
                if let Some(m) = q[self.rank][lane][src].pop_front() {
                    return Ok(m);
                }
                q = self.mesh.cv.wait(q).unwrap();
            }
        }
        fn retries(&self) -> u64 {
            7 // distinguishable constant for the accounting test
        }
    }

    /// Run `f(rank, handle)` over Remote-backend handles on a mock mesh.
    fn run_remote_group<T: Send + 'static>(
        world: usize,
        f: impl Fn(usize, &mut CommHandle) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let mesh = Arc::new(MockMesh {
            queues: Mutex::new(
                (0..world)
                    .map(|_| {
                        (0..=LANES)
                            .map(|_| (0..world).map(|_| VecDeque::new()).collect())
                            .collect()
                    })
                    .collect(),
            ),
            cv: Condvar::new(),
        });
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for rank in 0..world {
            let f = Arc::clone(&f);
            let t = MockTransport {
                rank,
                mesh: Arc::clone(&mesh),
            };
            joins.push(thread::spawn(move || {
                let mut h = CommHandle::from_remote(rank, world, Box::new(t));
                f(rank, &mut h)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_to_all_routes_correctly() {
        let out = run_group(4, |rank, h| {
            // Send [rank, dst] to each dst.
            let chunks = (0..4)
                .map(|dst| Message::Ids(vec![rank as u64, dst as u64]))
                .collect();
            let recv = h.all_to_all(chunks);
            recv.into_iter().map(|m| m.into_ids()).collect::<Vec<_>>()
        });
        for (rank, recv) in out.iter().enumerate() {
            for (src, msg) in recv.iter().enumerate() {
                assert_eq!(msg, &vec![src as u64, rank as u64]);
            }
        }
    }

    #[test]
    fn all_reduce_sum_and_repeat() {
        let out = run_group(8, |rank, h| {
            let mut v = vec![rank as f32, 1.0];
            h.all_reduce_sum(&mut v);
            let first = v.clone();
            // Back-to-back second reduction must not interleave with the
            // first (epoch protocol).
            let mut w = vec![1.0f32];
            h.all_reduce_sum(&mut w);
            (first, w[0])
        });
        for (first, second) in out {
            assert_eq!(first, vec![28.0, 8.0]); // 0+..+7, 8×1
            assert_eq!(second, 8.0);
        }
    }

    #[test]
    fn all_reduce_max() {
        let out = run_group(5, |rank, h| {
            let mut v = vec![rank as f32 * if rank % 2 == 0 { 1.0 } else { -1.0 }];
            h.all_reduce_max(&mut v);
            v[0]
        });
        for v in out {
            assert_eq!(v, 4.0);
        }
    }

    #[test]
    fn broadcast_from_root() {
        let out = run_group(3, |rank, h| {
            let payload = if rank == 1 {
                Message::Floats(vec![3.5, 4.5])
            } else {
                Message::Empty
            };
            h.broadcast(1, payload).into_floats()
        });
        for v in out {
            assert_eq!(v, vec![3.5, 4.5]);
        }
    }

    #[test]
    fn all_gather_u64_batch_sizes() {
        let out = run_group(4, |rank, h| h.all_gather_u64(100 + rank as u64));
        for v in out {
            assert_eq!(v, vec![100, 101, 102, 103]);
        }
    }

    #[test]
    fn stats_count_bytes() {
        let out = run_group(2, |_rank, h| {
            let chunks = vec![
                Message::Ids(vec![1, 2, 3]),
                Message::Ids(vec![4]),
            ];
            let _ = h.all_to_all(chunks);
            let mut v = vec![0.0f32; 10];
            h.all_reduce_sum(&mut v);
            h.stats
        });
        for s in out {
            // One remote Ids message of len ≤3 → ≤24 bytes (self-chunk free).
            assert!(s.all_to_all_bytes == 8 || s.all_to_all_bytes == 24);
            assert_eq!(s.all_reduce_bytes, 40);
            assert_eq!(s.all_to_all_ops, 1);
            assert_eq!(s.all_reduce_ops, 1);
            // The default-lane meter carries the whole exchange; per-lane
            // meters always sum to the aggregate.
            assert_eq!(s.lane_bytes[LANE_DEFAULT], s.all_to_all_bytes);
            assert_eq!(s.lane_bytes.iter().sum::<u64>(), s.all_to_all_bytes);
        }
    }

    #[test]
    fn barrier_world_of_one() {
        let out = run_group(1, |_rank, h| {
            h.barrier();
            let mut v = vec![5.0f32];
            h.all_reduce_sum(&mut v);
            v[0]
        });
        assert_eq!(out, vec![5.0]);
    }

    #[test]
    fn posted_exchanges_overlap_across_lanes() {
        // Post an ID exchange, then run a full embedding exchange on a
        // different lane, then complete the first — the pattern the
        // two-phase pipelined lookup uses. Payloads must not cross lanes.
        let out = run_group(4, |rank, h| {
            let ids = (0..4)
                .map(|dst| Message::Ids(vec![rank as u64 * 10 + dst as u64]))
                .collect();
            let pending = h.post_all_to_all_on(LANE_IDS, ids);
            let floats = (0..4)
                .map(|dst| Message::Floats(vec![(rank * 4 + dst) as f32]))
                .collect();
            let emb_pending = h.post_all_to_all_on(LANE_EMB, floats);
            let emb: Vec<f32> = h
                .complete_all_to_all(emb_pending)
                .into_iter()
                .map(|m| m.into_floats()[0])
                .collect();
            let ids: Vec<u64> = h
                .complete_all_to_all(pending)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            (ids, emb)
        });
        for (rank, (ids, emb)) in out.iter().enumerate() {
            for src in 0..4 {
                assert_eq!(ids[src], src as u64 * 10 + rank as u64);
                assert_eq!(emb[src], (src * 4 + rank) as f32);
            }
        }
    }

    #[test]
    fn pipelined_rounds_on_one_lane_complete_in_post_order() {
        let out = run_group(2, |rank, h| {
            // Two exchanges posted back to back on the same lane, then
            // completed in order.
            let mk = |tag: u64| {
                (0..2)
                    .map(|dst| Message::Ids(vec![tag * 100 + rank as u64 * 10 + dst as u64]))
                    .collect::<Vec<_>>()
            };
            let p1 = h.post_all_to_all_on(LANE_IDS, mk(1));
            let p2 = h.post_all_to_all_on(LANE_IDS, mk(2));
            let r1: Vec<u64> = h
                .complete_all_to_all(p1)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            let r2: Vec<u64> = h
                .complete_all_to_all(p2)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            (r1, r2)
        });
        for (rank, (r1, r2)) in out.iter().enumerate() {
            for src in 0..2 {
                assert_eq!(r1[src], 100 + src as u64 * 10 + rank as u64);
                assert_eq!(r2[src], 200 + src as u64 * 10 + rank as u64);
            }
        }
    }

    #[test]
    fn many_rounds_stress() {
        let out = run_group(4, |rank, h| {
            let mut acc = 0.0f32;
            for round in 0..50 {
                let chunks = (0..4)
                    .map(|d| Message::Floats(vec![(rank * 4 + d + round) as f32]))
                    .collect();
                let recv = h.all_to_all(chunks);
                let mut v: Vec<f32> =
                    vec![recv.iter().map(|m| m.clone().into_floats()[0]).sum()];
                h.all_reduce_sum(&mut v);
                acc += v[0];
                h.barrier();
            }
            acc
        });
        // Every rank must compute the same total.
        for w in out.windows(2) {
            assert_eq!(w[0], w[1]);
        }
    }

    /// The same mixed workload over the Local and Remote backends must
    /// produce bit-identical results — the invariant the distributed
    /// drill scales up to whole training runs.
    #[test]
    fn remote_backend_matches_local_bitwise() {
        fn workload(rank: usize, h: &mut CommHandle) -> (Vec<u64>, Vec<f32>, f32, Vec<u64>) {
            let chunks = (0..h.world)
                .map(|dst| Message::Ids(vec![rank as u64 * 100 + dst as u64]))
                .collect();
            let a2a: Vec<u64> = h
                .all_to_all(chunks)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            // Values chosen so fold order changes the f32 result: the
            // rank-order contract is what keeps backends bit-identical.
            let mut v = vec![0.1f32 + rank as f32 * 1e-7, rank as f32];
            h.all_reduce_sum(&mut v);
            let mut m = vec![rank as f32 * if rank % 2 == 0 { 1.0 } else { -1.5 }];
            h.all_reduce_max(&mut m);
            h.barrier();
            let gathered = h.all_gather_u64(rank as u64 + 7);
            (a2a, v, m[0], gathered)
        }
        for world in [1usize, 2, 4] {
            let local = run_group(world, workload);
            let remote = run_remote_group(world, workload);
            for rank in 0..world {
                assert_eq!(local[rank].0, remote[rank].0, "a2a world {world} rank {rank}");
                let (lv, rv) = (&local[rank].1, &remote[rank].1);
                assert_eq!(
                    lv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    rv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "reduce bits world {world} rank {rank}"
                );
                assert_eq!(local[rank].2.to_bits(), remote[rank].2.to_bits());
                assert_eq!(local[rank].3, remote[rank].3);
            }
        }
    }

    #[test]
    fn remote_posted_lanes_and_retry_accounting() {
        let out = run_remote_group(3, |rank, h| {
            let ids = (0..3)
                .map(|dst| Message::Ids(vec![rank as u64 * 10 + dst as u64]))
                .collect();
            let pending = h.post_all_to_all_on(LANE_IDS, ids);
            let floats = (0..3)
                .map(|dst| Message::Floats(vec![(rank * 3 + dst) as f32]))
                .collect();
            let emb_pending = h.post_all_to_all_on(LANE_EMB, floats);
            let emb: Vec<f32> = h
                .complete_all_to_all(emb_pending)
                .into_iter()
                .map(|m| m.into_floats()[0])
                .collect();
            let ids: Vec<u64> = h
                .complete_all_to_all(pending)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            (ids, emb, h.transport_retries())
        });
        for (rank, (ids, emb, retries)) in out.iter().enumerate() {
            for src in 0..3 {
                assert_eq!(ids[src], src as u64 * 10 + rank as u64);
                assert_eq!(emb[src], (src * 3 + rank) as f32);
            }
            assert_eq!(*retries, 7, "transport retry counter surfaces");
        }
        // Local handles report zero transport retries.
        let retries = run_group(2, |_r, h| {
            h.barrier();
            h.transport_retries()
        });
        assert_eq!(retries, vec![0, 0]);
    }
}
