//! In-process communicator: the NCCL substitute for simulated devices.
//!
//! A [`CommGroup`] creates one [`CommHandle`] per rank; handles move into
//! worker threads. Primitives:
//! - `all_to_all` — per-pair unbounded channels (deterministic source
//!   order on receive);
//! - `post_all_to_all_on` / `complete_all_to_all` — the non-blocking
//!   isend/irecv-style split of the same exchange: `post` enqueues the
//!   sends immediately and returns a [`PendingAllToAll`] token;
//!   `complete` blocks for the receives. Each in-flight exchange rides a
//!   dedicated **lane** (an independent per-pair channel set, the
//!   software analogue of a NCCL stream/tag), so an ID exchange for
//!   micro-batch *k+1* can overlap an embedding exchange for *k* without
//!   the FIFO streams interleaving mismatched payloads;
//! - `all_reduce_sum` / `all_reduce_max` — shared-buffer reduction with a
//!   two-phase epoch protocol (every caller returns only after the group
//!   fully resets, so back-to-back reductions cannot interleave);
//! - `barrier`, `broadcast`, `all_gather`.
//!
//! Every handle tracks sent-byte counts per primitive so callers can
//! charge simulated network time via [`crate::collective::NetModel`].

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// Number of independent channel lanes per pair. Lane assignments:
/// [`LANE_DEFAULT`] for ordinary collectives, [`LANE_IDS`] for posted ID
/// exchanges, [`LANE_EMB`] for embedding-row replies, and
/// [`LANE_GRAD_IDS`]/[`LANE_GRAD`] for the posted backward gradient
/// exchange — five lanes so a double-buffered round can keep micro-batch
/// *k+1*'s ID exchange, *k*'s embedding reply, and *k−1*'s gradient
/// push all in flight at once without FIFO interleaving.
pub const LANES: usize = 5;
/// Default lane used by the blocking collectives.
pub const LANE_DEFAULT: usize = 0;
/// Lane carrying posted (pipelined) ID all-to-alls.
pub const LANE_IDS: usize = 1;
/// Lane carrying embedding-row replies.
pub const LANE_EMB: usize = 2;
/// Lane carrying the backward gradient exchange's ID headers.
pub const LANE_GRAD_IDS: usize = 3;
/// Lane carrying the backward gradient payloads.
pub const LANE_GRAD: usize = 4;

/// Typed payloads exchanged between ranks (a tiny closed set instead of
/// generic serialization).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    Ids(Vec<u64>),
    Floats(Vec<f32>),
    Counts(Vec<u64>),
    Empty,
}

impl Message {
    /// Wire size in bytes (for cost accounting).
    pub fn bytes(&self) -> usize {
        match self {
            Message::Ids(v) => v.len() * 8,
            Message::Floats(v) => v.len() * 4,
            Message::Counts(v) => v.len() * 8,
            Message::Empty => 0,
        }
    }

    pub fn into_ids(self) -> Vec<u64> {
        match self {
            Message::Ids(v) => v,
            Message::Empty => Vec::new(),
            other => panic!("expected Ids, got {other:?}"),
        }
    }

    pub fn into_floats(self) -> Vec<f32> {
        match self {
            Message::Floats(v) => v,
            Message::Empty => Vec::new(),
            other => panic!("expected Floats, got {other:?}"),
        }
    }

    pub fn into_counts(self) -> Vec<u64> {
        match self {
            Message::Counts(v) => v,
            Message::Empty => Vec::new(),
            other => panic!("expected Counts, got {other:?}"),
        }
    }
}

/// Shared reduce/barrier state (epoch protocol).
struct ReduceState {
    buf: Vec<f32>,
    /// Per-rank contribution buffers (reused across epochs), folded in
    /// rank order once complete so the floating-point reduction is
    /// bitwise run-to-run deterministic (thread arrival order must not
    /// matter). Every slot is rewritten each epoch before the fold.
    contribs: Vec<Vec<f32>>,
    writers: usize,
    readers: usize,
    /// Bumped when all writers have contributed.
    write_gen: u64,
    /// Bumped when all readers have consumed (full reset).
    reset_gen: u64,
}

struct Shared {
    world: usize,
    reduce: Mutex<ReduceState>,
    cv: Condvar,
}

/// Per-primitive cumulative sent-bytes (this rank).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub all_to_all_bytes: u64,
    pub all_reduce_bytes: u64,
    pub all_to_all_ops: u64,
    pub all_reduce_ops: u64,
    /// All-to-all bytes split by lane (`all_to_all_bytes` is the sum):
    /// the per-lane wire meters behind the trainer's payload-conservation
    /// accounting for the multiplexed exchange.
    pub lane_bytes: [u64; LANES],
}

/// One rank's endpoint.
pub struct CommHandle {
    pub rank: usize,
    pub world: usize,
    /// senders[lane][dst] — channel into dst's inbox from this rank.
    senders: Vec<Vec<Sender<Message>>>,
    /// receivers[lane][src] — this rank's inbox from src.
    receivers: Vec<Vec<Receiver<Message>>>,
    /// Per-lane count of posted exchanges (stamps the pending token).
    posted_seq: Vec<u64>,
    /// Per-lane count of completed exchanges (checked on completion:
    /// lanes are FIFO, so completing out of post order would silently
    /// deliver the wrong payloads — instead it panics).
    completed_seq: Vec<u64>,
    shared: Arc<Shared>,
    pub stats: CommStats,
}

/// Token for an in-flight posted all-to-all: the sends are already
/// enqueued; [`CommHandle::complete_all_to_all`] collects the receives.
#[must_use = "a posted all-to-all must be completed or peers deadlock"]
#[derive(Debug)]
pub struct PendingAllToAll {
    lane: usize,
    seq: u64,
}

/// Factory for a communicator group.
pub struct CommGroup;

impl CommGroup {
    /// Create `world` connected handles (index = rank).
    pub fn new(world: usize) -> Vec<CommHandle> {
        assert!(world >= 1);
        // txs[src][lane][dst], rxs[dst][lane][src]
        let mut txs: Vec<Vec<Vec<Option<Sender<Message>>>>> = (0..world)
            .map(|_| (0..LANES).map(|_| (0..world).map(|_| None).collect()).collect())
            .collect();
        let mut rxs: Vec<Vec<Vec<Option<Receiver<Message>>>>> = (0..world)
            .map(|_| (0..LANES).map(|_| (0..world).map(|_| None).collect()).collect())
            .collect();
        for lane in 0..LANES {
            for src in 0..world {
                for dst in 0..world {
                    let (tx, rx) = channel();
                    txs[src][lane][dst] = Some(tx);
                    rxs[dst][lane][src] = Some(rx);
                }
            }
        }
        let shared = Arc::new(Shared {
            world,
            reduce: Mutex::new(ReduceState {
                buf: Vec::new(),
                contribs: (0..world).map(|_| Vec::new()).collect(),
                writers: 0,
                readers: 0,
                write_gen: 0,
                reset_gen: 0,
            }),
            cv: Condvar::new(),
        });
        txs.into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_lanes, rx_lanes))| CommHandle {
                rank,
                world,
                senders: tx_lanes
                    .into_iter()
                    .map(|row| row.into_iter().map(Option::unwrap).collect())
                    .collect(),
                receivers: rx_lanes
                    .into_iter()
                    .map(|row| row.into_iter().map(Option::unwrap).collect())
                    .collect(),
                posted_seq: vec![0; LANES],
                completed_seq: vec![0; LANES],
                shared: Arc::clone(&shared),
                stats: CommStats::default(),
            })
            .collect()
    }
}

impl CommHandle {
    /// All-to-all: send `chunks[dst]` to each rank, receive one message
    /// from every rank (indexed by source). `chunks.len()` must equal
    /// `world`; the self-chunk short-circuits through the local channel
    /// (zero cost is the caller's accounting decision).
    pub fn all_to_all(&mut self, chunks: Vec<Message>) -> Vec<Message> {
        let pending = self.post_all_to_all_on(LANE_DEFAULT, chunks);
        self.complete_all_to_all(pending)
    }

    /// Non-blocking half of an all-to-all: enqueue every send on `lane`
    /// and return immediately. The matching
    /// [`complete_all_to_all`](Self::complete_all_to_all) call collects
    /// the receives. Posted exchanges on *different* lanes may be
    /// in flight simultaneously; on one lane they complete in post
    /// order (FIFO per peer pair) — every rank must post/complete in the
    /// same global order per lane, the usual collective discipline.
    pub fn post_all_to_all_on(&mut self, lane: usize, chunks: Vec<Message>) -> PendingAllToAll {
        assert_eq!(chunks.len(), self.world);
        assert!(lane < LANES, "lane {lane} out of range");
        let mut sent = 0u64;
        for (dst, m) in chunks.into_iter().enumerate() {
            if dst != self.rank {
                sent += m.bytes() as u64;
            }
            self.senders[lane][dst].send(m).expect("peer hung up");
        }
        self.stats.all_to_all_bytes += sent;
        self.stats.lane_bytes[lane] += sent;
        self.stats.all_to_all_ops += 1;
        let seq = self.posted_seq[lane];
        self.posted_seq[lane] += 1;
        PendingAllToAll { lane, seq }
    }

    /// Blocking half: receive one message from every rank on the posted
    /// exchange's lane (indexed by source). Panics if exchanges on one
    /// lane are completed out of post order (the FIFO lane would
    /// otherwise hand back the wrong exchange's payloads).
    pub fn complete_all_to_all(&mut self, pending: PendingAllToAll) -> Vec<Message> {
        let lane = pending.lane;
        assert_eq!(
            pending.seq, self.completed_seq[lane],
            "all-to-all on lane {lane} completed out of post order"
        );
        self.completed_seq[lane] += 1;
        (0..self.world)
            .map(|src| self.receivers[lane][src].recv().expect("peer hung up"))
            .collect()
    }

    /// Element-wise sum all-reduce over an f32 buffer (in place).
    pub fn all_reduce_sum(&mut self, data: &mut [f32]) {
        self.reduce_with(data, |acc, x| *acc += x);
        self.stats.all_reduce_bytes += (data.len() * 4) as u64;
        self.stats.all_reduce_ops += 1;
    }

    /// Element-wise max all-reduce (used e.g. for sync'ing clocks).
    pub fn all_reduce_max(&mut self, data: &mut [f32]) {
        self.reduce_with(data, |acc, x| {
            if x > *acc {
                *acc = x
            }
        });
        self.stats.all_reduce_bytes += (data.len() * 4) as u64;
        self.stats.all_reduce_ops += 1;
    }

    fn reduce_with(&self, data: &mut [f32], combine: impl Fn(&mut f32, f32)) {
        let sh = &self.shared;
        let mut st = sh.reduce.lock().unwrap();
        // Wait out any previous operation that hasn't fully reset.
        while st.writers != 0 && st.readers != 0 {
            st = sh.cv.wait(st).unwrap();
        }
        // Contribute. Contributions park in reusable per-rank buffers;
        // the completing writer folds them in rank order so the result
        // is independent of thread arrival order (bitwise determinism
        // across runs) with no steady-state allocation.
        {
            let contrib = &mut st.contribs[self.rank];
            contrib.clear();
            contrib.extend_from_slice(data);
        }
        st.writers += 1;
        if st.writers == sh.world {
            let ReduceState { buf, contribs, .. } = &mut *st;
            buf.clear();
            buf.extend_from_slice(&contribs[0]);
            for c in contribs.iter().skip(1) {
                assert_eq!(c.len(), buf.len(), "all_reduce length mismatch");
                for (acc, &x) in buf.iter_mut().zip(c.iter()) {
                    combine(acc, x);
                }
            }
            st.write_gen += 1;
            sh.cv.notify_all();
        } else {
            let gen = st.write_gen;
            while st.write_gen == gen {
                st = sh.cv.wait(st).unwrap();
            }
        }
        // Consume.
        data.copy_from_slice(&st.buf);
        st.readers += 1;
        if st.readers == sh.world {
            st.writers = 0;
            st.readers = 0;
            st.reset_gen += 1;
            sh.cv.notify_all();
        } else {
            let gen = st.reset_gen;
            while st.reset_gen == gen {
                st = sh.cv.wait(st).unwrap();
            }
        }
    }

    /// Synchronization barrier.
    pub fn barrier(&mut self) {
        let mut noop: [f32; 1] = [0.0];
        self.reduce_with(&mut noop, |_, _| {});
    }

    /// Broadcast `data` from `root` to all ranks (returns the root's
    /// message everywhere).
    pub fn broadcast(&mut self, root: usize, data: Message) -> Message {
        let chunks: Vec<Message> = (0..self.world)
            .map(|_dst| {
                if self.rank == root {
                    data.clone()
                } else {
                    Message::Empty
                }
            })
            .collect();
        let mut received = self.all_to_all(chunks);
        received.swap_remove(root)
    }

    /// All-gather: everyone contributes one message, everyone receives
    /// the full vector indexed by rank.
    pub fn all_gather(&mut self, data: Message) -> Vec<Message> {
        let chunks: Vec<Message> = (0..self.world).map(|_| data.clone()).collect();
        self.all_to_all(chunks)
    }

    /// All-gather of one u64 per rank (batch sizes for §5.1 weighted
    /// gradient averaging: "All-to-all communication to synchronize batch
    /// sizes across devices").
    pub fn all_gather_u64(&mut self, value: u64) -> Vec<u64> {
        self.all_gather(Message::Counts(vec![value]))
            .into_iter()
            .map(|m| m.into_counts()[0])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Run `f(rank, handle)` on `world` threads, returning per-rank results.
    pub fn run_group<T: Send + 'static>(
        world: usize,
        f: impl Fn(usize, &mut CommHandle) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let handles = CommGroup::new(world);
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for (rank, mut h) in handles.into_iter().enumerate() {
            let f = Arc::clone(&f);
            joins.push(thread::spawn(move || f(rank, &mut h)));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_to_all_routes_correctly() {
        let out = run_group(4, |rank, h| {
            // Send [rank, dst] to each dst.
            let chunks = (0..4)
                .map(|dst| Message::Ids(vec![rank as u64, dst as u64]))
                .collect();
            let recv = h.all_to_all(chunks);
            recv.into_iter().map(|m| m.into_ids()).collect::<Vec<_>>()
        });
        for (rank, recv) in out.iter().enumerate() {
            for (src, msg) in recv.iter().enumerate() {
                assert_eq!(msg, &vec![src as u64, rank as u64]);
            }
        }
    }

    #[test]
    fn all_reduce_sum_and_repeat() {
        let out = run_group(8, |rank, h| {
            let mut v = vec![rank as f32, 1.0];
            h.all_reduce_sum(&mut v);
            let first = v.clone();
            // Back-to-back second reduction must not interleave with the
            // first (epoch protocol).
            let mut w = vec![1.0f32];
            h.all_reduce_sum(&mut w);
            (first, w[0])
        });
        for (first, second) in out {
            assert_eq!(first, vec![28.0, 8.0]); // 0+..+7, 8×1
            assert_eq!(second, 8.0);
        }
    }

    #[test]
    fn all_reduce_max() {
        let out = run_group(5, |rank, h| {
            let mut v = vec![rank as f32 * if rank % 2 == 0 { 1.0 } else { -1.0 }];
            h.all_reduce_max(&mut v);
            v[0]
        });
        for v in out {
            assert_eq!(v, 4.0);
        }
    }

    #[test]
    fn broadcast_from_root() {
        let out = run_group(3, |rank, h| {
            let payload = if rank == 1 {
                Message::Floats(vec![3.5, 4.5])
            } else {
                Message::Empty
            };
            h.broadcast(1, payload).into_floats()
        });
        for v in out {
            assert_eq!(v, vec![3.5, 4.5]);
        }
    }

    #[test]
    fn all_gather_u64_batch_sizes() {
        let out = run_group(4, |rank, h| h.all_gather_u64(100 + rank as u64));
        for v in out {
            assert_eq!(v, vec![100, 101, 102, 103]);
        }
    }

    #[test]
    fn stats_count_bytes() {
        let out = run_group(2, |_rank, h| {
            let chunks = vec![
                Message::Ids(vec![1, 2, 3]),
                Message::Ids(vec![4]),
            ];
            let _ = h.all_to_all(chunks);
            let mut v = vec![0.0f32; 10];
            h.all_reduce_sum(&mut v);
            h.stats
        });
        for s in out {
            // One remote Ids message of len ≤3 → ≤24 bytes (self-chunk free).
            assert!(s.all_to_all_bytes == 8 || s.all_to_all_bytes == 24);
            assert_eq!(s.all_reduce_bytes, 40);
            assert_eq!(s.all_to_all_ops, 1);
            assert_eq!(s.all_reduce_ops, 1);
            // The default-lane meter carries the whole exchange; per-lane
            // meters always sum to the aggregate.
            assert_eq!(s.lane_bytes[LANE_DEFAULT], s.all_to_all_bytes);
            assert_eq!(s.lane_bytes.iter().sum::<u64>(), s.all_to_all_bytes);
        }
    }

    #[test]
    fn barrier_world_of_one() {
        let out = run_group(1, |_rank, h| {
            h.barrier();
            let mut v = vec![5.0f32];
            h.all_reduce_sum(&mut v);
            v[0]
        });
        assert_eq!(out, vec![5.0]);
    }

    #[test]
    fn posted_exchanges_overlap_across_lanes() {
        // Post an ID exchange, then run a full embedding exchange on a
        // different lane, then complete the first — the pattern the
        // two-phase pipelined lookup uses. Payloads must not cross lanes.
        let out = run_group(4, |rank, h| {
            let ids = (0..4)
                .map(|dst| Message::Ids(vec![rank as u64 * 10 + dst as u64]))
                .collect();
            let pending = h.post_all_to_all_on(LANE_IDS, ids);
            let floats = (0..4)
                .map(|dst| Message::Floats(vec![(rank * 4 + dst) as f32]))
                .collect();
            let emb_pending = h.post_all_to_all_on(LANE_EMB, floats);
            let emb: Vec<f32> = h
                .complete_all_to_all(emb_pending)
                .into_iter()
                .map(|m| m.into_floats()[0])
                .collect();
            let ids: Vec<u64> = h
                .complete_all_to_all(pending)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            (ids, emb)
        });
        for (rank, (ids, emb)) in out.iter().enumerate() {
            for src in 0..4 {
                assert_eq!(ids[src], src as u64 * 10 + rank as u64);
                assert_eq!(emb[src], (src * 4 + rank) as f32);
            }
        }
    }

    #[test]
    fn pipelined_rounds_on_one_lane_complete_in_post_order() {
        let out = run_group(2, |rank, h| {
            // Two exchanges posted back to back on the same lane, then
            // completed in order.
            let mk = |tag: u64| {
                (0..2)
                    .map(|dst| Message::Ids(vec![tag * 100 + rank as u64 * 10 + dst as u64]))
                    .collect::<Vec<_>>()
            };
            let p1 = h.post_all_to_all_on(LANE_IDS, mk(1));
            let p2 = h.post_all_to_all_on(LANE_IDS, mk(2));
            let r1: Vec<u64> = h
                .complete_all_to_all(p1)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            let r2: Vec<u64> = h
                .complete_all_to_all(p2)
                .into_iter()
                .map(|m| m.into_ids()[0])
                .collect();
            (r1, r2)
        });
        for (rank, (r1, r2)) in out.iter().enumerate() {
            for src in 0..2 {
                assert_eq!(r1[src], 100 + src as u64 * 10 + rank as u64);
                assert_eq!(r2[src], 200 + src as u64 * 10 + rank as u64);
            }
        }
    }

    #[test]
    fn many_rounds_stress() {
        let out = run_group(4, |rank, h| {
            let mut acc = 0.0f32;
            for round in 0..50 {
                let chunks = (0..4)
                    .map(|d| Message::Floats(vec![(rank * 4 + d + round) as f32]))
                    .collect();
                let recv = h.all_to_all(chunks);
                let mut v: Vec<f32> =
                    vec![recv.iter().map(|m| m.clone().into_floats()[0]).sum()];
                h.all_reduce_sum(&mut v);
                acc += v[0];
                h.barrier();
            }
            acc
        });
        // Every rank must compute the same total.
        for w in out.windows(2) {
            assert_eq!(w[0], w[1]);
        }
    }
}
