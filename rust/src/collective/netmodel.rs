//! Analytic network cost model for the simulated cluster.
//!
//! Parameterized to the paper's testbed (§6.1): 8 × A100 per node,
//! NVLink 600 GB/s within a node, InfiniBand 200 GB/s across nodes. Every
//! data exchange in a simulated run is charged `latency + bytes/bandwidth`
//! on the slowest participating link; collectives take the max over
//! participants (synchronous training is gated by the slowest device —
//! the same effect that makes sequence balancing matter).

/// Link bandwidths/latencies for the simulated topology.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    pub gpus_per_node: usize,
    /// NVLink bandwidth, bytes/s (paper: 600 GB/s).
    pub intra_bw: f64,
    /// InfiniBand bandwidth, bytes/s (paper: 200 GB/s).
    pub inter_bw: f64,
    /// Per-message latencies, seconds.
    pub intra_lat: f64,
    pub inter_lat: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel {
            gpus_per_node: 8,
            intra_bw: 600.0e9,
            inter_bw: 200.0e9,
            intra_lat: 3.0e-6,
            inter_lat: 10.0e-6,
        }
    }
}

impl NetModel {
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Point-to-point transfer time between two ranks.
    pub fn p2p_time(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        // Zero bytes means "no message": no latency charged.
        if src == dst || bytes == 0 {
            return 0.0;
        }
        if self.node_of(src) == self.node_of(dst) {
            self.intra_lat + bytes as f64 / self.intra_bw
        } else {
            self.inter_lat + bytes as f64 / self.inter_bw
        }
    }

    /// All-to-all time given the full send matrix `bytes[src][dst]`.
    ///
    /// Each rank serializes its sends over its NIC/NVLink ports but
    /// intra- and inter-node traffic use separate fabrics, so the
    /// per-rank time is `max(intra serialized, inter serialized)`; the
    /// collective completes when the slowest rank does. Receive-side
    /// congestion is modeled symmetrically.
    pub fn all_to_all_time(&self, bytes: &[Vec<usize>]) -> f64 {
        let world = bytes.len();
        let mut worst: f64 = 0.0;
        for r in 0..world {
            // Send side.
            let (mut intra_s, mut inter_s) = (0.0, 0.0);
            // Receive side.
            let (mut intra_r, mut inter_r) = (0.0, 0.0);
            for peer in 0..world {
                if peer == r {
                    continue;
                }
                let t_s = self.p2p_time(r, peer, bytes[r][peer]);
                let t_r = self.p2p_time(peer, r, bytes[peer][r]);
                if self.node_of(peer) == self.node_of(r) {
                    intra_s += t_s;
                    intra_r += t_r;
                } else {
                    inter_s += t_s;
                    inter_r += t_r;
                }
            }
            worst = worst
                .max(intra_s.max(inter_s))
                .max(intra_r.max(inter_r));
        }
        worst
    }

    /// Uniform all-to-all: every rank sends `bytes_per_pair` to every
    /// other rank.
    pub fn all_to_all_uniform_time(&self, world: usize, bytes_per_pair: usize) -> f64 {
        let matrix: Vec<Vec<usize>> = (0..world)
            .map(|r| {
                (0..world)
                    .map(|d| if d == r { 0 } else { bytes_per_pair })
                    .collect()
            })
            .collect();
        self.all_to_all_time(&matrix)
    }

    /// Ring all-reduce time for `bytes` per rank across `world` ranks:
    /// `2·(n−1)/n · bytes / bottleneck_bw + 2·(n−1)·latency`.
    pub fn all_reduce_time(&self, world: usize, bytes: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let n = world as f64;
        let multi_node = world > self.gpus_per_node;
        let (bw, lat) = if multi_node {
            (self.inter_bw, self.inter_lat)
        } else {
            (self.intra_bw, self.intra_lat)
        };
        2.0 * (n - 1.0) / n * bytes as f64 / bw + 2.0 * (n - 1.0) * lat
    }

    /// Broadcast (tree) time.
    pub fn broadcast_time(&self, world: usize, bytes: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let hops = (world as f64).log2().ceil();
        let multi_node = world > self.gpus_per_node;
        let (bw, lat) = if multi_node {
            (self.inter_bw, self.inter_lat)
        } else {
            (self.intra_bw, self.intra_lat)
        };
        hops * (lat + bytes as f64 / bw)
    }

    /// Time for one rank to push a `bytes`-sized delta snapshot to the
    /// serving fleet (online training → serving sync). Serving lives
    /// off-cluster behind the inter-node fabric, and its ingest link is
    /// shared by all `world` ranks pushing their shards concurrently,
    /// so the effective per-rank bandwidth is `inter_bw / world`. Zero
    /// bytes means "nothing changed this interval": no push, no
    /// latency.
    pub fn delta_sync_time(&self, world: usize, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.inter_lat + bytes as f64 * world.max(1) as f64 / self.inter_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_intra_vs_inter() {
        let m = NetModel::default();
        let bytes = 600_000_000; // 0.6 GB
        let intra = m.p2p_time(0, 1, bytes);
        let inter = m.p2p_time(0, 8, bytes); // ranks 0 and 8 are on different nodes
        assert!(intra < inter, "NVLink must beat IB");
        assert!((intra - (3e-6 + 0.001)).abs() < 1e-6);
        assert!((inter - (10e-6 + 0.003)).abs() < 1e-6);
        assert_eq!(m.p2p_time(3, 3, bytes), 0.0);
    }

    #[test]
    fn all_to_all_single_node_scales_with_bytes() {
        let m = NetModel::default();
        // Bandwidth-dominated sizes so the ratio approaches 2.
        let t1 = m.all_to_all_uniform_time(8, 100_000_000);
        let t2 = m.all_to_all_uniform_time(8, 200_000_000);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
    }

    #[test]
    fn all_to_all_multi_node_slower_than_single() {
        let m = NetModel::default();
        // Same aggregate bytes per rank, spread over 16 ranks on 2 nodes
        // vs 8 ranks on 1 node.
        let single = m.all_to_all_uniform_time(8, 1_000_000);
        let multi = m.all_to_all_uniform_time(16, 1_000_000);
        assert!(multi > single, "IB hop must dominate");
    }

    #[test]
    fn all_to_all_skewed_matrix_gated_by_hotspot() {
        let m = NetModel::default();
        let world = 4;
        let mut bytes = vec![vec![0usize; world]; world];
        bytes[2][0] = 50_000_000; // one hot sender
        let t = m.all_to_all_time(&bytes);
        assert!((t - m.p2p_time(2, 0, 50_000_000)).abs() < 1e-12);
    }

    #[test]
    fn all_reduce_time_properties() {
        let m = NetModel::default();
        assert_eq!(m.all_reduce_time(1, 1_000_000), 0.0);
        let t8 = m.all_reduce_time(8, 100_000_000);
        let t128 = m.all_reduce_time(128, 100_000_000);
        // Multi-node all-reduce is bottlenecked by IB.
        assert!(t128 > t8);
        // Bandwidth term: 2·(7/8)·0.1GB / 600GB/s ≈ 0.29 ms (+latency).
        assert!(t8 > 0.00029 && t8 < 0.00035, "t8={t8}");
    }

    #[test]
    fn delta_sync_scales_with_bytes_and_world() {
        let m = NetModel::default();
        assert_eq!(m.delta_sync_time(8, 0), 0.0, "empty delta costs nothing");
        let t1 = m.delta_sync_time(8, 100_000_000);
        let t2 = m.delta_sync_time(8, 200_000_000);
        assert!(t2 > t1, "more bytes, more time");
        let wide = m.delta_sync_time(64, 100_000_000);
        assert!(wide > t1, "shared ingest link contended by more ranks");
    }

    #[test]
    fn broadcast_log_hops() {
        let m = NetModel::default();
        let t2 = m.broadcast_time(2, 1_000_000);
        let t8 = m.broadcast_time(8, 1_000_000);
        assert!((t8 / t2 - 3.0).abs() < 1e-9, "log2(8)/log2(2) = 3");
    }
}
