//! Simulated-cluster collectives.
//!
//! Each "GPU" is a worker thread; [`comm`] provides the in-process
//! communicator (all-to-all over per-pair channels, shared-state
//! all-reduce/barrier/broadcast — the NCCL substitute), and [`netmodel`]
//! the analytic network cost model (NVLink 600 GB/s intra-node, InfiniBand
//! 200 GB/s inter-node, per the paper's testbed) used to charge simulated
//! communication time to every exchange.

pub mod comm;
pub mod netmodel;

pub use comm::{CommGroup, CommHandle, Message, PendingAllToAll};
pub use netmodel::NetModel;
