//! Cluster collectives.
//!
//! [`comm`] provides the communicator (all-to-all over per-pair FIFO
//! lanes, rank-order-deterministic all-reduce/barrier/broadcast — the
//! NCCL substitute). A handle is backed either by in-process channels
//! (each "GPU" a worker thread, [`CommGroup::new`]) or by a
//! [`comm::RemoteTransport`] connecting real worker processes
//! ([`CommHandle::from_remote`]; the UDS mesh lives in
//! [`crate::dist::transport`]). [`netmodel`] is the analytic network
//! cost model (NVLink 600 GB/s intra-node, InfiniBand 200 GB/s
//! inter-node, per the paper's testbed) used to charge simulated
//! communication time to every exchange.

pub mod comm;
pub mod netmodel;

pub use comm::{CommGroup, CommHandle, Message, PendingAllToAll, RemoteTransport};
pub use netmodel::NetModel;
