//! Statistics helpers used by metrics, the workload generator, and the
//! bench harness: summary statistics, percentiles, online (Welford)
//! accumulation and fixed-bucket histograms.

/// Summary of a sample: n, mean, std, min, max, percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Percentile (linear interpolation) over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile over an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Welford online mean/variance accumulator — used for streaming metrics
/// (per-phase times over thousands of steps) without storing samples.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket histogram over `[lo, hi)` with saturating edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            total: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        let k = self.buckets.len();
        let idx = if x < self.lo {
            0
        } else if x >= self.hi {
            k - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * k as f64) as usize
        };
        self.buckets[idx.min(k - 1)] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Render a compact ASCII sparkline of the distribution — used in
    /// bench output to show e.g. the token-count distribution per GPU.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&b| BARS[(b * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
        assert_eq!(percentile(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn welford_matches_direct() {
        let mut rng = crate::util::rng::Xoshiro256::new(11);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal(3.0, 2.0)).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let mut rng = crate::util::rng::Xoshiro256::new(13);
        let xs: Vec<f64> = (0..1000).map(|_| rng.next_f64()).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.add(x);
            if i % 3 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_and_saturation() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-5.0); // below lo → first bucket
        h.add(99.0); // above hi → last bucket
        assert_eq!(h.total(), 12);
        assert_eq!(h.buckets()[0], 2);
        assert_eq!(h.buckets()[9], 2);
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
