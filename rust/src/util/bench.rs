//! Micro-benchmark harness (the offline registry has no `criterion`).
//!
//! Cargo benches in `rust/benches/` are built with `harness = false` and
//! drive this module directly. It provides:
//! - [`bench_fn`]: warmup + timed iterations with mean/p50/p99 reporting,
//! - [`Table`]: aligned text tables matching the paper's table/figure rows,
//! - [`BenchReport`]: JSON output (one file per experiment) so
//!   EXPERIMENTS.md numbers are regenerable and diffable.

use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// Result of a micro-benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall-clock seconds.
    pub summary: Summary,
}

impl BenchResult {
    pub fn ns_per_iter(&self) -> f64 {
        self.summary.mean * 1e9
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", self.name.as_str().into()),
            ("iters", self.iters.into()),
            ("mean_ns", (self.summary.mean * 1e9).into()),
            ("p50_ns", (self.summary.p50 * 1e9).into()),
            ("p99_ns", (self.summary.p99 * 1e9).into()),
            ("min_ns", (self.summary.min * 1e9).into()),
            ("max_ns", (self.summary.max * 1e9).into()),
        ])
    }
}

/// Run `f` for `warmup` untimed and `iters` timed iterations.
///
/// `f` receives the iteration index; use `std::hint::black_box` inside to
/// defeat dead-code elimination.
pub fn bench_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut(usize)) -> BenchResult {
    assert!(iters > 0);
    for i in 0..warmup {
        f(i);
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        f(i);
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        summary: Summary::of(&samples),
    };
    eprintln!(
        "  bench {:<40} {:>12.1} ns/iter (p50 {:.1}, p99 {:.1}, n={})",
        r.name,
        r.ns_per_iter(),
        r.summary.p50 * 1e9,
        r.summary.p99 * 1e9,
        iters
    );
    r
}

/// Aligned text table for printing paper-style result rows.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("title", self.title.as_str().into()),
            (
                "header",
                Json::Arr(self.header.iter().map(|h| h.as_str().into()).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| c.as_str().into()).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// A bench report: tables + free-form metrics, dumped as JSON under
/// `bench_results/` and printed to stdout.
#[derive(Debug, Default)]
pub struct BenchReport {
    pub experiment: String,
    pub tables: Vec<Table>,
    pub metrics: Vec<(String, Json)>,
}

impl BenchReport {
    pub fn new(experiment: &str) -> Self {
        BenchReport {
            experiment: experiment.to_string(),
            ..Default::default()
        }
    }

    pub fn add_table(&mut self, t: Table) {
        println!("{}", t.render());
        self.tables.push(t);
    }

    pub fn add_metric(&mut self, key: &str, value: Json) {
        println!("metric {key} = {value}");
        self.metrics.push((key.to_string(), value));
    }

    /// Write `bench_results/<experiment>.json` (creating the directory).
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let mut obj = Json::obj();
        obj.set("experiment", self.experiment.as_str().into());
        obj.set(
            "tables",
            Json::Arr(self.tables.iter().map(|t| t.to_json()).collect()),
        );
        let mut metrics = Json::obj();
        for (k, v) in &self.metrics {
            metrics.set(k, v.clone());
        }
        obj.set("metrics", metrics);
        let path = dir.join(format!("{}.json", self.experiment));
        std::fs::write(&path, obj.pretty())?;
        println!("saved {}", path.display());
        Ok(path)
    }
}

/// Format a throughput-style ratio as the paper does ("1.75x").
pub fn ratio(new: f64, base: f64) -> String {
    format!("{:.2}x", new / base)
}

/// Format a percent gain ("+26.5%").
pub fn pct_gain(new: f64, base: f64) -> String {
    format!("{:+.1}%", 100.0 * (new - base) / base)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_runs_and_reports() {
        let mut count = 0usize;
        let r = bench_fn("noop", 2, 10, |_| {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, 12); // warmup + iters
        assert_eq!(r.iters, 10);
        assert!(r.summary.mean >= 0.0);
        let j = r.to_json();
        assert_eq!(j.get("iters").as_usize(), Some(10));
    }

    #[test]
    fn table_render_aligned() {
        let mut t = Table::new("demo", &["config", "thpt", "gain"]);
        t.row(&["4G-1D".into(), "579649".into(), "1.60x".into()]);
        t.row(&["110G-64D".into(), "38575".into(), "2.44x".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("579649"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(ratio(240.0, 100.0), "2.40x");
        assert_eq!(pct_gain(126.5, 100.0), "+26.5%");
        assert_eq!(pct_gain(90.0, 100.0), "-10.0%");
    }
}
