//! Tiny CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `program <subcommand> --key value --flag positional...`.
//! Unknown keys are rejected when validated against a declared spec.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, bare `--flag`
/// switches and positional arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_flags` lists the `--x` switches that take no value; everything
    /// else starting with `--` is treated as `--key value`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if known_flags.contains(&key) {
                    out.flags.push(key.to_string());
                } else if let Some(v) = it.next() {
                    out.options.insert(key.to_string(), v);
                } else {
                    // Trailing --key with no value: treat as flag.
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got `{v}`"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positional() {
        let a = Args::parse(
            sv(&[
                "train", "--steps", "100", "--verbose", "--lr", "0.001", "fileA",
            ]),
            &["verbose"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!((a.get_f64("lr", 0.0) - 0.001).abs() < 1e-12);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, sv(&["fileA"]));
    }

    #[test]
    fn defaults_when_missing() {
        let a = Args::parse(sv(&["sim"]), &[]);
        assert_eq!(a.get_usize("gpus", 8), 8);
        assert_eq!(a.get_or("mode", "full"), "full");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn trailing_key_becomes_flag() {
        let a = Args::parse(sv(&["x", "--dangling"]), &[]);
        assert!(a.has_flag("dangling"));
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        let a = Args::parse(sv(&["t", "--steps", "abc"]), &[]);
        a.get_usize("steps", 0);
    }
}
