//! CRC-32 (IEEE) integrity footers for checkpoint row files.
//!
//! Every sparse shard / delta shard / dense blob written by the
//! checkpoint layer is *sealed*: the payload is followed by an 8-byte
//! footer `[crc32(payload) u32 LE][b"MTCR"]`. Loaders verify the magic
//! and the checksum before parsing, so a truncated file, a torn write
//! (killed mid-`fs::write`) or a flipped bit is a loud, named error
//! instead of silently corrupt embedding state. The footer lives at the
//! **end** of the file on purpose: a torn write that loses the tail
//! loses the footer too, which is exactly the failure the supervisor's
//! recovery scan must detect.

use anyhow::{bail, Result};

/// Footer magic. Distinguishes "sealed but corrupt" from "not a sealed
/// file at all" in error messages.
pub const SEAL_MAGIC: [u8; 4] = *b"MTCR";
/// Footer length in bytes: crc u32 LE + magic.
pub const SEAL_LEN: usize = 8;

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = make_table();

/// IEEE CRC-32 (the zlib/gzip polynomial, reflected).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append the integrity footer to `bytes` in place and return it.
pub fn seal(mut bytes: Vec<u8>) -> Vec<u8> {
    let crc = crc32(&bytes);
    bytes.extend_from_slice(&crc.to_le_bytes());
    bytes.extend_from_slice(&SEAL_MAGIC);
    bytes
}

/// Verify and strip the footer, returning the payload (truncated in
/// place — no copy). Errors name the specific failure: too short,
/// missing magic (not a sealed file / footer torn off), or checksum
/// mismatch (bit rot or a mid-file torn write).
pub fn unseal_vec(mut bytes: Vec<u8>) -> Result<Vec<u8>> {
    if bytes.len() < SEAL_LEN {
        bail!(
            "sealed file too short: {} bytes < {SEAL_LEN}-byte integrity footer (truncated?)",
            bytes.len()
        );
    }
    let body_len = bytes.len() - SEAL_LEN;
    if bytes[body_len + 4..] != SEAL_MAGIC {
        bail!("integrity footer magic missing (file truncated or not a sealed checkpoint file)");
    }
    let stored = u32::from_le_bytes([
        bytes[body_len],
        bytes[body_len + 1],
        bytes[body_len + 2],
        bytes[body_len + 3],
    ]);
    let actual = crc32(&bytes[..body_len]);
    if stored != actual {
        bail!("CRC32 mismatch: stored {stored:#010x}, computed {actual:#010x} (corrupt or torn file)");
    }
    bytes.truncate(body_len);
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_crc_vectors() {
        // Standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn seal_roundtrip() {
        for payload in [&b""[..], &b"x"[..], &[0u8; 1000][..], b"hello world"] {
            let sealed = seal(payload.to_vec());
            assert_eq!(sealed.len(), payload.len() + SEAL_LEN);
            let body = unseal_vec(sealed).unwrap();
            assert_eq!(body, payload);
        }
    }

    #[test]
    fn truncation_and_magic_and_crc_failures_are_loud() {
        let sealed = seal(vec![7u8; 64]);

        let mut torn = sealed.clone();
        torn.truncate(5);
        let err = unseal_vec(torn).unwrap_err().to_string();
        assert!(err.contains("too short"), "{err}");

        let mut tail_cut = sealed.clone();
        tail_cut.truncate(sealed.len() - 3);
        let err = unseal_vec(tail_cut).unwrap_err().to_string();
        assert!(err.contains("magic"), "losing footer tail breaks magic: {err}");

        let mut flipped = sealed.clone();
        flipped[10] ^= 0x40;
        let err = unseal_vec(flipped).unwrap_err().to_string();
        assert!(err.contains("CRC32 mismatch"), "{err}");
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        // CRC-32 detects all 1-bit errors; walk every bit of a small
        // sealed file (body + footer) and assert each flip is caught.
        let sealed = seal((0u8..48).collect::<Vec<u8>>());
        for byte in 0..sealed.len() {
            for bit in 0..8 {
                let mut bad = sealed.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    unseal_vec(bad).is_err(),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }
}
