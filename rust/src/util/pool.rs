//! Deterministic shared worker pool for the sparse hot paths.
//!
//! A [`WorkerPool`] owns `threads - 1` persistent worker threads; the
//! calling thread is always the `threads`-th participant, so
//! `WorkerPool::new(1)` degenerates to pure inline execution with zero
//! synchronization. Work is distributed by **static chunking** over
//! index ranges — there is no work stealing and no randomized
//! scheduling, so:
//!
//! - [`WorkerPool::parallel_map`] returns results in index order no
//!   matter which thread computed which chunk;
//! - chunk boundaries are a pure function of `(len, chunk count)`, so
//!   any rank-ordered reduction over per-chunk results is bitwise
//!   reproducible run to run;
//! - callers that need bit-identity *across thread counts* (the e2e
//!   determinism suite runs `--threads {1,4}`) arrange their work so
//!   either the chunking cannot affect the result (disjoint writes,
//!   per-row accumulation) or the chunk count is fixed independently of
//!   `threads` — both patterns live in [`crate::embedding::dedup`].
//!
//! Scoped borrows: tasks may capture non-`'static` references. This is
//! sound because [`WorkerPool::run_scope`] never returns (even by
//! panic) until every submitted task has finished executing, mirroring
//! `std::thread::scope`. Blocked scopes *help*: while waiting they
//! drain pending tasks from the shared queue, so nested
//! `parallel_for` calls from inside a pool task cannot deadlock even
//! on a single-worker pool.
//!
//! Panics inside tasks are caught, the scope still waits for its
//! remaining tasks, and the first panic payload is re-raised on the
//! caller — the same contract as `std::thread::scope`.
//!
//! **Fair sharing.** One process-wide pool serves every trainer worker:
//! [`WorkerPool::fair_share`] returns a cheap *view* onto the same
//! worker threads whose [`threads()`](WorkerPool::threads) — and thus
//! every chunk count — is the caller's deterministic share
//! (`⌈threads / participants⌉`, a pure function of the two numbers, so
//! chunking never depends on runtime racing). `world` concurrent
//! `run_scope` callers therefore split one pool instead of
//! oversubscribing the host with `world × threads` threads; the shared
//! queue plus caller helping keeps every region deadlock-free. The
//! number of *actual* thread pools alive in the process is observable
//! via [`WorkerPool::live_pool_count`] (views don't count; the
//! one-pool-per-training-process invariant is asserted by
//! `tests/global_pool.rs`).

use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Live thread-pool cores in this process (views excluded).
static LIVE_POOLS: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of [`LIVE_POOLS`] since the last reset.
static PEAK_POOLS: AtomicUsize = AtomicUsize::new(0);

/// A task queued for the pool, tagged with its scope so completion can
/// be signalled.
struct QueuedTask {
    f: Box<dyn FnOnce() + Send>,
    scope: Arc<ScopeSync>,
}

/// Per-`run_scope` completion state.
struct ScopeSync {
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct PoolState {
    queue: VecDeque<QueuedTask>,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Signals both "new task available" and "a scope finished a task".
    cv: Condvar,
}

impl PoolInner {
    /// Run one task, recording a panic in its scope, then decrement the
    /// scope's counter and wake any waiters.
    fn execute(&self, task: QueuedTask) {
        let QueuedTask { f, scope } = task;
        if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
            let mut slot = scope.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        // Hold the lock while signalling so a waiter cannot observe
        // `remaining > 0`, miss the decrement, and sleep forever.
        let _guard = self.state.lock().unwrap();
        scope.remaining.fetch_sub(1, Ordering::Release);
        self.cv.notify_all();
    }
}

/// The actual thread pool: persistent workers plus the shared queue.
/// [`WorkerPool`] values are views onto one of these; the workers shut
/// down when the last view drops.
struct PoolCore {
    inner: Arc<PoolInner>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        LIVE_POOLS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A fixed-size pool of persistent worker threads with scoped,
/// deterministic fork/join helpers — or a fair-share *view* onto one
/// (see [`fair_share`](WorkerPool::fair_share)). See the module docs
/// for the determinism contract.
pub struct WorkerPool {
    core: Arc<PoolCore>,
    /// Threads this view assumes for chunk counts and inline fast
    /// paths; equals the core's thread count for a full view.
    share: usize,
}

impl WorkerPool {
    /// A pool where `threads` threads participate in every parallel
    /// region: this caller plus `threads - 1` spawned workers.
    /// `threads` is clamped to at least 1.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn pool worker")
            })
            .collect();
        let live = LIVE_POOLS.fetch_add(1, Ordering::Relaxed) + 1;
        PEAK_POOLS.fetch_max(live, Ordering::Relaxed);
        WorkerPool {
            core: Arc::new(PoolCore {
                inner,
                workers,
                threads,
            }),
            share: threads,
        }
    }

    /// A pool sized to the machine (`std::thread::available_parallelism`).
    pub fn with_available_parallelism() -> Self {
        WorkerPool::new(Self::machine_threads())
    }

    /// `std::thread::available_parallelism` with a 1 fallback.
    pub fn machine_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Resolve a `--threads` CLI value: 0 means "size to the machine".
    pub fn resolve_threads(threads: usize) -> usize {
        if threads == 0 {
            Self::machine_threads()
        } else {
            threads
        }
    }

    /// A deterministic fair-share view for one of `participants`
    /// concurrent callers: same workers, same queue, but chunk counts
    /// (and the inline fast path) assume `⌈threads / participants⌉`
    /// threads — a pure function of the two numbers, so chunk
    /// boundaries stay independent of scheduling. Dropping a view never
    /// stops the workers; the core shuts down with its last view.
    pub fn fair_share(&self, participants: usize) -> WorkerPool {
        WorkerPool {
            core: Arc::clone(&self.core),
            share: self.core.threads.div_ceil(participants.max(1)).max(1),
        }
    }

    /// Thread-pool cores currently alive in this process (fair-share
    /// views excluded). The trainer must keep this at one.
    pub fn live_pool_count() -> usize {
        LIVE_POOLS.load(Ordering::Relaxed)
    }

    /// High-water mark of [`live_pool_count`](Self::live_pool_count)
    /// since [`reset_peak_pool_count`](Self::reset_peak_pool_count).
    pub fn peak_pool_count() -> usize {
        PEAK_POOLS.load(Ordering::Relaxed)
    }

    pub fn reset_peak_pool_count() {
        PEAK_POOLS.store(LIVE_POOLS.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Number of threads this view assumes in parallel regions (the
    /// fair share for shared views; callers + workers for full pools).
    pub fn threads(&self) -> usize {
        self.share
    }

    /// Threads owned by the underlying pool core (views report the full
    /// size here, their share via [`threads()`](Self::threads)).
    pub fn pool_threads(&self) -> usize {
        self.core.threads
    }

    /// Stable chunk boundaries: split `0..len` into at most `chunks`
    /// contiguous ranges, a pure function of `(len, chunks)`.
    pub fn chunk_ranges(len: usize, chunks: usize) -> Vec<Range<usize>> {
        if len == 0 {
            return Vec::new();
        }
        let chunks = chunks.clamp(1, len);
        (0..chunks)
            .map(|c| (c * len / chunks)..((c + 1) * len / chunks))
            .collect()
    }

    /// Execute every task, blocking until all complete; tasks may
    /// borrow from the caller's stack. The first panicking task's
    /// payload is re-raised here after all tasks have finished.
    pub fn run_scope<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        // Inline fast path: single participant, or a single task —
        // nothing to coordinate.
        if self.share == 1 || tasks.len() == 1 {
            for f in tasks {
                f();
            }
            return;
        }
        let scope = Arc::new(ScopeSync {
            remaining: AtomicUsize::new(tasks.len()),
            panic: Mutex::new(None),
        });
        let mut tasks = tasks.into_iter();
        // The caller keeps the first task for itself; the rest go to
        // the shared queue.
        let mine = tasks.next().unwrap();
        {
            let mut st = self.core.inner.state.lock().unwrap();
            for f in tasks {
                // SAFETY: lifetime erasure to put borrowed closures in
                // the 'static queue. `run_scope` does not return until
                // `scope.remaining == 0`, i.e. until every erased task
                // has finished running, so no borrow outlives its
                // referent (same argument as std::thread::scope).
                let f = unsafe { erase_task_lifetime(f) };
                st.queue.push_back(QueuedTask {
                    f,
                    scope: Arc::clone(&scope),
                });
            }
            self.core.inner.cv.notify_all();
        }
        // Run our own share inline (still counted in `remaining`).
        // SAFETY: as above — this scope blocks until the task has run.
        let mine = unsafe { erase_task_lifetime(mine) };
        self.core.inner.execute(QueuedTask {
            f: mine,
            scope: Arc::clone(&scope),
        });
        // Wait for the rest, helping drain the queue: a blocked scope
        // executing other pending tasks (possibly from a nested
        // parallel region or another fair-share caller) is what makes
        // nesting — and concurrent shared-pool scopes — deadlock-free.
        let mut st = self.core.inner.state.lock().unwrap();
        loop {
            if scope.remaining.load(Ordering::Acquire) == 0 {
                break;
            }
            if let Some(task) = st.queue.pop_front() {
                drop(st);
                self.core.inner.execute(task);
                st = self.core.inner.state.lock().unwrap();
            } else {
                st = self.core.inner.cv.wait(st).unwrap();
            }
        }
        drop(st);
        if let Some(payload) = scope.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Run `f` over stable chunks of `0..len`, one task per chunk (at
    /// most `threads()` chunks). Blocks until every chunk completes.
    pub fn parallel_for(&self, len: usize, f: impl Fn(Range<usize>) + Sync + Send) {
        if len == 0 {
            return;
        }
        if self.share == 1 {
            f(0..len);
            return;
        }
        let f = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Self::chunk_ranges(len, self.share)
            .into_iter()
            .map(|r| Box::new(move || f(r)) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        self.run_scope(tasks);
    }

    /// Map `f` over `0..len`; the output is in index order regardless
    /// of scheduling (each chunk writes its own contiguous slot range).
    pub fn parallel_map<T: Send>(
        &self,
        len: usize,
        f: impl Fn(usize) -> T + Sync + Send,
    ) -> Vec<T> {
        if len == 0 {
            return Vec::new();
        }
        if self.share == 1 {
            return (0..len).map(f).collect();
        }
        let mut out: Vec<Option<T>> = (0..len).map(|_| None).collect();
        {
            let f = &f;
            let mut rest: &mut [Option<T>] = &mut out;
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut prev_end = 0usize;
            for r in Self::chunk_ranges(len, self.share) {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.end - prev_end);
                rest = tail;
                prev_end = r.end;
                tasks.push(Box::new(move || {
                    for (slot, i) in chunk.iter_mut().zip(r) {
                        *slot = Some(f(i));
                    }
                }));
            }
            self.run_scope(tasks);
        }
        out.into_iter().map(|s| s.expect("chunk completed")).collect()
    }

    /// Run `f` over stable chunks of `items`, handing each task the
    /// matching disjoint sub-slice of `data` (`data.len()` must be
    /// `items * stride`; chunk `a..b` receives `data[a*stride..b*stride]`).
    /// The workhorse for chunked row kernels (gather, fetch, expand).
    pub fn parallel_for_chunks_mut<T: Send>(
        &self,
        data: &mut [T],
        items: usize,
        stride: usize,
        f: impl Fn(Range<usize>, &mut [T]) + Sync + Send,
    ) {
        self.parallel_for_ranges_mut(data, stride, &Self::chunk_ranges(items, self.share), f);
    }

    /// [`parallel_for_chunks_mut`](Self::parallel_for_chunks_mut) with
    /// **caller-supplied** boundaries: `ranges` must partition
    /// `0..data.len()/stride` contiguously in order (asserted). Use this
    /// when downstream logic depends on the exact boundaries (e.g. the
    /// sorted-dedup run merge), so the split cannot drift from the
    /// caller's bookkeeping.
    pub fn parallel_for_ranges_mut<T: Send>(
        &self,
        data: &mut [T],
        stride: usize,
        ranges: &[Range<usize>],
        f: impl Fn(Range<usize>, &mut [T]) + Sync + Send,
    ) {
        let items = ranges.last().map(|r| r.end).unwrap_or(0);
        assert_eq!(data.len(), items * stride, "ranges must cover data");
        let mut prev_end = 0usize;
        for r in ranges {
            assert_eq!(r.start, prev_end, "ranges must be contiguous from 0");
            prev_end = r.end;
        }
        if ranges.is_empty() {
            return;
        }
        if self.share == 1 || ranges.len() == 1 {
            let mut rest: &mut [T] = data;
            let mut prev_end = 0usize;
            for r in ranges {
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((r.end - prev_end) * stride);
                rest = tail;
                prev_end = r.end;
                f(r.clone(), chunk);
            }
            return;
        }
        let f = &f;
        let mut rest: &mut [T] = data;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        let mut prev_end = 0usize;
        for r in ranges {
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut((r.end - prev_end) * stride);
            rest = tail;
            prev_end = r.end;
            let r = r.clone();
            tasks.push(Box::new(move || f(r, chunk)));
        }
        self.run_scope(tasks);
    }
}

/// Erase a scoped task's lifetime so it can sit in the pool's `'static`
/// queue.
///
/// # Safety
/// The caller must not return (even by unwinding) until the task has
/// finished executing — [`WorkerPool::run_scope`] guarantees this by
/// waiting for `ScopeSync::remaining` to reach zero before returning or
/// re-raising a panic.
unsafe fn erase_task_lifetime<'scope>(
    f: Box<dyn FnOnce() + Send + 'scope>,
) -> Box<dyn FnOnce() + Send + 'static> {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Box<dyn FnOnce() + Send + 'static>>(f)
}

/// Shared write window over a mutable slice for scoped tasks that write
/// provably disjoint regions — scattered by index, which `split_at_mut`
/// cannot express (e.g. stripe-bucketed row writes in
/// [`crate::embedding::concurrent::ConcurrentDynamicTable`]).
pub struct SharedSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for SharedSliceMut<'_, T> {}

impl<'a, T> SharedSliceMut<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        SharedSliceMut {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            _marker: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Carve out `[start, start + len)` as a mutable sub-slice.
    ///
    /// # Safety
    /// Concurrent callers must slice pairwise-disjoint windows, and no
    /// other access to the underlying slice may occur while any window
    /// is live (guaranteed when all windows live inside one
    /// [`WorkerPool::run_scope`] region over disjoint indices).
    #[allow(clippy::mut_from_ref)] // deliberate: disjointness is the caller's contract
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        assert!(
            start.checked_add(len).is_some_and(|end| end <= self.len),
            "window {start}+{len} out of bounds (len {})",
            self.len
        );
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

fn worker_loop(inner: &PoolInner) {
    let mut st = inner.state.lock().unwrap();
    loop {
        if let Some(task) = st.queue.pop_front() {
            drop(st);
            inner.execute(task);
            st = inner.state.lock().unwrap();
        } else if st.shutdown {
            return;
        } else {
            st = inner.cv.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_index_order() {
        for threads in [1, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let out = pool.parallel_map(1000, |i| i * 3);
            assert_eq!(out, (0..1000).map(|i| i * 3).collect::<Vec<_>>(), "{threads} threads");
        }
    }

    #[test]
    fn parallel_map_deterministic_across_runs_and_threads() {
        let reference = WorkerPool::new(1).parallel_map(513, |i| (i as u64).wrapping_mul(0x9E37));
        for _ in 0..20 {
            let pool = WorkerPool::new(4);
            assert_eq!(
                pool.parallel_map(513, |i| (i as u64).wrapping_mul(0x9E37)),
                reference
            );
        }
    }

    #[test]
    fn parallel_for_covers_every_index_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(257, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunk_ranges_are_stable_and_cover() {
        let rs = WorkerPool::chunk_ranges(10, 4);
        assert_eq!(rs, WorkerPool::chunk_ranges(10, 4), "pure function");
        let total: usize = rs.iter().map(|r| r.len()).sum();
        assert_eq!(total, 10);
        for w in rs.windows(2) {
            assert_eq!(w[0].end, w[1].start, "contiguous");
        }
        assert!(WorkerPool::chunk_ranges(3, 16).len() <= 3, "no empty chunks");
        assert!(WorkerPool::chunk_ranges(0, 4).is_empty());
    }

    #[test]
    fn chunks_mut_slices_are_disjoint_and_aligned() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u32; 11 * 3];
        pool.parallel_for_chunks_mut(&mut data, 11, 3, |r, chunk| {
            assert_eq!(chunk.len(), r.len() * 3);
            for (j, item) in r.clone().enumerate() {
                for k in 0..3 {
                    chunk[j * 3 + k] = (item * 3 + k) as u32;
                }
            }
        });
        assert_eq!(data, (0..33).collect::<Vec<u32>>());
    }

    #[test]
    fn nested_parallel_regions_do_not_deadlock() {
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            let out = pool.parallel_map(8, |i| {
                // Inner region issued from inside a pool task.
                pool.parallel_map(8, |j| i * 8 + j).iter().sum::<usize>()
            });
            let expect: Vec<usize> = (0..8).map(|i| (0..8).map(|j| i * 8 + j).sum()).collect();
            assert_eq!(out, expect, "{threads} threads");
        }
    }

    #[test]
    fn scoped_borrows_of_caller_stack() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..100).collect();
        let sums = Mutex::new(0u64);
        pool.parallel_for(data.len(), |r| {
            let s: u64 = data[r].iter().sum();
            *sums.lock().unwrap() += s;
        });
        assert_eq!(*sums.lock().unwrap(), 4950);
    }

    #[test]
    fn panic_in_task_propagates_after_scope_completes() {
        let pool = WorkerPool::new(4);
        let completed: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(64, |range| {
                for i in range {
                    if i == 13 {
                        panic!("boom at {i}");
                    }
                    completed[i].fetch_add(1, Ordering::Relaxed);
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // The pool must still be fully usable afterwards.
        let out = pool.parallel_map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let order = Mutex::new(Vec::new());
        pool.parallel_for(5, |r| {
            for i in r {
                order.lock().unwrap().push(i);
            }
        });
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fair_share_views_split_deterministically() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        assert_eq!(pool.pool_threads(), 4);
        let half = pool.fair_share(2);
        assert_eq!(half.threads(), 2, "4 threads / 2 participants");
        assert_eq!(half.pool_threads(), 4, "same core");
        assert_eq!(pool.fair_share(3).threads(), 2, "ceil(4/3)");
        assert_eq!(pool.fair_share(8).threads(), 1, "never below 1");
        assert_eq!(pool.fair_share(0).threads(), 4, "0 participants clamps");
        // A share view computes the same results as the full pool.
        let full = pool.parallel_map(257, |i| i as u64 * 17);
        assert_eq!(half.parallel_map(257, |i| i as u64 * 17), full);
        // share == 1 runs inline (deterministic order) on the same core.
        let one = pool.fair_share(4);
        let order = Mutex::new(Vec::new());
        one.parallel_for(5, |r| {
            for i in r {
                order.lock().unwrap().push(i);
            }
        });
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn concurrent_fair_share_callers_share_one_queue() {
        // `world` threads hammer fair-share views of one pool at once;
        // every caller gets exact results (no lost or duplicated tasks).
        // share = ⌈4/2⌉ = 2 > 1, so every caller genuinely queues tasks
        // on the shared core rather than taking the inline fast path.
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for w in 0..4u64 {
            let view = pool.fair_share(2);
            joins.push(std::thread::spawn(move || {
                let mut ok = true;
                for round in 0..50u64 {
                    let out = view.parallel_map(97, |i| i as u64 + w * 1000 + round);
                    ok &= out
                        .iter()
                        .enumerate()
                        .all(|(i, &v)| v == i as u64 + w * 1000 + round);
                }
                ok
            }));
        }
        for j in joins {
            assert!(j.join().unwrap());
        }
    }

    #[test]
    fn resolve_threads_zero_is_machine() {
        assert_eq!(WorkerPool::resolve_threads(3), 3);
        let m = WorkerPool::resolve_threads(0);
        assert!(m >= 1);
        assert_eq!(m, WorkerPool::machine_threads());
    }

    #[test]
    fn zero_len_is_a_noop() {
        let pool = WorkerPool::new(4);
        pool.parallel_for(0, |_| panic!("must not run"));
        assert!(pool.parallel_map(0, |i| i).is_empty());
        let mut empty: Vec<f32> = Vec::new();
        pool.parallel_for_chunks_mut(&mut empty, 0, 8, |_, _| panic!("must not run"));
    }
}
