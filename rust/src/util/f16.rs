//! Software IEEE-754 binary16 ("half") conversion.
//!
//! Mixed-precision training (§5.2) stores *cold* embedding rows in FP16 to
//! halve their memory footprint and communication volume while *hot* rows
//! stay FP32. The CPU PJRT backend computes in f32, so we reproduce the
//! paper's mixed precision at the storage/communication layer: rows
//! round-trip through these conversions, which applies exactly the
//! quantization the paper's FP16 storage applies.

/// Convert f32 → f16 bits with round-to-nearest-even, handling subnormals,
/// infinities and NaN.
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN: keep a mantissa bit for NaN.
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m;
    }

    // Re-bias exponent: f32 bias 127 → f16 bias 15.
    let unbiased = exp - 127;
    let half_exp = unbiased + 15;

    if half_exp >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if half_exp <= 0 {
        // Subnormal or underflow to zero.
        if half_exp < -10 {
            return sign;
        }
        // Add the implicit leading 1, then shift into subnormal position.
        let m = mant | 0x0080_0000;
        let shift = (14 - half_exp) as u32;
        let half_mant = m >> shift;
        // Round to nearest even.
        let round_bit = 1u32 << (shift - 1);
        if (m & round_bit) != 0 && ((m & (round_bit - 1)) != 0 || (half_mant & 1) != 0) {
            return sign | (half_mant as u16 + 1);
        }
        return sign | half_mant as u16;
    }

    let half_mant = (mant >> 13) as u16;
    let result = sign | ((half_exp as u16) << 10) | half_mant;
    // Round to nearest even on the 13 dropped bits.
    let round_bit = 0x0000_1000u32;
    if (mant & round_bit) != 0 && ((mant & (round_bit - 1)) != 0 || (half_mant & 1) != 0) {
        return result + 1; // carries propagate correctly into exponent
    }
    result
}

/// Convert f16 bits → f32 exactly.
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // Subnormal: value = mant × 2⁻²⁴. Normalize so the leading 1
            // lands on bit 10, giving biased f32 exponent 113 − shift.
            let shift = mant.leading_zeros() - 21;
            let m = ((mant << shift) & 0x03ff) << 13;
            let e = 113 - shift;
            sign | (e << 23) | m
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize an f32 through f16 and back (the "stored as FP16" effect).
#[inline]
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Quantize a slice in place.
pub fn quantize_f16_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = quantize_f16(*x);
    }
}

/// Pack a slice of f32 into f16 bit patterns (storage / wire format).
pub fn pack_f16(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16_bits(x)).collect()
}

/// Unpack f16 bit patterns into f32.
pub fn unpack_f16(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| f16_bits_to_f32(h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        // Values exactly representable in f16 must round-trip bit-exactly.
        for &v in &[
            0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099975586,
            6.103515625e-5, // smallest normal
            5.9604645e-8,   // smallest subnormal
        ] {
            assert_eq!(quantize_f16(v), v, "value {v}");
        }
    }

    #[test]
    fn special_values() {
        assert_eq!(quantize_f16(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize_f16(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(quantize_f16(f32::NAN).is_nan());
        // Overflow saturates to inf.
        assert_eq!(quantize_f16(1.0e6), f32::INFINITY);
        // Deep underflow flushes to zero with sign.
        assert_eq!(quantize_f16(1.0e-10), 0.0);
        assert_eq!(quantize_f16(-1.0e-10).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        // f16 has 11 significand bits → rel err ≤ 2^-11.
        let mut rng = crate::util::rng::Xoshiro256::new(2024);
        for _ in 0..10_000 {
            let x = (rng.next_f32() - 0.5) * 100.0;
            if x.abs() < 1e-3 {
                continue;
            }
            let q = quantize_f16(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16 (1 + 2^-10):
        // must round to even mantissa → 1.0.
        let x = 1.0 + 2.0_f32.powi(-11);
        assert_eq!(quantize_f16(x), 1.0);
        // 1 + 3·2^-11 is between (1+2^-10) and (1+2^-9): rounds up to even.
        let x = 1.0 + 3.0 * 2.0_f32.powi(-11);
        assert_eq!(quantize_f16(x), 1.0 + 2.0_f32.powi(-9));
    }

    #[test]
    fn pack_unpack_slice() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.37).collect();
        let packed = pack_f16(&xs);
        assert_eq!(packed.len(), xs.len());
        let back = unpack_f16(&packed);
        for (a, b) in xs.iter().zip(back.iter()) {
            assert_eq!(quantize_f16(*a), *b);
        }
    }

    #[test]
    fn rne_ties_and_mantissa_carry_into_exponent() {
        // 2 − 2^-11 ties between the largest f16 below 2 (mantissa
        // 0x3ff, odd) and 2.0 (mantissa 0, even): the tie rounds up and
        // the mantissa increment must carry into the exponent.
        assert_eq!(quantize_f16(2.0 - 2.0_f32.powi(-11)), 2.0);
        // Just below the tie stays on the lower neighbor.
        assert_eq!(
            quantize_f16(2.0 - 2.0_f32.powi(-11) - 2.0_f32.powi(-20)),
            2.0 - 2.0_f32.powi(-10)
        );
        // The same carry at the top of the range overflows to infinity:
        // 65504 is the largest finite f16 and its mantissa is odd, so
        // the halfway point 65520 rounds away — into the exponent, onto
        // inf.
        assert_eq!(quantize_f16(65520.0), f32::INFINITY);
        assert_eq!(quantize_f16(-65520.0), f32::NEG_INFINITY);
        // Just below the halfway point stays finite.
        assert_eq!(quantize_f16(65519.996), 65504.0);
    }

    #[test]
    fn subnormal_boundaries() {
        let min_sub = 2.0_f32.powi(-24); // smallest f16 subnormal
        let min_norm = 2.0_f32.powi(-14); // smallest f16 normal

        // Exactly half the smallest subnormal ties between ±0 and the
        // subnormal; zero has the even mantissa.
        assert_eq!(quantize_f16(min_sub / 2.0), 0.0);
        assert_eq!(quantize_f16(-min_sub / 2.0).to_bits(), (-0.0f32).to_bits());
        // A hair above the tie rounds away from zero.
        assert_eq!(
            quantize_f16(min_sub / 2.0 * (1.0 + 2.0_f32.powi(-20))),
            min_sub
        );
        // 1.5 × min_sub ties between mantissa 1 (odd) and 2 (even):
        // rounds to the even neighbor, 2 × min_sub.
        assert_eq!(quantize_f16(1.5 * min_sub), 2.0 * min_sub);
        // The subnormal→normal boundary: halfway between the largest
        // subnormal (mantissa 0x3ff) and the smallest normal (mantissa
        // 0, even) rounds up across the boundary.
        assert_eq!(quantize_f16(min_norm - 2.0_f32.powi(-25)), min_norm);
        assert_eq!(
            quantize_f16(min_norm - 2.0_f32.powi(-25) - 2.0_f32.powi(-34)),
            min_norm - min_sub
        );
        // Exact subnormals and the smallest normal are fixed points.
        for k in 1..=10u32 {
            let v = k as f32 * min_sub;
            assert_eq!(quantize_f16(v), v, "k={k}");
        }
        assert_eq!(quantize_f16(min_norm), min_norm);
    }

    #[test]
    fn nan_and_infinity_survive_packing() {
        let hs = pack_f16(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -f32::NAN]);
        let back = unpack_f16(&hs);
        assert!(back[0].is_nan());
        assert_eq!(back[1], f32::INFINITY);
        assert_eq!(back[2], f32::NEG_INFINITY);
        assert!(back[3].is_nan());
        // NaN keeps a mantissa bit so it cannot collapse into inf.
        assert_ne!(hs[0] & 0x03ff, 0);
    }

    #[test]
    fn slice_quantize_matches_scalar_and_is_idempotent() {
        // The slice path must equal the scalar path bit for bit over
        // every row dim the tables use (1..=67 covers odd dims, the 8D
        // context groups and the model dims), and quantizing an
        // already-quantized row must be the identity — the storage
        // invariant that lets re-quantization run on every write path.
        let mut rng = crate::util::rng::Xoshiro256::new(77);
        for dim in 1..=67usize {
            let xs: Vec<f32> = (0..dim)
                .map(|i| {
                    // Spread across normals, subnormals and huge values.
                    let base = (rng.next_f32() - 0.5) * 4.0;
                    base * 2.0_f32.powi((i as i32 % 41) - 20)
                })
                .collect();
            let mut slice = xs.clone();
            quantize_f16_slice(&mut slice);
            for (j, (&orig, &q)) in xs.iter().zip(&slice).enumerate() {
                assert_eq!(
                    q.to_bits(),
                    quantize_f16(orig).to_bits(),
                    "dim {dim} elem {j}"
                );
            }
            let mut twice = slice.clone();
            quantize_f16_slice(&mut twice);
            for (j, (&a, &b)) in slice.iter().zip(&twice).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "idempotence dim {dim} elem {j}");
            }
        }
    }

    #[test]
    fn matches_all_f16_bit_patterns() {
        // Exhaustive: every finite f16 bit pattern must survive
        // f16→f32→f16 exactly.
        for h in 0..=0xffffu16 {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(f)).is_nan());
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), h, "pattern {h:#06x} -> {f}");
        }
    }
}
