//! Wall-clock timers and per-phase time decomposition.
//!
//! The paper's Figure 12 decomposes each training step into *lookup*,
//! *forward* and *backward* phases; [`PhaseTimer`] accumulates wall-clock
//! time per named phase so the trainer can report that decomposition.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::stats::Welford;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates wall-clock time per named phase, with per-phase Welford
/// statistics over "laps" (training steps).
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: BTreeMap<String, Welford>,
    totals: BTreeMap<String, f64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and attribute it to `phase` (seconds).
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed().as_secs_f64());
        out
    }

    /// Record an externally measured duration (seconds) for `phase`.
    pub fn record(&mut self, phase: &str, seconds: f64) {
        self.phases
            .entry(phase.to_string())
            .or_insert_with(Welford::new)
            .add(seconds);
        *self.totals.entry(phase.to_string()).or_insert(0.0) += seconds;
    }

    /// Total accumulated seconds for `phase` (0.0 if never recorded).
    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).copied().unwrap_or(0.0)
    }

    /// Mean seconds per recorded lap for `phase`.
    pub fn mean(&self, phase: &str) -> f64 {
        self.phases.get(phase).map(|w| w.mean()).unwrap_or(0.0)
    }

    pub fn stats(&self, phase: &str) -> Option<&Welford> {
        self.phases.get(phase)
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, f64)> {
        self.totals.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Merge another timer's accumulation into this one (for cross-worker
    /// aggregation).
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, w) in &other.phases {
            self.phases
                .entry(k.clone())
                .or_insert_with(Welford::new)
                .merge(w);
        }
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    /// Human-readable decomposition table (sorted by total time desc).
    pub fn report(&self) -> String {
        let mut rows: Vec<(&str, f64)> = self.phases().collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let grand: f64 = rows.iter().map(|r| r.1).sum();
        let mut out = String::from(format!(
            "{:<24} {:>12} {:>10} {:>8}\n",
            "phase", "total(s)", "mean(ms)", "share"
        ));
        for (name, total) in rows {
            out.push_str(&format!(
                "{:<24} {:>12.4} {:>10.3} {:>7.1}%\n",
                name,
                total,
                self.mean(name) * 1e3,
                100.0 * total / grand.max(1e-12),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn phase_accumulation() {
        let mut pt = PhaseTimer::new();
        pt.record("lookup", 0.5);
        pt.record("lookup", 1.5);
        pt.record("forward", 1.0);
        assert!((pt.total("lookup") - 2.0).abs() < 1e-12);
        assert!((pt.mean("lookup") - 1.0).abs() < 1e-12);
        assert_eq!(pt.total("missing"), 0.0);
        let report = pt.report();
        assert!(report.contains("lookup"));
        assert!(report.contains("forward"));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut pt = PhaseTimer::new();
        let v = pt.time("work", || 42);
        assert_eq!(v, 42);
        assert!(pt.total("work") >= 0.0);
        assert_eq!(pt.stats("work").unwrap().count(), 1);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        let mut b = PhaseTimer::new();
        a.record("x", 1.0);
        b.record("x", 3.0);
        b.record("y", 2.0);
        a.merge(&b);
        assert!((a.total("x") - 4.0).abs() < 1e-12);
        assert!((a.total("y") - 2.0).abs() < 1e-12);
        assert_eq!(a.stats("x").unwrap().count(), 2);
    }
}
