//! Deterministic retry / timeout / backoff for transport sends.
//!
//! The distributed runtime retries transient transport failures (an
//! injected frame drop, a peer socket that is still binding) on an
//! exponential backoff schedule. The schedule is a **pure function** of
//! `(policy, attempt)` — the jitter comes from a splitmix64 hash of the
//! policy seed and the attempt index, not from a clock or a global RNG —
//! so two runs with the same policy wait the same milliseconds at every
//! attempt and test assertions on the schedule are exact.

use anyhow::{bail, Result};

/// Backoff schedule parameters. Delays grow exponentially from
/// `base_delay_ms`, are capped at `max_delay_ms`, and carry a
/// deterministic jitter (up to 25% shaved off) derived from `seed` so
/// concurrent retriers with different seeds desynchronize.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts (first try included). 0 is rejected by [`retry`].
    pub max_attempts: usize,
    pub base_delay_ms: u64,
    pub max_delay_ms: u64,
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            base_delay_ms: 2,
            max_delay_ms: 50,
            seed: 0x5EED,
        }
    }
}

/// splitmix64: the one-u64 mixer used everywhere else in the crate for
/// deterministic per-key randomness.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Milliseconds to wait after failed attempt `attempt` (0-based).
/// Exponential (`base << attempt`), capped at `max_delay_ms`, minus a
/// deterministic jitter of up to a quarter of the capped value. Pure in
/// `(policy, attempt)`.
pub fn backoff_delay_ms(policy: &RetryPolicy, attempt: usize) -> u64 {
    let shift = attempt.min(20) as u32;
    let raw = policy.base_delay_ms.saturating_mul(1u64 << shift);
    let capped = raw.min(policy.max_delay_ms);
    let jitter_span = capped / 4;
    let jitter = if jitter_span == 0 {
        0
    } else {
        splitmix64(policy.seed ^ attempt as u64) % (jitter_span + 1)
    };
    capped - jitter
}

/// Run `op` until it succeeds or `max_attempts` are exhausted, sleeping
/// the deterministic backoff between attempts. `op` receives the 0-based
/// attempt index. Returns the value and the number of **retries** (0
/// when the first attempt succeeded). Exhaustion is a loud error naming
/// `label`, the attempt count and the last failure.
pub fn retry<T, E: std::fmt::Display>(
    policy: &RetryPolicy,
    label: &str,
    mut op: impl FnMut(usize) -> Result<T, E>,
) -> Result<(T, u64)> {
    anyhow::ensure!(policy.max_attempts > 0, "retry `{label}`: zero attempts");
    let mut last_err = String::new();
    for attempt in 0..policy.max_attempts {
        match op(attempt) {
            Ok(v) => return Ok((v, attempt as u64)),
            Err(e) => last_err = e.to_string(),
        }
        if attempt + 1 < policy.max_attempts {
            let ms = backoff_delay_ms(policy, attempt);
            if ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
    }
    bail!(
        "retry `{label}` exhausted after {} attempts (last error: {last_err})",
        policy.max_attempts
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base_delay_ms: 8,
            max_delay_ms: 100,
            seed: 42,
        }
    }

    #[test]
    fn backoff_is_deterministic_and_exponential() {
        let p = policy();
        let a: Vec<u64> = (0..6).map(|i| backoff_delay_ms(&p, i)).collect();
        let b: Vec<u64> = (0..6).map(|i| backoff_delay_ms(&p, i)).collect();
        assert_eq!(a, b, "pure function of (policy, attempt)");
        // Jitter shaves at most a quarter, so the exponential floor
        // (3/4 of base << attempt, pre-cap) still orders the schedule.
        for (i, &ms) in a.iter().enumerate() {
            let raw = (8u64 << i.min(20)).min(100);
            assert!(ms <= raw, "attempt {i}: {ms} > raw {raw}");
            assert!(ms >= raw - raw / 4, "attempt {i}: {ms} under jitter floor");
        }
    }

    #[test]
    fn backoff_caps_at_max_delay() {
        let p = policy();
        for attempt in [10, 20, 40, 1000, usize::MAX] {
            assert!(backoff_delay_ms(&p, attempt) <= p.max_delay_ms);
        }
        // Degenerate policies must not overflow.
        let wild = RetryPolicy {
            max_attempts: 3,
            base_delay_ms: u64::MAX,
            max_delay_ms: 7,
            seed: 0,
        };
        assert!(backoff_delay_ms(&wild, usize::MAX) <= 7);
    }

    #[test]
    fn different_seeds_jitter_differently() {
        let a = RetryPolicy { seed: 1, ..policy() };
        let b = RetryPolicy { seed: 2, ..policy() };
        let sa: Vec<u64> = (0..8).map(|i| backoff_delay_ms(&a, i)).collect();
        let sb: Vec<u64> = (0..8).map(|i| backoff_delay_ms(&b, i)).collect();
        assert_ne!(sa, sb, "seeds desynchronize concurrent retriers");
    }

    #[test]
    fn retry_counts_retries_and_succeeds() {
        let p = RetryPolicy {
            base_delay_ms: 0,
            ..policy()
        };
        let (v, retries) =
            retry(&p, "test", |attempt| -> Result<usize, &'static str> {
                if attempt < 2 {
                    Err("transient")
                } else {
                    Ok(attempt * 10)
                }
            })
            .unwrap();
        assert_eq!(v, 20);
        assert_eq!(retries, 2);

        let (_, retries) =
            retry(&p, "first-try", |_| Ok::<_, &'static str>(1)).unwrap();
        assert_eq!(retries, 0, "no retries on first-attempt success");
    }

    #[test]
    fn retry_exhaustion_is_a_loud_error() {
        let p = RetryPolicy {
            max_attempts: 3,
            base_delay_ms: 0,
            max_delay_ms: 0,
            seed: 0,
        };
        let err = retry(&p, "doomed-send", |_| Err::<(), _>("net down"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("doomed-send"), "{err}");
        assert!(err.contains("3 attempts"), "{err}");
        assert!(err.contains("net down"), "last error surfaced: {err}");
    }
}
