//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so this module provides a
//! small, well-tested replacement: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse generator, plus the
//! distributions the synthetic Meituan workload needs (uniform, normal,
//! lognormal, Zipf) and Fisher–Yates shuffling.
//!
//! All generators are deterministic given a seed; every experiment in the
//! repository threads explicit seeds so runs are exactly reproducible.

/// SplitMix64: tiny, high-quality generator used to expand a single `u64`
/// seed into the 256-bit state of [`Xoshiro256`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, 256-bit state, passes BigCrush. The default RNG
/// for everything in this crate.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (the cached second value is
    /// deliberately dropped to keep the generator stateless w.r.t. calls).
    pub fn gauss(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Lognormal sample parameterized by the *underlying* normal's mu and
    /// sigma. Used for the long-tail user sequence-length distribution
    /// (paper §5.1: mean ≈ 600 tokens, max 3 000).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Xoshiro256 {
        Xoshiro256::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Zipf(α) sampler over `{0, .., n-1}` by inverse-CDF on a precomputed
/// table. Feature-ID popularity in recommendation logs is heavily skewed;
/// the duplicate-ID rates that make two-stage deduplication (§4.3) pay off
/// come from exactly this skew.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw a rank in `[0, n)`; rank 0 is the most popular.
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain
        // reference implementation).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_range_bounds_and_coverage() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Xoshiro256::new(99);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_positive_and_longtail() {
        let mut r = Xoshiro256::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.lognormal(6.0, 0.8)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // E[lognormal(6, .8)] = exp(6 + .32) ≈ 556
        assert!((mean - 556.0).abs() < 30.0, "mean {mean}");
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 3.0 * mean, "long tail expected, max {max} mean {mean}");
    }

    #[test]
    fn zipf_skew() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Xoshiro256::new(17);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Head rank should dominate a mid rank by a large factor.
        assert!(counts[0] > 20 * counts[100].max(1));
        // And everything is in range (implicitly checked by indexing).
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>(), "astronomically unlikely");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Xoshiro256::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
