//! Runtime-tunable performance thresholds.
//!
//! The parallel sparse kernels switch strategy by input size
//! (hash→sorted dedup, serial→parallel gather/scatter, per-id→striped
//! batch fetch). The crossover points are machine-dependent, so each
//! threshold is a [`TunableThreshold`]: the compiled-in constant is the
//! default, an environment variable overrides it at process start, and
//! [`TunableThreshold::set`] overrides it programmatically (used by the
//! `bench_parallel_lookup --calibrate` sweep to force each path and by
//! deployments that measured their own crossovers).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Once;

/// Calibrated default crossover points for every tunable threshold —
/// the single source of truth the kernel-side `*_THRESHOLD` constants
/// re-export. The values are the `bench_parallel_lookup --calibrate`
/// crossovers measured on the reference development box (8-core x86,
/// 4-thread pool); the sweep writes its machine-local measurements to
/// `calibration.json` so a deployment can compare and override via the
/// `MTGR_*_THRESHOLD` environment variables without recompiling.
pub mod calibrated {
    /// Occurrences above which sorted (pool-parallel) dedup beats the
    /// serial hash kernel (`MTGR_DEDUP_SORT_THRESHOLD`).
    pub const DEDUP_SORT: usize = 8192;
    /// Rows above which parallel gather/scatter beats the serial loops
    /// (`MTGR_PAR_ROWS_THRESHOLD`).
    pub const PAR_ROWS: usize = 2048;
    /// Occurrences above which the stripe-bucketed batch fetch beats
    /// per-id fetch (`MTGR_PAR_FETCH_THRESHOLD`).
    pub const PAR_FETCH: usize = 512;
    /// Dense parameter count above which pooled dense Adam beats the
    /// serial element loop (`MTGR_PAR_DENSE_THRESHOLD`).
    pub const PAR_DENSE: usize = 4096;
}

/// A `usize` knob with a compile-time default, a one-shot env override
/// and a programmatic setter. Reads are a relaxed atomic load after the
/// first access, so hot-path call sites stay branch-cheap.
pub struct TunableThreshold {
    value: AtomicUsize,
    init: Once,
    env: &'static str,
    default: usize,
}

impl TunableThreshold {
    pub const fn new(env: &'static str, default: usize) -> Self {
        TunableThreshold {
            value: AtomicUsize::new(0),
            init: Once::new(),
            env,
            default,
        }
    }

    fn ensure_init(&self) {
        self.init.call_once(|| {
            let v = std::env::var(self.env)
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .unwrap_or(self.default);
            self.value.store(v.max(1), Ordering::Relaxed);
        });
    }

    /// Current value (env override applied on first read; never 0).
    pub fn get(&self) -> usize {
        self.ensure_init();
        self.value.load(Ordering::Relaxed)
    }

    /// Override the value for this process (clamped to ≥ 1). Wins over
    /// the env var regardless of call order.
    pub fn set(&self, v: usize) {
        self.ensure_init();
        self.value.store(v.max(1), Ordering::Relaxed);
    }

    /// The compiled-in default.
    pub fn default_value(&self) -> usize {
        self.default
    }

    /// The environment variable consulted on first read.
    pub fn env_var(&self) -> &'static str {
        self.env
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Dedicated statics so these tests cannot race the kernels' live
    // thresholds (unit tests share one process).
    static T_DEFAULT: TunableThreshold =
        TunableThreshold::new("MTGR_TEST_THRESHOLD_UNSET", 4096);
    static T_SET: TunableThreshold = TunableThreshold::new("MTGR_TEST_THRESHOLD_SET", 64);

    #[test]
    fn default_when_env_unset() {
        assert_eq!(T_DEFAULT.get(), 4096);
        assert_eq!(T_DEFAULT.default_value(), 4096);
        assert_eq!(T_DEFAULT.env_var(), "MTGR_TEST_THRESHOLD_UNSET");
    }

    #[test]
    fn set_overrides_and_clamps() {
        assert_eq!(T_SET.get(), 64);
        T_SET.set(10);
        assert_eq!(T_SET.get(), 10);
        T_SET.set(0);
        assert_eq!(T_SET.get(), 1, "clamped to 1");
        T_SET.set(64);
    }
}
