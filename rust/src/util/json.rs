//! Minimal JSON parser/writer (the offline registry has no `serde`).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`),
//! checkpoint metadata, experiment configuration files and bench report
//! output. Supports the full JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors -----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
    }

    /// Expect helpers used by manifest parsing: fail loudly with the key
    /// name instead of silently defaulting.
    pub fn expect_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid numeric field `{key}`"))
    }

    pub fn expect_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid string field `{key}`"))
    }

    pub fn expect_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid array field `{key}`"))
    }

    // ---- parsing -------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            while self.pos < self.bytes.len()
                && self.bytes[self.pos] != b'"'
                && self.bytes[self.pos] != b'\\'
            {
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| self.err("invalid utf-8"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("invalid \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("invalid \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").get("d").as_bool(), Some(true));
        assert_eq!(v.get("s").as_str(), Some("x\"y\n"));
        // Round-trip through compact form.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        // And pretty form.
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        let j = Json::from(123usize);
        assert_eq!(j.to_string(), "123");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).pretty(), "[]");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v, Json::Str("é中".to_string()));
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(*v.get("zz"), Json::Null);
        assert!(v.expect_usize("zz").is_err());
        assert_eq!(v.expect_usize("a").unwrap(), 1);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", "hstu".into());
        o.set("dims", vec![1usize, 2, 3].into());
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("dims").as_arr().unwrap().len(), 3);
    }
}
