//! Small self-contained substrates that the offline crate registry cannot
//! provide: seeded RNG (`rand` replacement), JSON (`serde_json`
//! replacement), software half floats (`half` replacement), statistics
//! helpers, timers, a micro-benchmark harness (`criterion` replacement)
//! and a CLI argument parser (`clap` replacement).

pub mod bench;
pub mod cli;
pub mod f16;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;
