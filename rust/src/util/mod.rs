//! Small self-contained substrates that the offline crate registry cannot
//! provide: seeded RNG (`rand` replacement), JSON (`serde_json`
//! replacement), software half floats (`half` replacement), statistics
//! helpers, timers, a micro-benchmark harness (`criterion` replacement),
//! a CLI argument parser (`clap` replacement), a deterministic scoped
//! worker pool (`rayon` replacement for the sparse hot paths),
//! runtime-tunable performance thresholds (`tuning`), deterministic
//! retry/backoff for transport sends (`retry`) and CRC-32 integrity
//! footers for checkpoint files (`crc32`).

pub mod bench;
pub mod cli;
pub mod crc32;
pub mod f16;
pub mod json;
pub mod pool;
pub mod retry;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod tuning;
