//! Sparse-embedding subsystem — the paper's §4 contribution.
//!
//! - [`hash`] — MurmurHash3 (the paper's chosen hash, §4.1).
//! - [`dynamic_table`] — the dynamic hash embedding table: decoupled
//!   key/embedding storage, grouped parallel probing (Eq. 5), power-of-two
//!   capacity expansion migrating keys only, dual-chunk value allocation,
//!   LRU/LFU eviction metadata.
//! - [`static_table`] — TorchRec-style fixed-capacity baseline.
//! - [`mch`] — TorchRec Managed Collision Handling baseline (Table 3).
//! - [`merge`] — automatic table merging: `FeatureConfig`,
//!   `HashTableCollection`, Eq. 8 bit-packed global IDs.
//! - [`dedup`] — two-stage ID deduplication (§4.3).
//! - [`sharded`] — model-parallel sharded lookup over the communicator
//!   (two all-to-alls per lookup, gradient all-to-all on backward);
//!   FP16-compresses cold-row replies and gradient pushes when the
//!   store's precision policy is enabled.
//! - [`precision`] — hot/cold FP32/FP16 mixed-precision policy (§5.2).
//!   Composes orthogonally with the other store layers: the policy
//!   lives inside [`concurrent::ConcurrentDynamicTable`] (per
//!   `MergePlan` dim group), the online admission gate wraps it
//!   unchanged, and consumers discover it through the
//!   `precision_policy`/`row_is_hot` trait hooks below.

pub mod concurrent;
pub mod dedup;
pub mod sharded;
pub mod dynamic_table;
pub mod hash;
pub mod mch;
pub mod merge;
pub mod precision;
pub mod static_table;

/// A feature ID as it appears in the raw log (per-table local ID).
pub type FeatureId = u64;

/// A globally unique ID after table merging (Eq. 8 bit packing).
pub type GlobalId = u64;

/// Common interface over embedding stores so the trainer, benches and
/// baselines (static / MCH / dynamic) are interchangeable.
pub trait EmbeddingStore {
    /// Embedding dimensionality of every row in this store.
    fn dim(&self) -> usize;

    /// Number of live rows.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `id`, inserting a freshly initialized row if absent
    /// (training-time semantics: unseen IDs get new embeddings).
    /// Writes the row into `out` (length `dim()`), returns `true` if the
    /// row already existed.
    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool;

    /// Look up without inserting (eval-time semantics). Returns `false`
    /// and writes the store's default row when absent.
    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool;

    /// Apply an additive update to the row for `id` (optimizer delta).
    /// Returns `false` if the id is not present (update dropped).
    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool;

    /// Batched lookup: write the row for `ids[i]` into
    /// `out[i*dim..(i+1)*dim]`. `train` selects insert-on-miss
    /// semantics. The default is the serial per-id loop; stores with
    /// interior synchronization (lock-striped tables) override it to
    /// fan out across `pool` — contents must stay identical to the
    /// serial path for every pool size.
    fn fetch_rows(
        &mut self,
        ids: &[GlobalId],
        train: bool,
        out: &mut [f32],
        pool: Option<&crate::util::pool::WorkerPool>,
    ) {
        let d = self.dim();
        assert_eq!(out.len(), ids.len() * d);
        let _ = pool; // exclusive stores cannot parallelize
        for (row, &id) in out.chunks_exact_mut(d).zip(ids) {
            if train {
                self.lookup_or_insert(id, row);
            } else {
                self.lookup(id, row);
            }
        }
    }

    /// Approximate resident bytes (key + value + metadata structures).
    fn memory_bytes(&self) -> usize;

    /// The mixed-precision policy composed into this store. Default:
    /// pure FP32 (policy-free stores need no changes). The sharded
    /// exchange keys its FP16 wire compression off `enabled`.
    fn precision_policy(&self) -> precision::PrecisionPolicy {
        precision::PrecisionPolicy::fp32()
    }

    /// Post-bump hot/cold classification for one row; `None` when the
    /// row is absent or the store carries no policy. Side-effect free
    /// (never bumps access metadata).
    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        let _ = id;
        None
    }
}

/// Shared-reference analogue of [`EmbeddingStore`] for stores that
/// sustain concurrent reader/writer traffic (Monolith-style collisionless
/// tables at production rates): every method takes `&self`, so one store
/// can serve stage-2 (server-side) lookups and sparse optimizer updates
/// from many simulated workers in parallel. Implementations must
/// synchronize internally — see
/// [`concurrent::ConcurrentDynamicTable`]'s lock striping.
pub trait ConcurrentEmbeddingStore: Send + Sync {
    /// Embedding dimensionality of every row in this store.
    fn dim(&self) -> usize;

    /// Number of live rows (a consistent snapshot, not a fenced total).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Training-time lookup: insert a freshly initialized row if absent.
    /// Returns `true` if the row already existed.
    fn lookup_or_insert(&self, id: GlobalId, out: &mut [f32]) -> bool;

    /// Read-only lookup; `false` and the default row when absent.
    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool;

    /// Additive update (optimizer delta); `false` if the id is absent.
    fn apply_delta(&self, id: GlobalId, delta: &[f32]) -> bool;

    /// Approximate resident bytes.
    fn memory_bytes(&self) -> usize;

    /// The mixed-precision policy composed into this store (see
    /// [`EmbeddingStore::precision_policy`]).
    fn precision_policy(&self) -> precision::PrecisionPolicy {
        precision::PrecisionPolicy::fp32()
    }

    /// Post-bump hot/cold classification (see
    /// [`EmbeddingStore::row_is_hot`]).
    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        let _ = id;
        None
    }
}
