//! Dynamic hash embedding table (§4.1) — the paper's replacement for
//! TorchRec's fixed-capacity static tables.
//!
//! Design points reproduced from the paper:
//!
//! - **Decoupled storage** (Fig. 6a): a compact *key structure* (key +
//!   pointer slots, open addressing) separate from the *embedding
//!   structure* (chunked value storage with per-row eviction metadata —
//!   access counters and timestamps for LRU/LFU).
//! - **Chunk-based allocation**: embedding rows are bulk-allocated in
//!   fixed-size chunks, reducing fragmentation and enabling single-op
//!   retirement; a *current* and a pre-allocated *next* chunk are
//!   maintained at all times (Fig. 6c) so new rows never wait on
//!   allocation.
//! - **MurmurHash3** (§4.1) maps IDs to slots.
//! - **Grouped parallel probing** (Eq. 5):
//!   `S = ((k % (M/threads − 1) + 1) | 1) * threads`, with thread group
//!   `g` probing `h_t = h0 + g + t·S (mod M)`. For `threads = 1` this is
//!   classic odd-step probing, and Theorem 1 (odd S ⟺ full coverage of a
//!   power-of-two table) holds — tested below as a property.
//! - **Capacity expansion** (Fig. 6c): when the load factor exceeds 0.75
//!   the key structure doubles (power-of-two progression) and *only keys
//!   and pointers migrate*; embedding chunks are never moved. The
//!   savings vs. moving values are tracked in [`TableStats`].

use crate::embedding::hash::hash_id;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::rng::Xoshiro256;

/// Sentinel: slot never used.
const EMPTY: u64 = u64::MAX;
/// Sentinel: slot deleted (probe chains must continue through it).
const TOMBSTONE: u64 = u64::MAX - 1;

/// Eviction policy for cold rows (§4.1: "auxiliary metadata (e.g.
/// counters and timestamps) required for eviction policies like Least
/// Recently Used and Least Frequently Used").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Evict the least-recently-accessed row.
    Lru,
    /// Evict the least-frequently-accessed row.
    Lfu,
}

/// Configuration for a [`DynamicEmbeddingTable`].
#[derive(Clone, Debug)]
pub struct DynamicTableConfig {
    /// Embedding dimensionality.
    pub dim: usize,
    /// Initial key-structure capacity (rounded up to a power of two).
    pub initial_capacity: usize,
    /// Load factor that triggers key-structure expansion (paper: 0.75).
    pub max_load_factor: f64,
    /// Rows per embedding chunk (bulk allocation unit).
    pub chunk_rows: usize,
    /// `threads` in Eq. 5 — the number of probing thread groups.
    pub probe_groups: u64,
    /// Hash seed.
    pub seed: u64,
    /// Optional row budget; inserts beyond it trigger eviction.
    pub max_rows: Option<usize>,
    pub eviction: EvictionPolicy,
    /// Std-dev scale for row init: N(0, init_scale/sqrt(dim)).
    pub init_scale: f32,
}

impl DynamicTableConfig {
    pub fn new(dim: usize) -> Self {
        DynamicTableConfig {
            dim,
            initial_capacity: 1024,
            max_load_factor: 0.75,
            chunk_rows: 4096,
            probe_groups: 4,
            seed: 0x5EED,
            max_rows: None,
            eviction: EvictionPolicy::Lru,
            init_scale: 1.0,
        }
    }

    pub fn with_capacity(mut self, cap: usize) -> Self {
        self.initial_capacity = cap;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_max_rows(mut self, rows: usize) -> Self {
        self.max_rows = Some(rows);
        self
    }

    pub fn with_eviction(mut self, policy: EvictionPolicy) -> Self {
        self.eviction = policy;
        self
    }

    pub fn with_probe_groups(mut self, g: u64) -> Self {
        self.probe_groups = g;
        self
    }

    pub fn with_chunk_rows(mut self, rows: usize) -> Self {
        self.chunk_rows = rows;
        self
    }
}

/// Key-structure slot: key + pointer into the embedding structure.
/// (Fig. 6b: pointers are recovered as `st_add + index*row_offset +
/// pointer_offset`; in safe Rust the same arithmetic is an index pair.)
#[derive(Clone, Copy, Debug)]
struct Slot {
    key: u64,
    /// Packed row pointer: high 24 bits chunk index, low 40 bits row.
    ptr: u64,
}

#[inline]
fn pack_ptr(chunk: usize, row: usize) -> u64 {
    ((chunk as u64) << 40) | row as u64
}

#[inline]
fn unpack_ptr(ptr: u64) -> (usize, usize) {
    ((ptr >> 40) as usize, (ptr & ((1u64 << 40) - 1)) as usize)
}

/// Per-row metadata in the embedding structure (counter + timestamp, the
/// eviction inputs the paper stores alongside values).
#[derive(Clone, Copy, Debug, Default)]
struct RowMeta {
    key: u64,
    access_count: u32,
    last_access: u64,
    live: bool,
}

/// A bulk-allocated chunk of embedding rows.
struct Chunk {
    values: Vec<f32>,
    meta: Vec<RowMeta>,
    /// Next unallocated row in this chunk.
    next_row: usize,
    rows: usize,
}

impl Chunk {
    fn new(rows: usize, dim: usize) -> Self {
        Chunk {
            values: vec![0.0; rows * dim],
            meta: vec![RowMeta::default(); rows],
            next_row: 0,
            rows,
        }
    }

    fn full(&self) -> bool {
        self.next_row == self.rows
    }
}

/// Cumulative statistics (expansion savings, probe behaviour, evictions).
#[derive(Clone, Copy, Debug, Default)]
pub struct TableStats {
    pub inserts: u64,
    pub hits: u64,
    pub misses: u64,
    pub probes: u64,
    pub expansions: u64,
    /// Bytes actually moved during expansions (key structure only).
    pub expansion_bytes_moved: u64,
    /// Bytes a static-table redistribution would have moved (values).
    pub expansion_bytes_avoided: u64,
    pub evictions: u64,
}

impl TableStats {
    /// Fold another stats snapshot into this one (stripe / shard
    /// aggregation).
    pub fn merge(&mut self, other: &TableStats) {
        self.inserts += other.inserts;
        self.hits += other.hits;
        self.misses += other.misses;
        self.probes += other.probes;
        self.expansions += other.expansions;
        self.expansion_bytes_moved += other.expansion_bytes_moved;
        self.expansion_bytes_avoided += other.expansion_bytes_avoided;
        self.evictions += other.evictions;
    }
}

/// The dynamic hash embedding table.
pub struct DynamicEmbeddingTable {
    cfg: DynamicTableConfig,
    slots: Vec<Slot>,
    /// Number of live keys (excludes tombstones).
    live: usize,
    /// Number of tombstones (for load-factor accounting).
    tombstones: usize,
    chunks: Vec<Chunk>,
    /// Index of the chunk currently receiving new rows. A pre-allocated
    /// "next" chunk always exists at `active + 1` (dual-chunk design).
    active: usize,
    /// Logical clock for LRU timestamps.
    clock: u64,
    /// Default row returned by `lookup` for absent ids.
    default_row: Vec<f32>,
    pub stats: TableStats,
}

impl DynamicEmbeddingTable {
    pub fn new(cfg: DynamicTableConfig) -> Self {
        assert!(cfg.dim > 0);
        assert!(cfg.chunk_rows > 0);
        assert!(cfg.probe_groups >= 1);
        assert!(
            cfg.max_load_factor > 0.0 && cfg.max_load_factor < 1.0,
            "load factor must be in (0,1)"
        );
        let cap = cfg.initial_capacity.next_power_of_two().max(16);
        // Eq. 5 needs M/threads − 1 ≥ 1.
        assert!(
            cap as u64 / cfg.probe_groups >= 2,
            "capacity too small for probe_groups"
        );
        let mut t = DynamicEmbeddingTable {
            slots: vec![Slot { key: EMPTY, ptr: 0 }; cap],
            live: 0,
            tombstones: 0,
            chunks: vec![
                Chunk::new(cfg.chunk_rows, cfg.dim),
                Chunk::new(cfg.chunk_rows, cfg.dim), // pre-allocated "next"
            ],
            active: 0,
            clock: 0,
            default_row: vec![0.0; cfg.dim],
            stats: TableStats::default(),
            cfg,
        };
        t.cfg.initial_capacity = cap;
        t
    }

    /// Current key-structure capacity M (always a power of two).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Live-key load factor (tombstones included, as they lengthen probe
    /// chains just like live keys).
    pub fn load_factor(&self) -> f64 {
        (self.live + self.tombstones) as f64 / self.slots.len() as f64
    }

    /// Grouped parallel probing (Eq. 5). Returns the step size for `key`
    /// in a table of size `m` with `groups` thread groups.
    #[inline]
    pub fn probe_step(key: u64, m: u64, groups: u64) -> u64 {
        debug_assert!(m.is_power_of_two());
        debug_assert!(m / groups >= 2);
        ((key % (m / groups - 1) + 1) | 1) * groups
    }

    /// The probe sequence for `key`: thread group `g ∈ [0, groups)` probes
    /// `h0 + g + t·S (mod M)`; sequentially we interleave groups per round
    /// (`t`), matching the GPU's lockstep behaviour.
    #[inline]
    fn probe_seq(&self, key: u64) -> ProbeSeq {
        let m = self.slots.len() as u64;
        let groups = self.cfg.probe_groups.min(m / 2);
        ProbeSeq {
            h0: hash_id(key, self.cfg.seed) & (m - 1),
            step: Self::probe_step(key, m, groups),
            groups,
            mask: m - 1,
            t: 0,
            g: 0,
        }
    }

    /// Find the slot index holding `key`, or None.
    fn find(&self, key: u64) -> Option<usize> {
        let mut seq = self.probe_seq(key);
        let max_probes = self.slots.len() as u64;
        for _ in 0..max_probes {
            let idx = seq.next_idx();
            let s = &self.slots[idx];
            if s.key == key {
                return Some(idx);
            }
            if s.key == EMPTY {
                return None;
            }
            // TOMBSTONE or other key: continue probing.
        }
        None
    }

    /// Find the insertion slot for `key`: an existing slot with the key,
    /// or the first EMPTY/TOMBSTONE position. Returns (idx, existed).
    fn find_insert(&mut self, key: u64) -> (usize, bool) {
        let mut seq = self.probe_seq(key);
        let mut first_free: Option<usize> = None;
        let max_probes = self.slots.len() as u64;
        for p in 0..max_probes {
            let idx = seq.next_idx();
            self.stats.probes += 1;
            match self.slots[idx].key {
                k if k == key => return (idx, true),
                EMPTY => {
                    return (first_free.unwrap_or(idx), false);
                }
                TOMBSTONE => {
                    if first_free.is_none() {
                        first_free = Some(idx);
                    }
                }
                _ => {}
            }
            // Guard against pathological fill (should be unreachable with
            // expansion at 0.75).
            debug_assert!(p < max_probes, "probe loop exhausted");
        }
        (
            first_free.expect("table full: expansion failed to trigger"),
            false,
        )
    }

    /// Deterministic row initialization: N(0, init_scale/√dim) seeded by
    /// the id, so a row's initial value is a pure function of (id, seed) —
    /// identical across shards, restarts and world sizes.
    fn init_row(&self, id: u64, out: &mut [f32]) {
        let mut rng = Xoshiro256::new(hash_id(id, self.cfg.seed ^ 0xD1CE));
        let scale = self.cfg.init_scale / (self.cfg.dim as f32).sqrt();
        for v in out.iter_mut() {
            *v = rng.gauss() as f32 * scale;
        }
    }

    /// Allocate a row in the embedding structure (dual-chunk scheme).
    fn alloc_row(&mut self, key: u64) -> (usize, usize) {
        if self.chunks[self.active].full() {
            // Retire the filled chunk; the pre-allocated next chunk
            // becomes current, and a fresh next chunk is allocated.
            self.active += 1;
            if self.active + 1 >= self.chunks.len() {
                self.chunks
                    .push(Chunk::new(self.cfg.chunk_rows, self.cfg.dim));
            }
        }
        let chunk_idx = self.active;
        let chunk = &mut self.chunks[chunk_idx];
        let row = chunk.next_row;
        chunk.next_row += 1;
        chunk.meta[row] = RowMeta {
            key,
            access_count: 0,
            last_access: self.clock,
            live: true,
        };
        (chunk_idx, row)
    }

    /// Double the key structure, migrating keys+pointers only (Fig. 6c).
    fn expand(&mut self) {
        let new_cap = (self.slots.len() * 2).next_power_of_two();
        let old = std::mem::replace(
            &mut self.slots,
            vec![Slot { key: EMPTY, ptr: 0 }; new_cap],
        );
        self.tombstones = 0;
        let migrated = self.live;
        self.live = 0;
        for s in old.iter() {
            if s.key != EMPTY && s.key != TOMBSTONE {
                // Re-probe in the doubled table; no value movement.
                let (idx, existed) = self.find_insert(s.key);
                debug_assert!(!existed);
                self.slots[idx] = *s;
                self.live += 1;
            }
        }
        self.stats.expansions += 1;
        self.stats.expansion_bytes_moved +=
            (migrated * std::mem::size_of::<Slot>()) as u64;
        // What a static-table re-layout would have moved: the values.
        self.stats.expansion_bytes_avoided +=
            (migrated * self.cfg.dim * std::mem::size_of::<f32>()) as u64;
    }

    fn maybe_expand(&mut self) {
        if self.load_factor() > self.cfg.max_load_factor {
            self.expand();
        }
    }

    /// Remove `id`. Returns true if it was present. The key slot becomes
    /// a tombstone; the row is marked dead (its chunk space is reclaimed
    /// only when the whole chunk retires, matching bulk deallocation).
    pub fn remove(&mut self, id: GlobalId) -> bool {
        match self.find(id) {
            Some(idx) => {
                let (c, r) = unpack_ptr(self.slots[idx].ptr);
                self.chunks[c].meta[r].live = false;
                self.slots[idx].key = TOMBSTONE;
                self.live -= 1;
                self.tombstones += 1;
                true
            }
            None => false,
        }
    }

    /// Evict one row according to the configured policy, using power-of-k
    /// choices sampling over live rows (an approximation of exact LRU/LFU,
    /// as production caches do). Returns the evicted id.
    pub fn evict_one(&mut self, rng: &mut Xoshiro256) -> Option<GlobalId> {
        if self.live == 0 {
            return None;
        }
        const SAMPLES: usize = 16;
        let mut best: Option<(u64, u64)> = None; // (key, score) — lower is colder
        let nslots = self.slots.len();
        let mut tries = 0;
        let mut found = 0;
        while found < SAMPLES && tries < nslots * 4 {
            tries += 1;
            let idx = rng.range_usize(0, nslots);
            let s = self.slots[idx];
            if s.key == EMPTY || s.key == TOMBSTONE {
                continue;
            }
            found += 1;
            let (c, r) = unpack_ptr(s.ptr);
            let meta = &self.chunks[c].meta[r];
            debug_assert_eq!(meta.key, s.key, "key/meta integrity");
            let score = match self.cfg.eviction {
                EvictionPolicy::Lru => meta.last_access,
                EvictionPolicy::Lfu => meta.access_count as u64,
            };
            if best.map(|(_, b)| score < b).unwrap_or(true) {
                best = Some((s.key, score));
            }
        }
        let (key, _) = best?;
        self.remove(key);
        self.stats.evictions += 1;
        Some(key)
    }

    /// Whether `id` currently has a live row (no metadata bump).
    pub fn contains(&self, id: GlobalId) -> bool {
        self.find(id).is_some()
    }

    /// Immutable access to a row's slice, if present.
    pub fn row(&self, id: GlobalId) -> Option<&[f32]> {
        let idx = self.find(id)?;
        let (c, r) = unpack_ptr(self.slots[idx].ptr);
        let d = self.cfg.dim;
        Some(&self.chunks[c].values[r * d..(r + 1) * d])
    }

    /// Mutable access to a row's slice, if present (bumps access meta).
    pub fn row_mut(&mut self, id: GlobalId) -> Option<&mut [f32]> {
        let idx = self.find(id)?;
        let (c, r) = unpack_ptr(self.slots[idx].ptr);
        self.clock += 1;
        let clock = self.clock;
        let d = self.cfg.dim;
        let chunk = &mut self.chunks[c];
        chunk.meta[r].access_count += 1;
        chunk.meta[r].last_access = clock;
        Some(&mut chunk.values[r * d..(r + 1) * d])
    }

    /// Access metadata for a row (for precision policies and tests).
    pub fn row_meta(&self, id: GlobalId) -> Option<(u32, u64)> {
        let idx = self.find(id)?;
        let (c, r) = unpack_ptr(self.slots[idx].ptr);
        let m = &self.chunks[c].meta[r];
        Some((m.access_count, m.last_access))
    }

    /// Mutable row access that does NOT touch the eviction metadata
    /// (no access-count or clock bump). Precision write-backs use this:
    /// re-quantizing a cold row in place is storage maintenance, not an
    /// access, so LRU/LFU state stays identical to an fp32 run.
    pub fn row_mut_untracked(&mut self, id: GlobalId) -> Option<&mut [f32]> {
        let idx = self.find(id)?;
        let (c, r) = unpack_ptr(self.slots[idx].ptr);
        let d = self.cfg.dim;
        Some(&mut self.chunks[c].values[r * d..(r + 1) * d])
    }

    /// Hot/cold row census for a precision policy: rows with
    /// `access_count >= threshold` are hot. Returns `(hot, cold)`.
    pub fn hot_cold_census(&self, threshold: u32) -> (usize, usize) {
        let mut hot = 0usize;
        let mut cold = 0usize;
        for s in self.slots.iter() {
            if s.key == EMPTY || s.key == TOMBSTONE {
                continue;
            }
            let (c, r) = unpack_ptr(s.ptr);
            if self.chunks[c].meta[r].access_count >= threshold {
                hot += 1;
            } else {
                cold += 1;
            }
        }
        (hot, cold)
    }

    /// Iterate over all live (id, row) pairs (checkpointing).
    pub fn iter_rows(&self) -> impl Iterator<Item = (GlobalId, &[f32])> + '_ {
        let d = self.cfg.dim;
        self.slots.iter().filter_map(move |s| {
            if s.key == EMPTY || s.key == TOMBSTONE {
                None
            } else {
                let (c, r) = unpack_ptr(s.ptr);
                Some((s.key, &self.chunks[c].values[r * d..(r + 1) * d]))
            }
        })
    }

    /// Number of allocated chunks (retired + current + next).
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn config(&self) -> &DynamicTableConfig {
        &self.cfg
    }
}

impl EmbeddingStore for DynamicEmbeddingTable {
    fn dim(&self) -> usize {
        self.cfg.dim
    }

    fn len(&self) -> usize {
        self.live
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        assert!(
            id < TOMBSTONE,
            "ids 2^64-1 and 2^64-2 are reserved sentinels"
        );
        assert_eq!(out.len(), self.cfg.dim);
        self.clock += 1;
        // Enforce the row budget before inserting.
        if let Some(budget) = self.cfg.max_rows {
            if self.live >= budget && self.find(id).is_none() {
                let mut rng = Xoshiro256::new(self.clock ^ self.cfg.seed);
                self.evict_one(&mut rng);
            }
        }
        let (idx, existed) = self.find_insert(id);
        if existed {
            self.stats.hits += 1;
            let (c, r) = unpack_ptr(self.slots[idx].ptr);
            let clock = self.clock;
            let chunk = &mut self.chunks[c];
            chunk.meta[r].access_count += 1;
            chunk.meta[r].last_access = clock;
            let d = self.cfg.dim;
            out.copy_from_slice(&chunk.values[r * d..(r + 1) * d]);
            true
        } else {
            self.stats.misses += 1;
            self.stats.inserts += 1;
            let was_tombstone = self.slots[idx].key == TOMBSTONE;
            let (c, r) = self.alloc_row(id);
            self.slots[idx] = Slot {
                key: id,
                ptr: pack_ptr(c, r),
            };
            self.live += 1;
            if was_tombstone {
                self.tombstones -= 1;
            }
            let d = self.cfg.dim;
            // Initialize deterministically, then copy out.
            let mut init = vec![0.0f32; d];
            self.init_row(id, &mut init);
            self.chunks[c].values[r * d..(r + 1) * d].copy_from_slice(&init);
            self.chunks[c].meta[r].access_count = 1;
            out.copy_from_slice(&init);
            self.maybe_expand();
            false
        }
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        assert_eq!(out.len(), self.cfg.dim);
        match self.row(id) {
            Some(row) => {
                out.copy_from_slice(row);
                true
            }
            None => {
                out.copy_from_slice(&self.default_row);
                false
            }
        }
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        assert_eq!(delta.len(), self.cfg.dim);
        match self.row_mut(id) {
            Some(row) => {
                for (v, d) in row.iter_mut().zip(delta) {
                    *v += d;
                }
                true
            }
            None => false,
        }
    }

    fn memory_bytes(&self) -> usize {
        self.slots.len() * std::mem::size_of::<Slot>()
            + self
                .chunks
                .iter()
                .map(|c| {
                    c.values.len() * 4 + c.meta.len() * std::mem::size_of::<RowMeta>()
                })
                .sum::<usize>()
    }
}

/// Iterator state for grouped parallel probing.
struct ProbeSeq {
    h0: u64,
    step: u64,
    groups: u64,
    mask: u64,
    t: u64,
    g: u64,
}

impl ProbeSeq {
    #[inline]
    fn next_idx(&mut self) -> usize {
        let idx = (self.h0 + self.g + self.t * self.step) & self.mask;
        self.g += 1;
        if self.g == self.groups {
            self.g = 0;
            self.t += 1;
        }
        idx as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_table(dim: usize) -> DynamicEmbeddingTable {
        DynamicEmbeddingTable::new(
            DynamicTableConfig::new(dim)
                .with_capacity(32)
                .with_seed(99),
        )
    }

    #[test]
    fn insert_then_lookup_returns_same_row() {
        let mut t = small_table(8);
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        assert!(!t.lookup_or_insert(42, &mut a)); // fresh
        assert!(t.lookup_or_insert(42, &mut b)); // hit
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x != 0.0), "row must be initialized");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn init_is_deterministic_per_id() {
        let mut t1 = small_table(16);
        let mut t2 = small_table(16);
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        t1.lookup_or_insert(777, &mut a);
        t2.lookup_or_insert(777, &mut b);
        assert_eq!(a, b, "same id+seed → same init across tables");
        let mut c = vec![0.0; 16];
        t1.lookup_or_insert(778, &mut c);
        assert_ne!(a, c);
    }

    #[test]
    fn lookup_without_insert_gives_default() {
        let t = small_table(4);
        let mut out = vec![9.0; 4];
        assert!(!t.lookup(5, &mut out));
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn apply_delta_updates_row() {
        let mut t = small_table(4);
        let mut row = vec![0.0; 4];
        t.lookup_or_insert(1, &mut row);
        assert!(t.apply_delta(1, &[1.0, 2.0, 3.0, 4.0]));
        let mut row2 = vec![0.0; 4];
        t.lookup_or_insert(1, &mut row2);
        for i in 0..4 {
            assert!((row2[i] - (row[i] + (i + 1) as f32)).abs() < 1e-6);
        }
        assert!(!t.apply_delta(999, &[0.0; 4]), "absent id drops update");
    }

    #[test]
    fn expansion_preserves_contents_and_moves_keys_only() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(4).with_capacity(16).with_seed(3),
        );
        let n = 2000u64;
        let mut rows = Vec::new();
        for id in 0..n {
            let mut r = vec![0.0; 4];
            t.lookup_or_insert(id, &mut r);
            rows.push(r);
        }
        assert!(t.stats.expansions > 0, "must have expanded");
        assert!(t.capacity() >= 2048 && t.capacity().is_power_of_two());
        assert!(t.load_factor() <= 0.76);
        for id in 0..n {
            let mut r = vec![0.0; 4];
            assert!(t.lookup(id, &mut r), "id {id} lost after expansion");
            assert_eq!(r, rows[id as usize]);
        }
        // Key-only migration: moved bytes ≪ avoided value bytes (dim 4 →
        // slot is 16 B vs value 16 B... use dim 4: equal; check accounting
        // fields are both populated and consistent instead).
        assert!(t.stats.expansion_bytes_moved > 0);
        assert_eq!(
            t.stats.expansion_bytes_avoided / t.stats.expansion_bytes_moved,
            (4 * 4) as u64 / std::mem::size_of::<Slot>() as u64
        );
    }

    #[test]
    fn chunks_grow_without_moving_rows() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(2)
                .with_capacity(16)
                .with_chunk_rows(8),
        );
        for id in 0..100 {
            let mut r = vec![0.0; 2];
            t.lookup_or_insert(id, &mut r);
        }
        // 100 rows / 8 per chunk → ≥ 13 chunks + the pre-allocated next.
        assert!(t.num_chunks() >= 14);
        // Dual-chunk invariant: there is always a pre-allocated next chunk.
        assert!(t.num_chunks() >= 2);
    }

    #[test]
    fn remove_and_reinsert_through_tombstones() {
        let mut t = small_table(4);
        let mut r = vec![0.0; 4];
        for id in 0..10 {
            t.lookup_or_insert(id, &mut r);
        }
        assert!(t.remove(3));
        assert!(!t.remove(3), "double remove");
        assert_eq!(t.len(), 9);
        assert!(!t.lookup(3, &mut r));
        // Other keys still reachable through the tombstone.
        for id in (0..10).filter(|&i| i != 3) {
            assert!(t.lookup(id, &mut r), "id {id}");
        }
        // Re-insert gets a fresh (deterministic) row again.
        assert!(!t.lookup_or_insert(3, &mut r));
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn eviction_respects_budget_and_policy() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(4)
                .with_capacity(256)
                .with_max_rows(50)
                .with_eviction(EvictionPolicy::Lru),
        );
        let mut r = vec![0.0; 4];
        for id in 0..200 {
            t.lookup_or_insert(id, &mut r);
            // Keep id 0 hot so LRU never evicts it.
            t.lookup_or_insert(0, &mut r);
        }
        assert!(t.len() <= 51, "budget enforced, len={}", t.len());
        assert!(t.stats.evictions > 0);
        assert!(t.lookup(0, &mut r), "hot id survived LRU");
    }

    #[test]
    fn lfu_keeps_frequent_rows() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(4)
                .with_capacity(256)
                .with_max_rows(20)
                .with_eviction(EvictionPolicy::Lfu),
        );
        let mut r = vec![0.0; 4];
        // Make id 7 very frequent.
        for _ in 0..100 {
            t.lookup_or_insert(7, &mut r);
        }
        for id in 100..300 {
            t.lookup_or_insert(id, &mut r);
        }
        assert!(t.lookup(7, &mut r), "frequent id survived LFU");
    }

    // ---- Theorem 1 / Eq. 5 properties --------------------------------

    #[test]
    fn probe_step_is_odd_times_groups() {
        for &m in &[16u64, 64, 1024, 65536] {
            for &g in &[1u64, 2, 4, 8] {
                for key in 0..200u64 {
                    let s = DynamicEmbeddingTable::probe_step(key, m, g);
                    assert_eq!(s % g, 0);
                    assert_eq!((s / g) % 2, 1, "S/groups must be odd");
                    assert!(s >= g && s < m * g);
                }
            }
        }
    }

    /// Theorem 1: with `groups == 1` (odd step S), the probe sequence
    /// covers all M slots exactly once in M steps.
    #[test]
    fn theorem1_single_group_covers_all_slots() {
        let mut rng = Xoshiro256::new(2026);
        for &m in &[16u64, 64, 256, 4096] {
            for _ in 0..20 {
                let key = rng.next_u64();
                let s = DynamicEmbeddingTable::probe_step(key, m, 1);
                let h0 = hash_id(key, 1) & (m - 1);
                let mut seen = vec![false; m as usize];
                for t in 0..m {
                    let idx = ((h0 + t * s) & (m - 1)) as usize;
                    assert!(!seen[idx], "slot {idx} revisited at t={t}, m={m}");
                    seen[idx] = true;
                }
                assert!(seen.iter().all(|&b| b));
            }
        }
    }

    /// Grouped probing: the union of all groups' sequences covers every
    /// slot (each group covers its residue class; groups are staggered by
    /// +g offsets).
    #[test]
    fn grouped_probing_union_covers_all_slots() {
        let mut rng = Xoshiro256::new(7);
        for &m in &[64u64, 256, 1024] {
            for &groups in &[2u64, 4, 8] {
                let key = rng.next_u64();
                let s = DynamicEmbeddingTable::probe_step(key, m, groups);
                let h0 = hash_id(key, 99) & (m - 1);
                let mut seen = vec![false; m as usize];
                for t in 0..(m / groups) {
                    for g in 0..groups {
                        seen[((h0 + g + t * s) & (m - 1)) as usize] = true;
                    }
                }
                assert!(
                    seen.iter().all(|&b| b),
                    "m={m} groups={groups} left slots unvisited"
                );
            }
        }
    }

    #[test]
    fn matches_std_hashmap_under_churn() {
        use std::collections::HashMap;
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(4).with_capacity(16).with_seed(5),
        );
        let mut reference: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut rng = Xoshiro256::new(31337);
        let mut buf = vec![0.0f32; 4];
        for step in 0..5000 {
            let id = rng.gen_range(500);
            match rng.gen_range(10) {
                0..=5 => {
                    // lookup_or_insert
                    let existed = t.lookup_or_insert(id, &mut buf);
                    match reference.get(&id) {
                        Some(row) => {
                            assert!(existed, "step {step}: ref has {id}, table missed");
                            assert_eq!(&buf, row);
                        }
                        None => {
                            assert!(!existed);
                            reference.insert(id, buf.clone());
                        }
                    }
                }
                6..=7 => {
                    // delta update
                    let delta = [0.1, -0.2, 0.3, 0.0];
                    let ok = t.apply_delta(id, &delta);
                    assert_eq!(ok, reference.contains_key(&id));
                    if let Some(row) = reference.get_mut(&id) {
                        for (v, d) in row.iter_mut().zip(delta.iter()) {
                            *v += d;
                        }
                    }
                }
                _ => {
                    // remove
                    let ok = t.remove(id);
                    assert_eq!(ok, reference.remove(&id).is_some(), "step {step}");
                }
            }
            assert_eq!(t.len(), reference.len());
        }
        // Final full-content check.
        for (id, row) in &reference {
            let mut out = vec![0.0; 4];
            assert!(t.lookup(*id, &mut out));
            for (a, b) in out.iter().zip(row.iter()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn iter_rows_yields_all_live() {
        let mut t = small_table(4);
        let mut r = vec![0.0; 4];
        for id in 0..20 {
            t.lookup_or_insert(id, &mut r);
        }
        t.remove(5);
        let ids: std::collections::HashSet<u64> =
            t.iter_rows().map(|(id, _)| id).collect();
        assert_eq!(ids.len(), 19);
        assert!(!ids.contains(&5));
    }

    #[test]
    fn memory_accounting_scales_with_content() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(64)
                .with_capacity(1024)
                .with_chunk_rows(512),
        );
        let m0 = t.memory_bytes();
        let mut r = vec![0.0; 64];
        for id in 0..2000 {
            t.lookup_or_insert(id, &mut r);
        }
        assert!(t.memory_bytes() > m0);
        // ~2000 rows × 64 dims × 4 B ≈ 512 KB of values at least.
        assert!(t.memory_bytes() >= 2000 * 64 * 4);
    }
}
