//! MurmurHash3 — the paper's hash function for the dynamic embedding
//! table (§4.1): "MurmurHash3 processes input ID in 4-byte blocks through
//! mixing operations (constant multiplication, bit rotation, XOR merging)
//! to maximize entropy and ensure avalanche effects".
//!
//! We implement the x86_32 variant (the canonical 4-byte-block algorithm
//! the paper describes) plus the 64-bit finalizer (fmix64), which is what
//! the table uses to hash 8-byte feature IDs in one step on 64-bit CPUs.

/// MurmurHash3 x86_32 over an arbitrary byte slice.
pub fn murmur3_x86_32(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    let mut h1 = seed;
    let nblocks = data.len() / 4;

    // Body: 4-byte blocks.
    for i in 0..nblocks {
        let mut k1 = u32::from_le_bytes([
            data[4 * i],
            data[4 * i + 1],
            data[4 * i + 2],
            data[4 * i + 3],
        ]);
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xe654_6b64);
    }

    // Tail.
    let tail = &data[nblocks * 4..];
    let mut k1: u32 = 0;
    if tail.len() >= 3 {
        k1 ^= (tail[2] as u32) << 16;
    }
    if tail.len() >= 2 {
        k1 ^= (tail[1] as u32) << 8;
    }
    if !tail.is_empty() {
        k1 ^= tail[0] as u32;
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
    }

    // Finalize.
    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// MurmurHash3 32-bit finalizer.
#[inline]
pub fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

/// MurmurHash3 64-bit finalizer (fmix64) — a full-avalanche mix of a
/// 64-bit key. This is the hot-path hash for 8-byte feature IDs: one
/// multiply-xorshift chain instead of block iteration.
#[inline]
pub fn fmix64(mut k: u64) -> u64 {
    k ^= k >> 33;
    k = k.wrapping_mul(0xff51_afd7_ed55_8ccd);
    k ^= k >> 33;
    k = k.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    k ^= k >> 33;
    k
}

/// Hash a 64-bit feature ID (seedable so tables can re-randomize).
#[inline]
pub fn hash_id(id: u64, seed: u64) -> u64 {
    fmix64(id ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn murmur3_x86_32_reference_vectors() {
        // Reference vectors from the canonical smhasher implementation.
        assert_eq!(murmur3_x86_32(b"", 0), 0);
        assert_eq!(murmur3_x86_32(b"", 1), 0x514E28B7);
        assert_eq!(murmur3_x86_32(b"", 0xffffffff), 0x81F16F39);
        assert_eq!(murmur3_x86_32(b"test", 0), 0xba6bd213);
        assert_eq!(murmur3_x86_32(b"test", 0x9747b28c), 0x704b81dc);
        assert_eq!(murmur3_x86_32(b"Hello, world!", 0x9747b28c), 0x24884CBA);
        assert_eq!(murmur3_x86_32(b"The quick brown fox jumps over the lazy dog", 0x9747b28c), 0x2FA826CD);
    }

    #[test]
    fn fmix64_bijective_on_sample() {
        // fmix64 is a bijection; over a sample, no collisions may occur.
        let mut seen = std::collections::HashSet::new();
        for i in 0..100_000u64 {
            assert!(seen.insert(fmix64(i)), "collision at {i}");
        }
    }

    #[test]
    fn avalanche_effect() {
        // Flipping one input bit should flip ~half the output bits.
        let mut total = 0u32;
        let n = 1000;
        for i in 0..n {
            let a = fmix64(i);
            let b = fmix64(i ^ 1);
            total += (a ^ b).count_ones();
        }
        let mean = total as f64 / n as f64;
        assert!((mean - 32.0).abs() < 2.0, "avalanche mean {mean}");
    }

    #[test]
    fn hash_id_seed_sensitivity() {
        assert_ne!(hash_id(42, 0), hash_id(42, 1));
        assert_eq!(hash_id(42, 7), hash_id(42, 7));
    }

    #[test]
    fn uniformity_over_pow2_buckets() {
        // Sequential IDs (typical of new-user assignment) must spread
        // uniformly over power-of-two bucket counts.
        let m = 1024u64;
        let mut counts = vec![0u32; m as usize];
        let n = 1_000_000u64;
        for i in 0..n {
            counts[(hash_id(i, 0) & (m - 1)) as usize] += 1;
        }
        let expected = n as f64 / m as f64;
        // Chi-squared-ish sanity bound: all buckets within ±15 %.
        for (b, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expected).abs() / expected;
            assert!(dev < 0.15, "bucket {b} count {c} dev {dev}");
        }
    }
}
