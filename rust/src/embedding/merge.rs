//! Automatic embedding-table merging (§4.2).
//!
//! Industrial models have hundreds of feature tables; merging those with
//! identical embedding dimension into one physical table fuses many
//! lookup operators into one and avoids memory fragmentation. TorchRec
//! requires manual per-table configuration; MTGRBoost automates it:
//!
//! - [`FeatureConfig`] — the unified per-feature configuration interface
//!   (feature name, embedding dim, pooling, shared lookup table).
//! - [`MergePlan`] — the automatically generated merge strategy: features
//!   grouped by embedding dimension (the paper's example strategy).
//! - [`GlobalIdCodec`] — Eq. 8 bit packing. Dynamic tables have no fixed
//!   row count, so classic cumulative row offsets (Fig. 7a) don't apply;
//!   instead the top `k = ⌈log₂(m+1)⌉` bits after the sign bit encode the
//!   feature-table index: `ID = (i << (63 − k)) | x`.
//! - [`HashTableCollection`] — the merged physical tables, one dynamic
//!   hash table per merge group, addressed by global IDs.
//!
//! # Multi-group data flow (the trainer's path)
//!
//! The distributed trainer instantiates **one physical shard table per
//! merge group** on every worker (each behind its own
//! [`crate::online::OnlineTable`] gate and
//! [`crate::embedding::sharded::ShardedEmbedding`] exchange). Per micro
//! round the occurrence stream is split per group
//! ([`crate::train::features::BatchIds`]), and every exchange phase —
//! stage-1/2 dedup, the ID and embedding all-to-alls, gather/scatter,
//! the gradient push, row-wise Adam, checkpoints and delta sync — runs
//! once per group at the group's width, in ascending group order on
//! every rank (the comm lanes are FIFO, so the collective discipline is
//! preserved). IDs are globalized through [`GlobalIdCodec`] *before*
//! they enter the exchange, so an id is unique system-wide and aliased
//! features ([`FeatureConfig::shared`]) transparently hit one row set.
//!
//! **Single-group compatibility guarantee:** when the schema is
//! homogeneous (one dim ⇒ one group, e.g. `Schema::meituan_like`), the
//! per-group machinery degenerates to exactly one table, one exchange
//! and one optimizer whose message contents, arithmetic order and file
//! formats are byte-identical to the historical single-table path.

use std::collections::BTreeMap;

use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use crate::embedding::{EmbeddingStore, FeatureId, GlobalId};

/// Pooling applied when a feature yields multiple IDs per token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pooling {
    Sum,
    Mean,
}

/// Unified feature configuration interface (§4.2): "defining parameters
/// for each feature (e.g., feature name, embedding dimensions, and lookup
/// tables)". Developers declare features; merging is automatic.
#[derive(Clone, Debug)]
pub struct FeatureConfig {
    pub name: String,
    pub dim: usize,
    pub pooling: Pooling,
    /// Features naming the same `shared_table` alias share one logical
    /// table (e.g. "item_id" in history and exposure sequences).
    pub shared_table: Option<String>,
}

impl FeatureConfig {
    pub fn new(name: &str, dim: usize) -> Self {
        FeatureConfig {
            name: name.to_string(),
            dim,
            pooling: Pooling::Sum,
            shared_table: None,
        }
    }

    pub fn shared(mut self, table: &str) -> Self {
        self.shared_table = Some(table.to_string());
        self
    }

    pub fn with_pooling(mut self, p: Pooling) -> Self {
        self.pooling = p;
        self
    }

    /// The logical table key this feature resolves to.
    pub fn table_key(&self) -> String {
        self.shared_table.clone().unwrap_or_else(|| self.name.clone())
    }
}

/// Eq. 8 global-ID codec. `m` logical tables need
/// `k = ⌈log₂(m+1)⌉` identifier bits; the sign bit stays 0 and the
/// remaining `63 − k` bits carry the per-table local ID.
#[derive(Clone, Copy, Debug)]
pub struct GlobalIdCodec {
    k: u32,
    m: usize,
}

impl GlobalIdCodec {
    pub fn new(num_tables: usize) -> Self {
        assert!(num_tables >= 1);
        let k = (usize::BITS - num_tables.leading_zeros()) as u32; // ⌈log2(m+1)⌉
        assert!(k < 63, "too many tables");
        GlobalIdCodec { k, m: num_tables }
    }

    /// Identifier bits `k`.
    pub fn id_bits(&self) -> u32 {
        self.k
    }

    /// Maximum local ID representable: 2^(63−k) − 1.
    pub fn max_local_id(&self) -> u64 {
        (1u64 << (63 - self.k)) - 1
    }

    /// Eq. 8: `ID = (i << (63 − k)) | x`.
    pub fn encode(&self, table_index: usize, local_id: FeatureId) -> GlobalId {
        debug_assert!(table_index < self.m, "table index {table_index} out of range");
        debug_assert!(
            local_id <= self.max_local_id(),
            "local id {local_id} overflows {} bits",
            63 - self.k
        );
        ((table_index as u64) << (63 - self.k)) | local_id
    }

    /// Inverse of [`encode`].
    pub fn decode(&self, id: GlobalId) -> (usize, FeatureId) {
        let table = (id >> (63 - self.k)) as usize;
        let local = id & self.max_local_id();
        (table, local)
    }
}

/// One merge group: features with identical dim share a physical table.
#[derive(Clone, Debug)]
pub struct MergeGroup {
    pub dim: usize,
    /// Logical table keys in this group, in stable order.
    pub tables: Vec<String>,
}

/// The automatically generated merging strategy.
#[derive(Clone, Debug)]
pub struct MergePlan {
    pub groups: Vec<MergeGroup>,
    /// feature name → (group index, table index within the codec space).
    pub feature_to_table: BTreeMap<String, (usize, usize)>,
    pub codec: GlobalIdCodec,
    /// Number of lookup operators before merging (one per logical table)
    /// vs after (one per group) — the operator-fusion win of §4.2.
    pub ops_before: usize,
    pub ops_after: usize,
}

impl MergePlan {
    /// Build the plan: group logical tables by embedding dimension (the
    /// paper's "combining tables with identical embedding dimensions").
    pub fn build(features: &[FeatureConfig]) -> MergePlan {
        // Logical tables in declaration order, deduped by shared alias.
        let mut table_dims: Vec<(String, usize)> = Vec::new();
        for f in features {
            let key = f.table_key();
            match table_dims.iter().find(|(k, _)| *k == key) {
                Some((_, d)) => assert_eq!(
                    *d, f.dim,
                    "feature `{}` shares table `{}` with a different dim",
                    f.name, key
                ),
                None => table_dims.push((key, f.dim)),
            }
        }
        // Group by dim.
        let mut by_dim: BTreeMap<usize, Vec<String>> = BTreeMap::new();
        for (key, dim) in &table_dims {
            by_dim.entry(*dim).or_default().push(key.clone());
        }
        let groups: Vec<MergeGroup> = by_dim
            .into_iter()
            .map(|(dim, tables)| MergeGroup { dim, tables })
            .collect();

        // Codec over *all* logical tables (global across groups so an ID
        // is unique system-wide).
        let codec = GlobalIdCodec::new(table_dims.len());
        let mut table_index: BTreeMap<&str, usize> = BTreeMap::new();
        {
            let mut next = 0usize;
            for g in &groups {
                for t in &g.tables {
                    table_index.insert(t.as_str(), next);
                    next += 1;
                }
            }
        }
        let mut feature_to_table = BTreeMap::new();
        for f in features {
            let key = f.table_key();
            let gi = groups
                .iter()
                .position(|g| g.tables.contains(&key))
                .unwrap();
            feature_to_table.insert(f.name.clone(), (gi, table_index[key.as_str()]));
        }
        MergePlan {
            ops_before: table_dims.len(),
            ops_after: groups.len(),
            groups,
            feature_to_table,
            codec,
        }
    }

    /// Build the *unmerged* ablation plan: one group (= one physical
    /// table and one exchange per step) per logical table. Groups keep
    /// the walked order of [`build`](Self::build) and the codec spans
    /// the same logical-table index space, so a feature's global IDs
    /// are identical under both plans — only the grouping (and thus
    /// the number of lookup operators / exchanges) differs. This is
    /// the trainer-side `--no-merging` ablation: the fusion win is
    /// measured in wall-clock seconds, not just sim op counts.
    pub fn build_unmerged(features: &[FeatureConfig]) -> MergePlan {
        let merged = MergePlan::build(features);
        let groups: Vec<MergeGroup> = merged
            .groups
            .iter()
            .flat_map(|g| {
                g.tables.iter().map(|t| MergeGroup {
                    dim: g.dim,
                    tables: vec![t.clone()],
                })
            })
            .collect();
        // Table indices were assigned walking groups in order, so after
        // splitting, a table's group index equals its codec index.
        let feature_to_table = merged
            .feature_to_table
            .iter()
            .map(|(name, &(_, ti))| (name.clone(), (ti, ti)))
            .collect();
        MergePlan {
            ops_before: merged.ops_before,
            ops_after: groups.len(),
            groups,
            feature_to_table,
            codec: merged.codec,
        }
    }

    /// Number of merge groups (= physical tables after fusion).
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Per-group embedding dims, in group order.
    pub fn group_dims(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g.dim).collect()
    }

    /// Translate (feature name, local id) → (group index, global id).
    pub fn global_id(&self, feature: &str, local_id: FeatureId) -> (usize, GlobalId) {
        let (group, table) = *self
            .feature_to_table
            .get(feature)
            .unwrap_or_else(|| panic!("unregistered feature `{feature}`"));
        (group, self.codec.encode(table, local_id))
    }
}

/// The merged physical storage: one dynamic hash table per merge group
/// (§4.2 `HashTableCollection`), plus the plan that routes features.
pub struct HashTableCollection {
    pub plan: MergePlan,
    pub tables: Vec<DynamicEmbeddingTable>,
}

impl HashTableCollection {
    pub fn new(features: &[FeatureConfig], base_cfg: &DynamicTableConfig) -> Self {
        let plan = MergePlan::build(features);
        let tables = plan
            .groups
            .iter()
            .map(|g| {
                let mut cfg = base_cfg.clone();
                cfg.dim = g.dim;
                DynamicEmbeddingTable::new(cfg)
            })
            .collect();
        HashTableCollection { plan, tables }
    }

    /// Number of fused lookup operators (one per physical table).
    pub fn num_lookup_ops(&self) -> usize {
        self.tables.len()
    }

    /// Look up one feature occurrence, inserting if new; `out` must have
    /// the feature's dim.
    pub fn lookup_or_insert(
        &mut self,
        feature: &str,
        local_id: FeatureId,
        out: &mut [f32],
    ) -> bool {
        let (group, gid) = self.plan.global_id(feature, local_id);
        self.tables[group].lookup_or_insert(gid, out)
    }

    /// Pooled lookup over several ids of one feature (Sum/Mean pooling
    /// per the feature's config).
    pub fn lookup_pooled(
        &mut self,
        feature: &FeatureConfig,
        ids: &[FeatureId],
        out: &mut [f32],
    ) {
        out.fill(0.0);
        if ids.is_empty() {
            return;
        }
        let mut buf = vec![0.0f32; feature.dim];
        for &id in ids {
            self.lookup_or_insert(&feature.name, id, &mut buf);
            for (o, b) in out.iter_mut().zip(&buf) {
                *o += b;
            }
        }
        if feature.pooling == Pooling::Mean {
            let n = ids.len() as f32;
            for o in out.iter_mut() {
                *o /= n;
            }
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.tables.iter().map(|t| t.memory_bytes()).sum()
    }

    pub fn total_rows(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_features() -> Vec<FeatureConfig> {
        vec![
            FeatureConfig::new("user_id", 32),
            FeatureConfig::new("item_id", 32),
            FeatureConfig::new("cate_id", 16),
            FeatureConfig::new("city_id", 16),
            FeatureConfig::new("action_type", 16),
            // exposure item shares the item_id table
            FeatureConfig::new("exp_item_id", 32).shared("item_id"),
        ]
    }

    #[test]
    fn codec_matches_paper_example() {
        // Paper Fig. 7b: 3 tables → k = ⌈log2(4)⌉ = 2 identifier bits,
        // max rows 2^61, offsets 2^59 and 2^60 for tables 2 and 3.
        let c = GlobalIdCodec::new(3);
        assert_eq!(c.id_bits(), 2);
        assert_eq!(c.max_local_id(), (1u64 << 61) - 1);
        assert_eq!(c.encode(0, 5), 5);
        assert_eq!(c.encode(1, 0), 1u64 << 61 >> 2 << 2); // 1 << 61
        assert_eq!(c.encode(1, 0), 1u64 << 61);
        assert_eq!(c.encode(2, 0), 2u64 << 61);
        // Sign bit stays clear for every encodable id.
        assert_eq!(c.encode(2, c.max_local_id()) >> 63, 0);
    }

    #[test]
    fn codec_bijective_randomized() {
        let mut rng = crate::util::rng::Xoshiro256::new(8);
        for &m in &[1usize, 2, 3, 7, 8, 100] {
            let c = GlobalIdCodec::new(m);
            for _ in 0..500 {
                let t = rng.range_usize(0, m);
                let x = rng.next_u64() & c.max_local_id();
                let (t2, x2) = c.decode(c.encode(t, x));
                assert_eq!((t, x), (t2, x2));
            }
        }
    }

    #[test]
    fn distinct_tables_never_collide() {
        let c = GlobalIdCodec::new(5);
        let a = c.encode(0, 12345);
        let b = c.encode(1, 12345);
        assert_ne!(a, b, "same local id in different tables must differ");
    }

    #[test]
    fn merge_groups_by_dim() {
        let plan = MergePlan::build(&demo_features());
        // 5 logical tables (exp_item_id shares item_id): dims {32: 2, 16: 3}.
        assert_eq!(plan.ops_before, 5);
        assert_eq!(plan.ops_after, 2, "fused into one op per dim group");
        let g16 = plan.groups.iter().find(|g| g.dim == 16).unwrap();
        assert_eq!(g16.tables.len(), 3);
        let g32 = plan.groups.iter().find(|g| g.dim == 32).unwrap();
        assert_eq!(g32.tables.len(), 2);
    }

    #[test]
    fn shared_table_features_resolve_to_same_rows() {
        let feats = demo_features();
        let mut coll =
            HashTableCollection::new(&feats, &DynamicTableConfig::new(1).with_capacity(64));
        let mut a = vec![0.0; 32];
        let mut b = vec![0.0; 32];
        coll.lookup_or_insert("item_id", 42, &mut a);
        // Same id through the aliased feature hits the same row.
        assert!(coll.lookup_or_insert("exp_item_id", 42, &mut b));
        assert_eq!(a, b);
        // But the same local id in an unshared table differs.
        let mut c = vec![0.0; 32];
        assert!(!coll.lookup_or_insert("user_id", 42, &mut c));
        assert_ne!(a, c);
    }

    #[test]
    fn unmerged_plan_one_group_per_table_same_global_ids() {
        let feats = demo_features();
        let merged = MergePlan::build(&feats);
        let unmerged = MergePlan::build_unmerged(&feats);
        // One group per logical table; fusion win disappears.
        assert_eq!(unmerged.num_groups(), merged.ops_before);
        assert_eq!(unmerged.ops_after, unmerged.ops_before);
        for g in &unmerged.groups {
            assert_eq!(g.tables.len(), 1);
        }
        // Same codec space: every feature's global id is bit-identical
        // under both plans (only the group routing differs).
        for f in &feats {
            let (_, gid_m) = merged.global_id(&f.name, 12345);
            let (gi, gid_u) = unmerged.global_id(&f.name, 12345);
            assert_eq!(gid_m, gid_u);
            assert_eq!(unmerged.groups[gi].dim, f.dim);
            assert_eq!(unmerged.groups[gi].tables[0], f.table_key());
        }
    }

    #[test]
    #[should_panic(expected = "different dim")]
    fn shared_table_dim_mismatch_rejected() {
        let feats = vec![
            FeatureConfig::new("a", 8),
            FeatureConfig::new("b", 16).shared("a"),
        ];
        MergePlan::build(&feats);
    }

    #[test]
    fn pooled_lookup_sum_and_mean() {
        let feats = vec![FeatureConfig::new("f", 4).with_pooling(Pooling::Mean)];
        let mut coll =
            HashTableCollection::new(&feats, &DynamicTableConfig::new(1).with_capacity(64));
        let mut r1 = vec![0.0; 4];
        let mut r2 = vec![0.0; 4];
        coll.lookup_or_insert("f", 1, &mut r1);
        coll.lookup_or_insert("f", 2, &mut r2);
        let mut pooled = vec![0.0; 4];
        coll.lookup_pooled(&feats[0], &[1, 2], &mut pooled);
        for i in 0..4 {
            assert!((pooled[i] - (r1[i] + r2[i]) / 2.0).abs() < 1e-6);
        }
        // Empty id list → zero vector.
        coll.lookup_pooled(&feats[0], &[], &mut pooled);
        assert_eq!(pooled, vec![0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "unregistered feature")]
    fn unknown_feature_rejected() {
        let plan = MergePlan::build(&demo_features());
        plan.global_id("nope", 1);
    }
}
