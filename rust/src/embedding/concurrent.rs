//! Lock-striped concurrent dynamic embedding table.
//!
//! The single-threaded [`DynamicEmbeddingTable`] is the paper's §4.1
//! design; production sparse engines (Monolith's collisionless tables,
//! TorchRec's sharded kernels) additionally sustain *concurrent*
//! reader/writer traffic on one shard — stage-2 lookups arriving from
//! many peers while the sparse optimizer applies updates.
//! [`ConcurrentDynamicTable`] brings that here by partitioning the ID
//! space into `S` power-of-two **stripes**, each an independent
//! chunked open-addressing sub-table behind its own `RwLock` (one lock
//! per chunk group):
//!
//! - IDs route to stripes by a dedicated hash, independent of both slot
//!   probing and shard placement, so stripes stay balanced;
//! - readers (`lookup`) take the stripe's read lock and run in parallel
//!   with each other; writers (`lookup_or_insert`, `apply_delta`,
//!   `remove`) take the stripe's write lock and run in parallel across
//!   stripes;
//! - row initialization is a pure function of `(id, seed)` inherited
//!   from the inner table, so contents are **identical** to a
//!   single-threaded table with the same config — verified by tests and
//!   the multi-threaded shard-stress suite.
//!
//! Row budgets split evenly across stripes (each stripe evicts locally,
//! the same approximation production per-shard LRU applies).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig, TableStats};
use crate::embedding::hash::{fmix64, hash_id};
use crate::embedding::precision::{PrecisionPolicy, PrecisionStats};
use crate::embedding::{ConcurrentEmbeddingStore, EmbeddingStore, GlobalId};
use crate::util::f16::quantize_f16_slice;
use crate::util::pool::{SharedSliceMut, WorkerPool};
use crate::util::rng::Xoshiro256;
use crate::util::tuning::TunableThreshold;

/// Default occurrence count below which the stripe fan-out is not worth
/// the fork/join overhead (the serial per-id path is used instead). The
/// live value is [`PAR_FETCH`] (env `MTGR_PAR_FETCH_THRESHOLD`).
pub const PAR_FETCH_THRESHOLD: usize = crate::util::tuning::calibrated::PAR_FETCH;

/// Runtime knob for the per-id→striped batch fetch switch.
pub static PAR_FETCH: TunableThreshold =
    TunableThreshold::new("MTGR_PAR_FETCH_THRESHOLD", PAR_FETCH_THRESHOLD);

/// Live fetch fan-out switch point.
pub fn par_fetch_threshold() -> usize {
    PAR_FETCH.get()
}

/// Seed for stripe routing (distinct from slot probing and shard
/// placement so the three hash partitions are independent).
const STRIPE_SEED: u64 = 0x57121BE5;

/// A dynamic embedding table partitioned into independently locked
/// stripes; all operations take `&self`.
pub struct ConcurrentDynamicTable {
    stripes: Vec<RwLock<DynamicEmbeddingTable>>,
    dim: usize,
    mask: u64,
    route_seed: u64,
    /// Logical clock for eviction RNG streams (not part of row state).
    evict_clock: AtomicU64,
    /// Hot/cold mixed-precision policy (§5.2). Disabled by default —
    /// the fp32 path is byte-identical to the pre-policy table. With
    /// the policy enabled, every write path re-quantizes still-cold
    /// rows under the stripe write lock using the shared post-bump
    /// classification rule, so a cold row's stored bits are always on
    /// the f16 grid.
    precision: PrecisionPolicy,
    /// Total cold-row quantization write-backs (telemetry; the total is
    /// schedule-independent even though the increment order is not).
    quantize_ops: AtomicU64,
}

impl ConcurrentDynamicTable {
    /// Build with `stripes` lock stripes (rounded up to a power of two).
    /// The config's capacity and row budget are split across stripes.
    pub fn new(cfg: DynamicTableConfig, stripes: usize) -> Self {
        let n = stripes.max(1).next_power_of_two();
        let per_stripe_cap = (cfg.initial_capacity / n).max(16);
        let tables = (0..n)
            .map(|_| {
                let mut c = cfg.clone();
                c.initial_capacity = per_stripe_cap;
                c.max_rows = cfg.max_rows.map(|m| m.div_ceil(n));
                DynamicEmbeddingTable::new(c)
            })
            .map(RwLock::new)
            .collect();
        ConcurrentDynamicTable {
            stripes: tables,
            dim: cfg.dim,
            mask: n as u64 - 1,
            route_seed: cfg.seed ^ STRIPE_SEED,
            evict_clock: AtomicU64::new(0),
            precision: PrecisionPolicy::fp32(),
            quantize_ops: AtomicU64::new(0),
        }
    }

    /// Install a mixed-precision policy (builder; call before sharing).
    pub fn with_precision(mut self, policy: PrecisionPolicy) -> Self {
        self.precision = policy;
        self
    }

    /// The active precision policy.
    pub fn precision(&self) -> PrecisionPolicy {
        self.precision
    }

    /// Default striping: 8 stripes (one per simulated GPU's worth of
    /// server-side traffic on a typical test topology).
    pub fn with_default_stripes(cfg: DynamicTableConfig) -> Self {
        ConcurrentDynamicTable::new(cfg, 8)
    }

    #[inline]
    fn stripe_of(&self, id: GlobalId) -> usize {
        (hash_id(id, self.route_seed) & self.mask) as usize
    }

    pub fn num_stripes(&self) -> usize {
        self.stripes.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total live rows (sum of per-stripe snapshots).
    pub fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.read().unwrap().len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Worst-case stripe load factor (the expansion-trigger bound holds
    /// per stripe, so the maximum is the system's bound).
    pub fn max_load_factor(&self) -> f64 {
        self.stripes
            .iter()
            .map(|s| s.read().unwrap().load_factor())
            .fold(0.0, f64::max)
    }

    /// Aggregate statistics across stripes.
    pub fn stats(&self) -> TableStats {
        let mut total = TableStats::default();
        for s in &self.stripes {
            total.merge(&s.read().unwrap().stats);
        }
        total
    }

    /// Quantize the stored row (and the caller's copy) if the row is
    /// cold *after* the operation that just bumped its metadata — the
    /// single post-bump classification rule shared with
    /// [`crate::embedding::precision::MixedPrecisionTable`]. Called
    /// under the stripe's write lock with the guard's table, so the
    /// check-and-quantize is atomic per row. The untracked row access
    /// keeps LRU/LFU metadata identical to an fp32 run.
    #[inline]
    fn quantize_if_cold(
        &self,
        t: &mut DynamicEmbeddingTable,
        id: GlobalId,
        out: Option<&mut [f32]>,
    ) {
        if !self.precision.enabled {
            return;
        }
        let hot = match t.row_meta(id) {
            Some((count, _)) => self.precision.is_hot_count(count),
            None => return,
        };
        if hot {
            return;
        }
        if let Some(row) = t.row_mut_untracked(id) {
            quantize_f16_slice(row);
            if let Some(out) = out {
                out.copy_from_slice(row);
            }
            self.quantize_ops.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Training-time lookup (write-locks only the id's stripe; other
    /// stripes proceed in parallel).
    pub fn lookup_or_insert(&self, id: GlobalId, out: &mut [f32]) -> bool {
        let s = self.stripe_of(id);
        let mut t = self.stripes[s].write().unwrap();
        let existed = t.lookup_or_insert(id, out);
        self.quantize_if_cold(&mut t, id, Some(out));
        existed
    }

    /// Read-only lookup (read lock: concurrent with other readers).
    pub fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        let s = self.stripe_of(id);
        self.stripes[s].read().unwrap().lookup(id, out)
    }

    /// Whether `id` has a live row (read lock; no metadata bump).
    pub fn contains(&self, id: GlobalId) -> bool {
        let s = self.stripe_of(id);
        self.stripes[s].read().unwrap().contains(id)
    }

    /// Whether a row budget (auto-eviction) is configured. Budgeted
    /// tables evict victims *inside* `lookup_or_insert`, invisibly to
    /// wrappers — the online delta tracker refuses them (it could not
    /// record the removals).
    pub fn has_row_budget(&self) -> bool {
        self.stripes[0].read().unwrap().config().max_rows.is_some()
    }

    /// Insert-or-overwrite a row with exact bits (checkpoint/delta
    /// install): the row is materialized if absent, then its value is
    /// copied from `row` verbatim, so the stored bits never depend on
    /// the table's init seed. Deliberately bypasses the precision
    /// policy: snapshots copy stored bits (cold rows already on the f16
    /// grid), so installing them verbatim is exactly the binary16
    /// round-trip — re-quantizing here would be redundant and would
    /// corrupt installs of rows that were hot at snapshot time.
    pub fn set_row(&self, id: GlobalId, row: &[f32]) {
        let mut scratch = Vec::new();
        self.set_row_scratch(id, row, &mut scratch);
    }

    /// [`set_row`](Self::set_row) with a caller-owned scratch buffer,
    /// hoisting the per-call allocation out of bulk install loops
    /// (serving-side base/delta installs touch every row).
    pub fn set_row_scratch(&self, id: GlobalId, row: &[f32], scratch: &mut Vec<f32>) {
        assert_eq!(row.len(), self.dim);
        let s = self.stripe_of(id);
        let mut t = self.stripes[s].write().unwrap();
        if let Some(slot) = t.row_mut(id) {
            slot.copy_from_slice(row);
            return;
        }
        scratch.clear();
        scratch.resize(self.dim, 0.0);
        t.lookup_or_insert(id, scratch);
        t.row_mut(id)
            .expect("row just inserted")
            .copy_from_slice(row);
    }

    /// Additive row update (optimizer delta). With a mixed policy the
    /// write-back re-quantizes rows that are still cold *after* the
    /// bump — a row promoted by this very write lands at full f32
    /// precision, matching the read path's classification.
    pub fn apply_delta(&self, id: GlobalId, delta: &[f32]) -> bool {
        let s = self.stripe_of(id);
        let mut t = self.stripes[s].write().unwrap();
        let ok = t.apply_delta(id, delta);
        if ok {
            self.quantize_if_cold(&mut t, id, None);
        }
        ok
    }

    /// Remove an id; returns whether it was present.
    pub fn remove(&self, id: GlobalId) -> bool {
        let s = self.stripe_of(id);
        self.stripes[s].write().unwrap().remove(id)
    }

    /// Evict one cold row, preferring the fullest stripe. The fullness
    /// snapshot is advisory (taken under read locks); because writers
    /// may race it, every stripe is tried in snapshot order until one
    /// eviction succeeds, so the call only returns `None` when every
    /// stripe was observed empty under its write lock.
    pub fn evict_one(&self) -> Option<GlobalId> {
        let mut order: Vec<(usize, usize)> = self
            .stripes
            .iter()
            .enumerate()
            .map(|(i, s)| (s.read().unwrap().len(), i))
            .collect();
        order.sort_unstable_by(|a, b| b.0.cmp(&a.0));
        let tick = self.evict_clock.fetch_add(1, Ordering::Relaxed);
        let mut rng = Xoshiro256::new(tick ^ self.route_seed);
        for (_, i) in order {
            if let Some(id) = self.stripes[i].write().unwrap().evict_one(&mut rng) {
                return Some(id);
            }
        }
        None
    }

    /// Snapshot of all live ids (per-stripe consistent; only globally
    /// consistent when writers are quiescent, as at checkpoint time).
    pub fn live_ids(&self) -> Vec<GlobalId> {
        let mut out = Vec::new();
        for s in &self.stripes {
            let t = s.read().unwrap();
            out.extend(t.iter_rows().map(|(id, _)| id));
        }
        out
    }

    /// Owned copy of one row, if present.
    pub fn row(&self, id: GlobalId) -> Option<Vec<f32>> {
        let s = self.stripe_of(id);
        let t = self.stripes[s].read().unwrap();
        t.row(id).map(|r| r.to_vec())
    }

    pub fn memory_bytes(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.read().unwrap().memory_bytes())
            .sum()
    }

    /// Batched lookup taking `&self`: bucket occurrences by stripe
    /// (occurrence order preserved within each stripe), then serve each
    /// stripe under a single lock acquisition — in parallel across
    /// stripes when a pool with more than one thread is supplied.
    ///
    /// Stripes are independent sub-tables and each receives its
    /// occurrences in the same relative order as the serial per-id
    /// loop, so the resulting table contents *and* the returned rows
    /// are bit-identical to the serial path for every pool size.
    pub fn fetch_rows_shared(
        &self,
        ids: &[GlobalId],
        train: bool,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d);
        if ids.is_empty() {
            return;
        }
        let parallel =
            matches!(pool, Some(p) if p.threads() > 1) && ids.len() >= par_fetch_threshold();
        if !parallel {
            for (row, &id) in out.chunks_exact_mut(d).zip(ids) {
                if train {
                    self.lookup_or_insert(id, row);
                } else {
                    self.lookup(id, row);
                }
            }
            return;
        }
        let ns = self.stripes.len();
        let mut by_stripe: Vec<Vec<u32>> = vec![Vec::new(); ns];
        for (i, &id) in ids.iter().enumerate() {
            by_stripe[self.stripe_of(id)].push(i as u32);
        }
        let window = SharedSliceMut::new(out);
        pool.unwrap().parallel_for(ns, |stripes| {
            for s in stripes {
                let idxs = &by_stripe[s];
                if idxs.is_empty() {
                    continue;
                }
                if train {
                    let mut t = self.stripes[s].write().unwrap();
                    for &i in idxs {
                        // SAFETY: every occurrence index lands in exactly
                        // one stripe bucket, so row windows are disjoint.
                        let row = unsafe { window.slice_mut(i as usize * d, d) };
                        t.lookup_or_insert(ids[i as usize], row);
                        self.quantize_if_cold(&mut t, ids[i as usize], Some(row));
                    }
                } else {
                    let t = self.stripes[s].read().unwrap();
                    for &i in idxs {
                        // SAFETY: as above — one bucket per occurrence.
                        let row = unsafe { window.slice_mut(i as usize * d, d) };
                        t.lookup(ids[i as usize], row);
                    }
                }
            }
        });
    }

    /// [`fetch_rows_shared`](Self::fetch_rows_shared) with a per-id
    /// admission mask: `admit[i] == true` serves occurrence `i` with
    /// insert-on-miss semantics, `false` with read-only semantics (an
    /// absent rejected id yields the default all-zero row and never
    /// allocates). Used by the online feature-admission gate; the same
    /// stripe-bucketed fan-out and per-stripe occurrence order as the
    /// unmasked path, so results are bit-identical for every pool size.
    pub fn fetch_rows_masked(
        &self,
        ids: &[GlobalId],
        admit: &[bool],
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d);
        assert_eq!(admit.len(), ids.len());
        if ids.is_empty() {
            return;
        }
        let parallel =
            matches!(pool, Some(p) if p.threads() > 1) && ids.len() >= par_fetch_threshold();
        if !parallel {
            for (i, (row, &id)) in out.chunks_exact_mut(d).zip(ids).enumerate() {
                if admit[i] {
                    self.lookup_or_insert(id, row);
                } else {
                    self.lookup(id, row);
                }
            }
            return;
        }
        let ns = self.stripes.len();
        let mut by_stripe: Vec<Vec<u32>> = vec![Vec::new(); ns];
        for (i, &id) in ids.iter().enumerate() {
            by_stripe[self.stripe_of(id)].push(i as u32);
        }
        let window = SharedSliceMut::new(out);
        pool.unwrap().parallel_for(ns, |stripes| {
            for s in stripes {
                let idxs = &by_stripe[s];
                if idxs.is_empty() {
                    continue;
                }
                // Write lock regardless: admitted occurrences may
                // insert; rejected ones just read under the same lock.
                let mut t = self.stripes[s].write().unwrap();
                for &i in idxs {
                    // SAFETY: every occurrence index lands in exactly
                    // one stripe bucket, so row windows are disjoint.
                    let row = unsafe { window.slice_mut(i as usize * d, d) };
                    if admit[i as usize] {
                        t.lookup_or_insert(ids[i as usize], row);
                        self.quantize_if_cold(&mut t, ids[i as usize], Some(row));
                    } else {
                        // Rejected ids read only: absent → default row,
                        // present → stored bits (already on the f16 grid
                        // when cold — no bump, no re-quantization).
                        t.lookup(ids[i as usize], row);
                    }
                }
            }
        });
    }

    /// Order-independent fingerprint of the table contents (ids and row
    /// bits). Iteration order, striping and insertion interleaving
    /// cannot affect it — only the actual contents can — which makes it
    /// the embedding-state witness for the e2e bitwise-equality suite.
    pub fn content_checksum(&self) -> u64 {
        let mut sum = 0u64;
        for s in &self.stripes {
            let t = s.read().unwrap();
            for (id, row) in t.iter_rows() {
                let mut h = hash_id(id, 0xC0FFEE);
                for &x in row {
                    h = fmix64(h ^ x.to_bits() as u64);
                }
                sum = sum.wrapping_add(h);
            }
        }
        sum
    }

    /// Post-bump hot/cold classification for one row (`None` when
    /// absent). Read lock only — classification never bumps metadata,
    /// so probing a row's precision is free of side effects.
    pub fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        let s = self.stripe_of(id);
        let t = self.stripes[s].read().unwrap();
        t.row_meta(id)
            .map(|(count, _)| self.precision.is_hot_count(count))
    }

    /// Hot/cold census + cumulative quantization ops. With the policy
    /// disabled every row counts as hot (threshold 0).
    pub fn precision_stats(&self) -> PrecisionStats {
        let threshold = if self.precision.enabled {
            self.precision.hot_threshold
        } else {
            0
        };
        let mut stats = PrecisionStats {
            quantize_ops: self.quantize_ops.load(Ordering::Relaxed),
            ..Default::default()
        };
        for s in &self.stripes {
            let (hot, cold) = s.read().unwrap().hot_cold_census(threshold);
            stats.hot_rows += hot;
            stats.cold_rows += cold;
        }
        stats
    }

    /// Effective value-storage bytes under the active policy (hot rows
    /// 4 B, cold rows 2 B per element).
    pub fn effective_value_bytes(&self) -> usize {
        self.precision_stats().effective_value_bytes(self.dim)
    }
}

impl ConcurrentEmbeddingStore for ConcurrentDynamicTable {
    fn dim(&self) -> usize {
        ConcurrentDynamicTable::dim(self)
    }

    fn len(&self) -> usize {
        ConcurrentDynamicTable::len(self)
    }

    fn lookup_or_insert(&self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup_or_insert(self, id, out)
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup(self, id, out)
    }

    fn apply_delta(&self, id: GlobalId, delta: &[f32]) -> bool {
        ConcurrentDynamicTable::apply_delta(self, id, delta)
    }

    fn memory_bytes(&self) -> usize {
        ConcurrentDynamicTable::memory_bytes(self)
    }

    fn precision_policy(&self) -> PrecisionPolicy {
        self.precision
    }

    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        ConcurrentDynamicTable::row_is_hot(self, id)
    }
}

/// Exclusive-reference compatibility: the concurrent table drops into
/// every `EmbeddingStore` consumer (trainer shards, `SparseAdam`,
/// benches) unchanged.
impl EmbeddingStore for ConcurrentDynamicTable {
    fn dim(&self) -> usize {
        ConcurrentDynamicTable::dim(self)
    }

    fn len(&self) -> usize {
        ConcurrentDynamicTable::len(self)
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup_or_insert(self, id, out)
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        ConcurrentDynamicTable::lookup(self, id, out)
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        ConcurrentDynamicTable::apply_delta(self, id, delta)
    }

    fn fetch_rows(
        &mut self,
        ids: &[GlobalId],
        train: bool,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        ConcurrentDynamicTable::fetch_rows_shared(self, ids, train, out, pool)
    }

    fn memory_bytes(&self) -> usize {
        ConcurrentDynamicTable::memory_bytes(self)
    }

    fn precision_policy(&self) -> PrecisionPolicy {
        self.precision
    }

    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        ConcurrentDynamicTable::row_is_hot(self, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn cfg() -> DynamicTableConfig {
        DynamicTableConfig::new(4).with_capacity(256).with_seed(11)
    }

    #[test]
    fn contents_identical_to_single_threaded_table() {
        let conc = ConcurrentDynamicTable::new(cfg(), 4);
        let mut single = DynamicEmbeddingTable::new(cfg());
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        for id in 0..500u64 {
            let e1 = conc.lookup_or_insert(id, &mut a);
            let e2 = single.lookup_or_insert(id, &mut b);
            assert_eq!(e1, e2);
            assert_eq!(a, b, "id {id}: init must be a pure function of (id, seed)");
        }
        assert_eq!(ConcurrentDynamicTable::len(&conc), single.len());
        // Deltas land identically.
        for id in (0..500u64).step_by(7) {
            let delta = [0.5, -0.25, 0.125, 1.0];
            assert!(conc.apply_delta(id, &delta));
            assert!(single.apply_delta(id, &delta));
        }
        for id in 0..500u64 {
            assert!(conc.lookup(id, &mut a));
            assert!(single.lookup(id, &mut b));
            assert_eq!(a, b, "id {id} diverged after updates");
        }
    }

    #[test]
    fn remove_and_budget() {
        let conc = ConcurrentDynamicTable::new(cfg(), 2);
        let mut buf = vec![0.0f32; 4];
        for id in 0..20u64 {
            conc.lookup_or_insert(id, &mut buf);
        }
        assert!(conc.remove(7));
        assert!(!conc.remove(7));
        assert_eq!(ConcurrentDynamicTable::len(&conc), 19);
        assert!(!conc.lookup(7, &mut buf));
        let ids = conc.live_ids();
        assert_eq!(ids.len(), 19);
        assert!(!ids.contains(&7));
    }

    #[test]
    fn eviction_bounds_rows() {
        let conc = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(2)
                .with_capacity(512)
                .with_seed(3)
                .with_max_rows(64),
            4,
        );
        let mut buf = vec![0.0f32; 2];
        for id in 0..2000u64 {
            conc.lookup_or_insert(id, &mut buf);
        }
        // Budget split per stripe: ≤ ceil(64/4) per stripe + slack.
        assert!(
            ConcurrentDynamicTable::len(&conc) <= 64 + 4,
            "len {}",
            ConcurrentDynamicTable::len(&conc)
        );
        assert!(conc.stats().evictions > 0);
        // Manual eviction also works.
        let before = ConcurrentDynamicTable::len(&conc);
        assert!(conc.evict_one().is_some());
        assert_eq!(ConcurrentDynamicTable::len(&conc), before - 1);
    }

    #[test]
    fn parallel_inserts_from_many_threads_match_reference() {
        let conc = Arc::new(ConcurrentDynamicTable::new(cfg(), 8));
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let conc = Arc::clone(&conc);
            joins.push(std::thread::spawn(move || {
                let mut buf = vec![0.0f32; 4];
                // Overlapping id ranges: contention on shared stripes.
                for id in (t * 100)..(t * 100 + 300) {
                    conc.lookup_or_insert(id, &mut buf);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // Reference: same ids through a single-threaded table.
        let mut single = DynamicEmbeddingTable::new(cfg());
        let mut b = vec![0.0f32; 4];
        for id in 0..1000u64 {
            single.lookup_or_insert(id, &mut b);
        }
        assert_eq!(ConcurrentDynamicTable::len(&conc), single.len());
        let mut a = vec![0.0f32; 4];
        for id in 0..1000u64 {
            assert!(conc.lookup(id, &mut a), "id {id} lost under concurrency");
            single.lookup(id, &mut b);
            assert_eq!(a, b, "id {id}");
        }
    }

    #[test]
    fn batched_fetch_matches_serial_for_every_pool_size() {
        // Zipf-ish overlapping ids, enough to clear PAR_FETCH_THRESHOLD.
        let ids: Vec<u64> = (0..4000u64).map(|i| (i * i + 7) % 613).collect();
        // Serial reference: the per-id path on a fresh table.
        let serial_table = ConcurrentDynamicTable::new(cfg(), 8);
        let mut serial_out = vec![0.0f32; ids.len() * 4];
        serial_table.fetch_rows_shared(&ids, true, &mut serial_out, None);
        for threads in [1, 2, 4] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            let table = ConcurrentDynamicTable::new(cfg(), 8);
            let mut out = vec![0.0f32; ids.len() * 4];
            table.fetch_rows_shared(&ids, true, &mut out, Some(&pool));
            assert_eq!(out, serial_out, "{threads} threads: rows diverged");
            assert_eq!(
                ConcurrentDynamicTable::len(&table),
                ConcurrentDynamicTable::len(&serial_table),
                "{threads} threads: row counts diverged"
            );
            assert_eq!(
                table.content_checksum(),
                serial_table.content_checksum(),
                "{threads} threads: contents diverged"
            );
            // Read-only batch over the filled table also matches.
            let mut ro = vec![0.0f32; ids.len() * 4];
            table.fetch_rows_shared(&ids, false, &mut ro, Some(&pool));
            assert_eq!(ro, serial_out, "{threads} threads: read-only rows");
        }
    }

    #[test]
    fn content_checksum_reflects_contents_not_order() {
        let a = ConcurrentDynamicTable::new(cfg(), 4);
        let b = ConcurrentDynamicTable::new(cfg(), 4);
        let mut buf = vec![0.0f32; 4];
        for id in 0..100u64 {
            a.lookup_or_insert(id, &mut buf);
        }
        for id in (0..100u64).rev() {
            b.lookup_or_insert(id, &mut buf);
        }
        assert_eq!(a.content_checksum(), b.content_checksum(), "order-free");
        assert!(a.apply_delta(42, &[0.5, 0.0, 0.0, 0.0]));
        assert_ne!(a.content_checksum(), b.content_checksum(), "value-sensitive");
    }

    #[test]
    fn mixed_precision_matches_reference_wrapper() {
        use crate::embedding::precision::{MixedPrecisionTable, PrecisionPolicy};
        // Same touch sequence through the concurrent table (policy
        // native) and the single-threaded reference wrapper: stored
        // bits, returned bits and classification must agree id by id.
        let policy = PrecisionPolicy::mixed(3);
        let conc = ConcurrentDynamicTable::new(cfg(), 4).with_precision(policy);
        let mut reference =
            MixedPrecisionTable::new(DynamicEmbeddingTable::new(cfg()), policy);
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        for round in 0..4u64 {
            for id in 0..200u64 {
                if id % (round + 1) != 0 {
                    continue; // skewed touch counts → both classes exist
                }
                conc.lookup_or_insert(id, &mut a);
                reference.lookup_or_insert(id, &mut b);
                assert_eq!(a, b, "round {round} id {id}: returned rows");
                let delta = [0.01 * (id as f32 + 1.0), -0.5, 1e-6, 0.25];
                assert_eq!(
                    conc.apply_delta(id, &delta),
                    reference.apply_delta(id, &delta)
                );
            }
        }
        let mut hot = 0;
        for id in 0..200u64 {
            let cr = conc.row(id);
            let rr = reference.inner().row(id).map(|r| r.to_vec());
            assert_eq!(cr, rr, "id {id}: stored bits");
            if let Some(h) = conc.row_is_hot(id) {
                assert_eq!(h, reference.is_hot(id), "id {id}: classification");
                hot += usize::from(h);
            }
        }
        assert!(hot > 0, "threshold 3 over 4 rounds must promote some rows");
        let stats = conc.precision_stats();
        assert!(stats.hot_rows > 0 && stats.cold_rows > 0);
        assert_eq!(stats.hot_rows, hot);
        assert!(stats.quantize_ops > 0);
        assert!(conc.effective_value_bytes() < ConcurrentDynamicTable::len(&conc) * 4 * 4);
    }

    #[test]
    fn mixed_precision_batched_fetch_matches_serial_and_stays_on_grid() {
        use crate::embedding::precision::PrecisionPolicy;
        use crate::util::f16::quantize_f16;
        let ids: Vec<u64> = (0..4000u64).map(|i| (i * i + 7) % 613).collect();
        let policy = PrecisionPolicy::mixed(1_000_000); // everything stays cold
        let serial_table = ConcurrentDynamicTable::new(cfg(), 8).with_precision(policy);
        let mut serial_out = vec![0.0f32; ids.len() * 4];
        serial_table.fetch_rows_shared(&ids, true, &mut serial_out, None);
        for threads in [1, 2, 4] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            let table = ConcurrentDynamicTable::new(cfg(), 8).with_precision(policy);
            let mut out = vec![0.0f32; ids.len() * 4];
            table.fetch_rows_shared(&ids, true, &mut out, Some(&pool));
            assert_eq!(out, serial_out, "{threads} threads: rows diverged");
            assert_eq!(
                table.content_checksum(),
                serial_table.content_checksum(),
                "{threads} threads: contents diverged"
            );
        }
        // The storage invariant: every cold row's stored bits (and the
        // returned copies) sit exactly on the f16 grid.
        for id in serial_table.live_ids() {
            let row = serial_table.row(id).unwrap();
            for &v in &row {
                assert_eq!(v, quantize_f16(v), "id {id} off the f16 grid");
            }
        }
        for &v in &serial_out {
            assert_eq!(v, quantize_f16(v), "returned row off the f16 grid");
        }
    }

    #[test]
    fn fp32_policy_is_byte_identical_to_unpoliced_table() {
        // `--precision fp32` must be a no-op: same contents as a table
        // constructed without any policy call.
        let plain = ConcurrentDynamicTable::new(cfg(), 4);
        let policed = ConcurrentDynamicTable::new(cfg(), 4)
            .with_precision(crate::embedding::precision::PrecisionPolicy::fp32());
        let mut buf = vec![0.0f32; 4];
        for id in 0..300u64 {
            plain.lookup_or_insert(id, &mut buf);
            policed.lookup_or_insert(id, &mut buf);
            plain.apply_delta(id, &[1e-6; 4]);
            policed.apply_delta(id, &[1e-6; 4]);
        }
        assert_eq!(plain.content_checksum(), policed.content_checksum());
        let stats = policed.precision_stats();
        assert_eq!(stats.quantize_ops, 0);
        assert_eq!(stats.cold_rows, 0, "disabled policy counts every row hot");
    }

    #[test]
    fn set_row_installs_exact_bits_under_mixed_policy() {
        use crate::embedding::precision::PrecisionPolicy;
        // Checkpoint/delta/replica installs must preserve bits verbatim
        // even for values off the f16 grid (a row can be hot at
        // snapshot time).
        let t = ConcurrentDynamicTable::new(cfg(), 4)
            .with_precision(PrecisionPolicy::mixed(2));
        let row = [0.1f32, 1e-6, -3.14159, 42.4242];
        t.set_row(77, &row);
        assert_eq!(t.row(77).unwrap(), row.to_vec());
    }

    #[test]
    fn load_factor_bounded_per_stripe() {
        let conc = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(2).with_capacity(64).with_seed(5),
            4,
        );
        let mut buf = vec![0.0f32; 2];
        for id in 0..5000u64 {
            conc.lookup_or_insert(id, &mut buf);
        }
        assert!(conc.max_load_factor() <= 0.76);
        assert!(conc.stats().expansions > 0, "stripes must have expanded");
    }
}
