//! Static embedding table — the TorchRec-style baseline the paper
//! replaces (§4.1).
//!
//! Characteristics reproduced faithfully because the paper's comparisons
//! depend on them:
//! - **Fixed capacity, pre-allocated**: all `capacity × dim` values are
//!   allocated up front ("static tables typically require preallocation
//!   of capacity exceeding actual requirements"), so `memory_bytes()` is
//!   independent of how many rows are actually used — this is the memory
//!   inefficiency (and the Table 3 OOM failure mode) the paper calls out.
//! - **Default embedding for out-of-range IDs**: IDs ≥ capacity cannot be
//!   allocated a row and fall back to a shared default embedding, the
//!   accuracy-degrading path described in §4.1.

use crate::embedding::hash::hash_id;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::rng::Xoshiro256;

/// Fixed-capacity embedding table indexed directly by ID.
pub struct StaticEmbeddingTable {
    dim: usize,
    capacity: usize,
    values: Vec<f32>,
    /// Which rows have been touched (for `len`).
    used: Vec<bool>,
    default_row: Vec<f32>,
    seed: u64,
    /// Count of lookups that overflowed capacity and got the default row.
    pub default_fallbacks: u64,
}

impl StaticEmbeddingTable {
    /// Pre-allocates `capacity × dim` floats immediately.
    pub fn new(dim: usize, capacity: usize, seed: u64) -> Self {
        assert!(dim > 0 && capacity > 0);
        StaticEmbeddingTable {
            dim,
            capacity,
            values: vec![0.0; capacity * dim],
            used: vec![false; capacity],
            default_row: vec![0.0; dim],
            seed,
            default_fallbacks: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn init_row(&self, id: u64, out: &mut [f32]) {
        let mut rng = Xoshiro256::new(hash_id(id, self.seed ^ 0xD1CE));
        let scale = 1.0 / (self.dim as f32).sqrt();
        for v in out.iter_mut() {
            *v = rng.gauss() as f32 * scale;
        }
    }

    /// Whether this ID is representable (fits the static range).
    pub fn in_range(&self, id: GlobalId) -> bool {
        (id as usize) < self.capacity
    }
}

impl EmbeddingStore for StaticEmbeddingTable {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.used.iter().filter(|&&u| u).count()
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        assert_eq!(out.len(), self.dim);
        if !self.in_range(id) {
            // The static table cannot allocate a row for this id: the
            // accuracy-degrading default-embedding path.
            self.default_fallbacks += 1;
            out.copy_from_slice(&self.default_row);
            return false;
        }
        let idx = id as usize;
        let existed = self.used[idx];
        if !existed {
            let mut init = vec![0.0f32; self.dim];
            self.init_row(id, &mut init);
            self.values[idx * self.dim..(idx + 1) * self.dim].copy_from_slice(&init);
            self.used[idx] = true;
        }
        out.copy_from_slice(&self.values[idx * self.dim..(idx + 1) * self.dim]);
        existed
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        assert_eq!(out.len(), self.dim);
        if !self.in_range(id) || !self.used[id as usize] {
            out.copy_from_slice(&self.default_row);
            return false;
        }
        let idx = id as usize;
        out.copy_from_slice(&self.values[idx * self.dim..(idx + 1) * self.dim]);
        true
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        assert_eq!(delta.len(), self.dim);
        if !self.in_range(id) || !self.used[id as usize] {
            return false;
        }
        let idx = id as usize;
        for (v, d) in self.values[idx * self.dim..(idx + 1) * self.dim]
            .iter_mut()
            .zip(delta)
        {
            *v += d;
        }
        true
    }

    /// Full pre-allocated footprint regardless of actual occupancy.
    fn memory_bytes(&self) -> usize {
        self.capacity * self.dim * std::mem::size_of::<f32>() + self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_range_ids_behave_like_a_table() {
        let mut t = StaticEmbeddingTable::new(4, 100, 1);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        assert!(!t.lookup_or_insert(7, &mut a));
        assert!(t.lookup_or_insert(7, &mut b));
        assert_eq!(a, b);
        assert!(t.apply_delta(7, &[1.0; 4]));
        t.lookup(7, &mut b);
        assert!((b[0] - (a[0] + 1.0)).abs() < 1e-6);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn out_of_range_gets_default_row() {
        let mut t = StaticEmbeddingTable::new(4, 10, 1);
        let mut out = vec![9.0; 4];
        assert!(!t.lookup_or_insert(10, &mut out)); // == capacity → overflow
        assert_eq!(out, vec![0.0; 4]);
        assert_eq!(t.default_fallbacks, 1);
        assert!(!t.apply_delta(10, &[1.0; 4]), "default row is not trainable");
    }

    #[test]
    fn memory_is_preallocated() {
        let empty = StaticEmbeddingTable::new(64, 10_000, 1);
        let mut full = StaticEmbeddingTable::new(64, 10_000, 1);
        let mut r = vec![0.0; 64];
        for id in 0..10_000 {
            full.lookup_or_insert(id, &mut r);
        }
        assert_eq!(empty.memory_bytes(), full.memory_bytes());
        assert_eq!(empty.memory_bytes(), 10_000 * 64 * 4 + 10_000);
    }

    #[test]
    fn init_matches_dynamic_table_convention() {
        // Same (id, seed) should produce the same init as the dynamic
        // table, so baseline-vs-system accuracy runs start identically.
        use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
        let mut s = StaticEmbeddingTable::new(8, 100, 42);
        let mut d = DynamicEmbeddingTable::new(DynamicTableConfig::new(8).with_seed(42));
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        s.lookup_or_insert(3, &mut a);
        d.lookup_or_insert(3, &mut b);
        assert_eq!(a, b);
    }
}
