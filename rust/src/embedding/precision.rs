//! Mixed-precision embedding storage (§5.2).
//!
//! "For high-frequency accessed feature embeddings, we preserve embedding
//! vectors in FP32 format to avoid quantization accumulation errors caused
//! by frequent gradient updates. Conversely, low-frequency features employ
//! FP16 storage and computation, significantly reducing memory footprint
//! while accelerating table lookup operations."
//!
//! The policy lives here ([`PrecisionPolicy`] / [`PrecisionMode`] /
//! [`PrecisionStats`]) and composes into two stores:
//!
//! - [`MixedPrecisionTable`] wraps the single-threaded
//!   [`DynamicEmbeddingTable`] (the original seed wrapper, kept for the
//!   §5.2 ablations and as the policy's reference semantics);
//! - [`super::concurrent::ConcurrentDynamicTable`] applies the same
//!   policy natively under its stripe locks, which is what the trainer,
//!   the online gate and the sharded exchange actually run
//!   (`--precision mixed`).
//!
//! **One deterministic classification rule, shared by every path**: a row
//! is *hot* iff its access count **after** the current operation's
//! metadata bump is `>= hot_threshold`. Reads (`lookup_or_insert` hit),
//! fresh inserts and write-backs (`apply_delta`) all classify post-bump,
//! so hot/cold membership is a pure function of the per-id touch sequence
//! — independent of the read-vs-write path and of thread schedules. Cold
//! rows physically round-trip through IEEE binary16 on every write-back,
//! so the quantization error the paper accepts for cold rows is actually
//! applied; the promoting touch itself is served at full precision (a row
//! crossing the threshold on a write is NOT re-quantized on that write).
//!
//! The storage invariant that falls out: **a cold row's stored bits are
//! always on the f16 grid**. Checkpoints, deltas and serving replicas
//! copy stored bits verbatim, so cold rows round-trip binary16 exactly
//! with no extra machinery, and FP16 wire encodings of cold rows are
//! lossless.

use crate::embedding::dynamic_table::DynamicEmbeddingTable;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::f16::quantize_f16_slice;

/// Storage/wire precision selection (`--precision` flag, checkpoint
/// metadata).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecisionMode {
    /// Everything FP32 (byte-identical to the pre-policy system).
    Fp32,
    /// FP32 hot rows, FP16 cold rows (§5.2).
    Mixed,
}

impl PrecisionMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "fp32" => Ok(PrecisionMode::Fp32),
            "mixed" => Ok(PrecisionMode::Mixed),
            other => Err(format!("invalid precision '{other}' (expected fp32|mixed)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            PrecisionMode::Fp32 => "fp32",
            PrecisionMode::Mixed => "mixed",
        }
    }
}

/// Hot/cold partitioning policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPolicy {
    /// Rows with post-bump `access_count >= hot_threshold` stay FP32.
    pub hot_threshold: u32,
    /// Enable mixed precision; if false everything is FP32.
    pub enabled: bool,
}

impl Default for PrecisionPolicy {
    fn default() -> Self {
        PrecisionPolicy {
            hot_threshold: 8,
            enabled: true,
        }
    }
}

impl PrecisionPolicy {
    /// Pure-FP32 policy (the system default; zero behavioral change).
    pub fn fp32() -> Self {
        PrecisionPolicy {
            hot_threshold: 0,
            enabled: false,
        }
    }

    /// Mixed FP32-hot/FP16-cold policy.
    pub fn mixed(hot_threshold: u32) -> Self {
        PrecisionPolicy {
            hot_threshold,
            enabled: true,
        }
    }

    pub fn from_mode(mode: PrecisionMode, hot_threshold: u32) -> Self {
        match mode {
            PrecisionMode::Fp32 => PrecisionPolicy::fp32(),
            PrecisionMode::Mixed => PrecisionPolicy::mixed(hot_threshold),
        }
    }

    pub fn mode(&self) -> PrecisionMode {
        if self.enabled {
            PrecisionMode::Mixed
        } else {
            PrecisionMode::Fp32
        }
    }

    /// The single classification rule: hot iff the (post-bump) access
    /// count clears the threshold. Disabled policies treat every row as
    /// hot (FP32).
    #[inline]
    pub fn is_hot_count(&self, access_count: u32) -> bool {
        !self.enabled || access_count >= self.hot_threshold
    }
}

/// Running counts for memory accounting and the §5.2 ablations.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrecisionStats {
    pub hot_rows: usize,
    pub cold_rows: usize,
    pub quantize_ops: u64,
}

impl PrecisionStats {
    /// Fold another snapshot into this one (stripe / group aggregation).
    pub fn merge(&mut self, other: &PrecisionStats) {
        self.hot_rows += other.hot_rows;
        self.cold_rows += other.cold_rows;
        self.quantize_ops += other.quantize_ops;
    }

    /// Effective value-storage bytes at `dim`: hot rows 4 B, cold 2 B
    /// per element.
    pub fn effective_value_bytes(&self, dim: usize) -> usize {
        self.hot_rows * dim * 4 + self.cold_rows * dim * 2
    }
}

/// Mixed-precision wrapper over the dynamic table.
pub struct MixedPrecisionTable {
    inner: DynamicEmbeddingTable,
    policy: PrecisionPolicy,
    pub stats: PrecisionStats,
}

impl MixedPrecisionTable {
    pub fn new(inner: DynamicEmbeddingTable, policy: PrecisionPolicy) -> Self {
        MixedPrecisionTable {
            inner,
            policy,
            stats: PrecisionStats::default(),
        }
    }

    pub fn inner(&self) -> &DynamicEmbeddingTable {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut DynamicEmbeddingTable {
        &mut self.inner
    }

    pub fn policy(&self) -> PrecisionPolicy {
        self.policy
    }

    /// Is this row currently in the hot (FP32) set?
    pub fn is_hot(&self, id: GlobalId) -> bool {
        match self.inner.row_meta(id) {
            Some((count, _)) => self.policy.is_hot_count(count),
            None => false,
        }
    }

    /// Quantize the stored row (and the caller's copy) if the row is
    /// cold *after* the operation that just bumped its metadata. The
    /// stored bits and the bits handed to compute stay identical.
    fn quantize_if_cold(&mut self, id: GlobalId, out: Option<&mut [f32]>) {
        if !self.policy.enabled || self.is_hot(id) {
            return;
        }
        if let Some(row) = self.inner.row_mut_untracked(id) {
            quantize_f16_slice(row);
            if let Some(out) = out {
                out.copy_from_slice(row);
            }
            self.stats.quantize_ops += 1;
        }
    }

    /// Recompute the hot/cold row census (cheap full scan, run once per
    /// reporting interval, not per step).
    pub fn refresh_census(&mut self) {
        let threshold = if self.policy.enabled {
            self.policy.hot_threshold
        } else {
            0
        };
        let (hot, cold) = self.inner.hot_cold_census(threshold);
        self.stats.hot_rows = hot;
        self.stats.cold_rows = cold;
    }

    /// Effective storage bytes under the mixed scheme: hot rows at 4 B,
    /// cold rows at 2 B per element (plus key structure overhead from the
    /// inner table's slot array).
    pub fn effective_value_bytes(&self) -> usize {
        let d = self.inner.dim();
        if !self.policy.enabled {
            return (self.stats.hot_rows + self.stats.cold_rows) * d * 4;
        }
        self.stats.effective_value_bytes(d)
    }

    /// Wire bytes for transmitting `rows` embedding rows of which
    /// `cold_fraction` are cold (FP16 on the wire). The cold count
    /// rounds to nearest (a truncating cast undercounted the cold set
    /// and overstated wire volume).
    pub fn wire_bytes(&self, rows: usize, cold_fraction: f64) -> usize {
        let d = self.inner.dim();
        if !self.policy.enabled {
            return rows * d * 4;
        }
        let cold = ((rows as f64 * cold_fraction).round() as usize).min(rows);
        (rows - cold) * d * 4 + cold * d * 2
    }
}

impl EmbeddingStore for MixedPrecisionTable {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        let existed = self.inner.lookup_or_insert(id, out);
        // Cold rows are *stored* as f16: the stored bits and the values
        // handed to compute are both the quantized ones.
        self.quantize_if_cold(id, Some(out));
        existed
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        // Read-only path: cold stored bits are already on the f16 grid
        // (every write-back quantizes), so the stored value is returned
        // verbatim and classification needs no metadata bump.
        self.inner.lookup(id, out)
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        let ok = self.inner.apply_delta(id, delta);
        // Classify AFTER the inner table bumped the access count — the
        // same post-bump rule as lookup_or_insert. A row whose crossing
        // write just promoted it to hot is served at full precision;
        // rows still cold re-quantize on write-back, which is where FP16
        // storage accumulates quantization error (exactly why the paper
        // keeps hot rows FP32).
        if ok {
            self.quantize_if_cold(id, None);
        }
        ok
    }

    fn memory_bytes(&self) -> usize {
        // Key structure + metadata from the inner table, values at mixed
        // precision. Saturate the subtraction: the inner accounting may
        // legitimately report less than the live-value bytes (chunked
        // allocation counts allocated, not live, rows — if that changes,
        // misreporting must not wrap).
        let full = self.inner.memory_bytes();
        let d = self.inner.dim();
        let value_bytes_f32 = self.inner.len() * d * 4;
        full.saturating_sub(value_bytes_f32) + self.effective_value_bytes()
    }

    fn precision_policy(&self) -> PrecisionPolicy {
        self.policy
    }

    fn row_is_hot(&self, id: GlobalId) -> Option<bool> {
        self.inner
            .row_meta(id)
            .map(|(count, _)| self.policy.is_hot_count(count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::dynamic_table::DynamicTableConfig;
    use crate::util::f16::quantize_f16;

    fn table(threshold: u32) -> MixedPrecisionTable {
        MixedPrecisionTable::new(
            DynamicEmbeddingTable::new(DynamicTableConfig::new(8).with_capacity(64)),
            PrecisionPolicy::mixed(threshold),
        )
    }

    fn on_f16_grid(xs: &[f32]) -> bool {
        xs.iter().all(|&v| v == quantize_f16(v))
    }

    #[test]
    fn cold_rows_are_quantized() {
        let mut t = table(1000); // everything cold
        let mut out = vec![0.0f32; 8];
        t.lookup_or_insert(1, &mut out);
        assert!(on_f16_grid(&out), "returned value not on f16 grid");
        // The STORED bits are quantized too, not just the returned copy.
        assert!(
            on_f16_grid(t.inner().row(1).unwrap()),
            "stored value not on f16 grid"
        );
    }

    #[test]
    fn hot_rows_stay_fp32() {
        let mut t = table(3);
        let mut out = vec![0.0f32; 8];
        // Three accesses promote the row to hot.
        t.lookup_or_insert(7, &mut out);
        t.lookup_or_insert(7, &mut out);
        t.lookup_or_insert(7, &mut out);
        assert!(t.is_hot(7));
        // Apply a delta that is NOT representable in f16 relative terms.
        assert!(t.apply_delta(7, &[1e-4; 8]));
        let mut after = vec![0.0f32; 8];
        t.lookup_or_insert(7, &mut after); // still hot → unquantized read
        // Full f32 precision retained: difference ≈ 1e-4 (up to f32 ulp),
        // whereas f16 storage would have absorbed it entirely for most
        // magnitudes.
        for i in 0..8 {
            assert!(((after[i] - out[i]) - 1e-4).abs() < 1e-6);
        }
    }

    /// Regression for the read/write classification asymmetry: a row
    /// whose access count crosses `hot_threshold` ON an `apply_delta`
    /// must be classified post-bump (hot) and therefore NOT re-quantized
    /// by that write — the same rule `lookup_or_insert` applies.
    #[test]
    fn threshold_crossing_write_is_not_requantized() {
        let threshold = 3u32;
        let mut t = table(threshold);
        let mut out = vec![0.0f32; 8];
        // Two touches: insert (count 1) + hit (count 2) — one below the
        // threshold, still cold, stored bits on the f16 grid.
        t.lookup_or_insert(9, &mut out);
        t.lookup_or_insert(9, &mut out);
        assert!(!t.is_hot(9));
        assert!(on_f16_grid(t.inner().row(9).unwrap()));
        // The crossing write: count 2 → 3 == threshold. Post-bump the
        // row is hot, so the delta must land at full f32 precision.
        let tiny = 1e-6f32; // far below f16 resolution near |v|≈0.1
        assert!(t.apply_delta(9, &[tiny; 8]));
        assert!(t.is_hot(9), "crossing write must promote post-bump");
        let stored = t.inner().row(9).unwrap();
        for (i, (&s, &o)) in stored.iter().zip(out.iter()).enumerate() {
            assert_eq!(
                s,
                o + tiny,
                "dim {i}: promoting write was quantized (pre-bump classification)"
            );
        }
        // And the next read returns those exact fp32 bits.
        let mut back = vec![0.0f32; 8];
        assert!(t.lookup_or_insert(9, &mut back));
        for i in 0..8 {
            assert_eq!(back[i], out[i] + tiny, "dim {i}");
        }
    }

    #[test]
    fn reads_and_writes_share_one_classification() {
        // Drive the same id through interleaved reads and writes around
        // the threshold; at every point the stored bits must be on the
        // f16 grid iff the post-bump count is below the threshold.
        let threshold = 4u32;
        let mut t = table(threshold);
        let mut out = vec![0.0f32; 8];
        t.lookup_or_insert(11, &mut out); // count 1
        assert!(t.apply_delta(11, &[0.123; 8])); // count 2, still cold
        assert!(on_f16_grid(t.inner().row(11).unwrap()));
        t.lookup_or_insert(11, &mut out); // count 3, still cold
        assert!(on_f16_grid(&out));
        assert!(t.apply_delta(11, &[1e-6; 8])); // count 4 → hot on the write
        assert!(t.is_hot(11));
        let stored = t.inner().row(11).unwrap().to_vec();
        assert_eq!(
            stored,
            out.iter().map(|&v| v + 1e-6).collect::<Vec<_>>(),
            "write and subsequent reads disagree on classification"
        );
    }

    #[test]
    fn cold_write_back_accumulates_quantization() {
        let mut t = table(1000); // forever cold
        let mut v0 = vec![0.0f32; 8];
        t.lookup_or_insert(5, &mut v0);
        // A tiny delta below f16 resolution around |v|≈0.1 is lost.
        let tiny = 1e-6f32;
        t.apply_delta(5, &[tiny; 8]);
        let mut v1 = vec![0.0f32; 8];
        t.lookup(5, &mut v1);
        assert_eq!(v0, v1, "sub-resolution delta absorbed by f16 storage");
        assert!(t.stats.quantize_ops > 0);
    }

    #[test]
    fn census_and_memory_accounting() {
        let mut t = table(2);
        let mut out = vec![0.0f32; 8];
        // ids 0..10 cold (1 access), id 42 hot (3 accesses).
        for id in 0..10 {
            t.lookup_or_insert(id, &mut out);
        }
        for _ in 0..3 {
            t.lookup_or_insert(42, &mut out);
        }
        t.refresh_census();
        assert_eq!(t.stats.hot_rows, 1);
        assert_eq!(t.stats.cold_rows, 10);
        let eff = t.effective_value_bytes();
        assert_eq!(eff, 8 * 4 + 10 * 8 * 2);
        // Mixed-precision memory strictly below all-FP32 memory.
        assert!(t.memory_bytes() < t.inner().memory_bytes());
    }

    #[test]
    fn wire_bytes_scale_with_cold_fraction() {
        let t = table(2);
        assert_eq!(t.wire_bytes(100, 0.0), 100 * 8 * 4);
        assert_eq!(t.wire_bytes(100, 1.0), 100 * 8 * 2);
        assert_eq!(t.wire_bytes(100, 0.5), 50 * 8 * 4 + 50 * 8 * 2);
    }

    /// Regression for the truncating cold-count cast: a fraction that
    /// rounds up must round up, and float error near 1.0 must never
    /// produce cold > rows.
    #[test]
    fn wire_bytes_rounds_cold_count() {
        let t = table(2);
        let d = 8;
        // 10 × 0.55 = 5.5 → 6 cold (round-to-nearest), not 5 (truncate).
        assert_eq!(t.wire_bytes(10, 0.55), 4 * d * 4 + 6 * d * 2);
        // Accumulated float error cannot push cold beyond rows.
        assert_eq!(t.wire_bytes(3, 0.999_999_9), 3 * d * 2);
        // The undercount case from the bug: 3 × (2/3) = 1.9999… was
        // truncated to 1 cold; must round to 2.
        assert_eq!(t.wire_bytes(3, 2.0 / 3.0), d * 4 + 2 * d * 2);
    }

    #[test]
    fn disabled_policy_is_transparent_fp32() {
        let mut t = MixedPrecisionTable::new(
            DynamicEmbeddingTable::new(DynamicTableConfig::new(4).with_capacity(64)),
            PrecisionPolicy::fp32(),
        );
        let mut out = vec![0.0f32; 4];
        t.lookup_or_insert(1, &mut out);
        assert!(t.apply_delta(1, &[1e-5; 4]));
        let mut v = vec![0.0f32; 4];
        t.lookup(1, &mut v);
        for i in 0..4 {
            // No f16 quantization anywhere: the small delta survives to
            // f32 precision.
            assert!(((v[i] - out[i]) - 1e-5).abs() < 1e-7);
            assert_ne!(v[i], out[i]);
        }
        assert_eq!(t.stats.quantize_ops, 0);
    }

    #[test]
    fn precision_mode_parses() {
        assert_eq!(PrecisionMode::parse("fp32").unwrap(), PrecisionMode::Fp32);
        assert_eq!(PrecisionMode::parse("mixed").unwrap(), PrecisionMode::Mixed);
        assert!(PrecisionMode::parse("bf16").is_err());
        assert_eq!(PrecisionMode::Mixed.as_str(), "mixed");
        assert_eq!(
            PrecisionPolicy::from_mode(PrecisionMode::Fp32, 8).mode(),
            PrecisionMode::Fp32
        );
        assert_eq!(
            PrecisionPolicy::from_mode(PrecisionMode::Mixed, 8).mode(),
            PrecisionMode::Mixed
        );
    }
}
