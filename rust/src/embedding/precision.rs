//! Mixed-precision embedding storage (§5.2).
//!
//! "For high-frequency accessed feature embeddings, we preserve embedding
//! vectors in FP32 format to avoid quantization accumulation errors caused
//! by frequent gradient updates. Conversely, low-frequency features employ
//! FP16 storage and computation, significantly reducing memory footprint
//! while accelerating table lookup operations."
//!
//! [`MixedPrecisionTable`] wraps a [`DynamicEmbeddingTable`], dynamically
//! partitioning rows into *hot* (FP32, access count ≥ threshold) and
//! *cold* (FP16) sets. Cold rows physically round-trip through IEEE
//! binary16 on every write-back, so the quantization error the paper
//! accepts for cold rows is actually applied; memory/communication
//! accounting reports cold rows at 2 bytes/element.

use crate::embedding::dynamic_table::DynamicEmbeddingTable;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::f16::quantize_f16_slice;

/// Hot/cold partitioning policy.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionPolicy {
    /// Rows with `access_count >= hot_threshold` stay FP32.
    pub hot_threshold: u32,
    /// Enable mixed precision; if false everything is FP32.
    pub enabled: bool,
}

impl Default for PrecisionPolicy {
    fn default() -> Self {
        PrecisionPolicy {
            hot_threshold: 8,
            enabled: true,
        }
    }
}

/// Running counts for memory accounting and the §5.2 ablations.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrecisionStats {
    pub hot_rows: usize,
    pub cold_rows: usize,
    pub quantize_ops: u64,
}

/// Mixed-precision wrapper over the dynamic table.
pub struct MixedPrecisionTable {
    inner: DynamicEmbeddingTable,
    policy: PrecisionPolicy,
    pub stats: PrecisionStats,
}

impl MixedPrecisionTable {
    pub fn new(inner: DynamicEmbeddingTable, policy: PrecisionPolicy) -> Self {
        MixedPrecisionTable {
            inner,
            policy,
            stats: PrecisionStats::default(),
        }
    }

    pub fn inner(&self) -> &DynamicEmbeddingTable {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut DynamicEmbeddingTable {
        &mut self.inner
    }

    /// Is this row currently in the hot (FP32) set?
    pub fn is_hot(&self, id: GlobalId) -> bool {
        match self.inner.row_meta(id) {
            Some((count, _)) => count >= self.policy.hot_threshold,
            None => false,
        }
    }

    /// Recompute the hot/cold row census (cheap full scan, run once per
    /// reporting interval, not per step).
    pub fn refresh_census(&mut self) {
        let mut hot = 0;
        let mut cold = 0;
        let ids: Vec<GlobalId> = self.inner.iter_rows().map(|(id, _)| id).collect();
        for id in ids {
            if self.is_hot(id) {
                hot += 1;
            } else {
                cold += 1;
            }
        }
        self.stats.hot_rows = hot;
        self.stats.cold_rows = cold;
    }

    /// Effective storage bytes under the mixed scheme: hot rows at 4 B,
    /// cold rows at 2 B per element (plus key structure overhead from the
    /// inner table's slot array).
    pub fn effective_value_bytes(&self) -> usize {
        let d = self.inner.dim();
        if !self.policy.enabled {
            return (self.stats.hot_rows + self.stats.cold_rows) * d * 4;
        }
        self.stats.hot_rows * d * 4 + self.stats.cold_rows * d * 2
    }

    /// Wire bytes for transmitting `rows` embedding rows of which
    /// `cold_fraction` are cold (FP16 on the wire).
    pub fn wire_bytes(&self, rows: usize, cold_fraction: f64) -> usize {
        let d = self.inner.dim();
        if !self.policy.enabled {
            return rows * d * 4;
        }
        let cold = (rows as f64 * cold_fraction) as usize;
        (rows - cold) * d * 4 + cold * d * 2
    }
}

impl EmbeddingStore for MixedPrecisionTable {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        let existed = self.inner.lookup_or_insert(id, out);
        // Cold rows are *stored* as f16: the values handed to compute are
        // the quantized ones.
        if self.policy.enabled && !self.is_hot(id) {
            quantize_f16_slice(out);
            self.stats.quantize_ops += 1;
        }
        existed
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        let found = self.inner.lookup(id, out);
        if found && self.policy.enabled && !self.is_hot(id) {
            quantize_f16_slice(out);
        }
        found
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        let hot = !self.policy.enabled || self.is_hot(id);
        let ok = self.inner.apply_delta(id, delta);
        if ok && !hot {
            // Write-back for a cold row re-quantizes the stored value —
            // this is where FP16 storage accumulates quantization error,
            // which is exactly why the paper keeps hot rows FP32.
            if let Some(row) = self.inner.row_mut(id) {
                quantize_f16_slice(row);
            }
            self.stats.quantize_ops += 1;
        }
        ok
    }

    fn memory_bytes(&self) -> usize {
        // Key structure + metadata from the inner table, values at mixed
        // precision.
        let full = self.inner.memory_bytes();
        let d = self.inner.dim();
        let value_bytes_f32 = self.inner.len() * d * 4;
        full - value_bytes_f32.min(full) + self.effective_value_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::dynamic_table::DynamicTableConfig;

    fn table(threshold: u32) -> MixedPrecisionTable {
        MixedPrecisionTable::new(
            DynamicEmbeddingTable::new(DynamicTableConfig::new(8).with_capacity(64)),
            PrecisionPolicy {
                hot_threshold: threshold,
                enabled: true,
            },
        )
    }

    #[test]
    fn cold_rows_are_quantized() {
        let mut t = table(1000); // everything cold
        let mut out = vec![0.0f32; 8];
        t.lookup_or_insert(1, &mut out);
        for &v in &out {
            assert_eq!(v, crate::util::f16::quantize_f16(v), "value not on f16 grid");
        }
    }

    #[test]
    fn hot_rows_stay_fp32() {
        let mut t = table(3);
        let mut out = vec![0.0f32; 8];
        // Three accesses promote the row to hot.
        t.lookup_or_insert(7, &mut out);
        t.lookup_or_insert(7, &mut out);
        t.lookup_or_insert(7, &mut out);
        assert!(t.is_hot(7));
        // Apply a delta that is NOT representable in f16 relative terms.
        assert!(t.apply_delta(7, &[1e-4; 8]));
        let mut after = vec![0.0f32; 8];
        t.lookup_or_insert(7, &mut after); // still hot → unquantized read
        // Full f32 precision retained: difference ≈ 1e-4 (up to f32 ulp),
        // whereas f16 storage would have absorbed it entirely for most
        // magnitudes.
        for i in 0..8 {
            assert!(((after[i] - out[i]) - 1e-4).abs() < 1e-6);
        }
    }

    #[test]
    fn cold_write_back_accumulates_quantization() {
        let mut t = table(1000); //永 cold
        let mut v0 = vec![0.0f32; 8];
        t.lookup_or_insert(5, &mut v0);
        // A tiny delta below f16 resolution around |v|≈0.1 is lost.
        let tiny = 1e-6f32;
        t.apply_delta(5, &[tiny; 8]);
        let mut v1 = vec![0.0f32; 8];
        t.lookup(5, &mut v1);
        assert_eq!(v0, v1, "sub-resolution delta absorbed by f16 storage");
        assert!(t.stats.quantize_ops > 0);
    }

    #[test]
    fn census_and_memory_accounting() {
        let mut t = table(2);
        let mut out = vec![0.0f32; 8];
        // ids 0..10 cold (1 access), id 42 hot (3 accesses).
        for id in 0..10 {
            t.lookup_or_insert(id, &mut out);
        }
        for _ in 0..3 {
            t.lookup_or_insert(42, &mut out);
        }
        t.refresh_census();
        assert_eq!(t.stats.hot_rows, 1);
        assert_eq!(t.stats.cold_rows, 10);
        let eff = t.effective_value_bytes();
        assert_eq!(eff, 1 * 8 * 4 + 10 * 8 * 2);
        // Mixed-precision memory strictly below all-FP32 memory.
        assert!(t.memory_bytes() < t.inner().memory_bytes());
    }

    #[test]
    fn wire_bytes_scale_with_cold_fraction() {
        let t = table(2);
        assert_eq!(t.wire_bytes(100, 0.0), 100 * 8 * 4);
        assert_eq!(t.wire_bytes(100, 1.0), 100 * 8 * 2);
        assert_eq!(t.wire_bytes(100, 0.5), 50 * 8 * 4 + 50 * 8 * 2);
    }

    #[test]
    fn disabled_policy_is_transparent_fp32() {
        let mut t = MixedPrecisionTable::new(
            DynamicEmbeddingTable::new(DynamicTableConfig::new(4).with_capacity(64)),
            PrecisionPolicy {
                hot_threshold: 1,
                enabled: false,
            },
        );
        let mut out = vec![0.0f32; 4];
        t.lookup_or_insert(1, &mut out);
        assert!(t.apply_delta(1, &[1e-5; 4]));
        let mut v = vec![0.0f32; 4];
        t.lookup(1, &mut v);
        for i in 0..4 {
            // No f16 quantization anywhere: the small delta survives to
            // f32 precision.
            assert!(((v[i] - out[i]) - 1e-5).abs() < 1e-7);
            assert_ne!(v[i], out[i]);
        }
    }
}
