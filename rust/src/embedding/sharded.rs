//! Model-parallel sharded embedding lookup/update (§3 Fig. 5, §4.3).
//!
//! Embedding tables are sharded across devices by `hash(id) % world`.
//! Each lookup performs the paper's two all-to-alls — **ID communication**
//! then **embedding communication** — with the two-stage deduplication of
//! §4.3 applied according to a [`DedupStrategy`]:
//!
//! 1. *Stage 1* (requester): deduplicate the IDs headed to each peer
//!    before the ID all-to-all, shrinking both the ID payload and —
//!    decisively — the embedding payload coming back.
//! 2. *Stage 2* (server): the IDs received from different peers overlap;
//!    deduplicate the union before touching the hash table so each row is
//!    fetched once.
//!
//! Backward mirrors forward: occurrence gradients are aggregated per
//! destination (sparse accumulation), exchanged via all-to-all, and
//! aggregated again on the owning shard.
//!
//! The lookup is a **two-phase pipeline**: [`ShardedEmbedding::post_ids`]
//! partitions + stage-1 dedups and posts the ID all-to-all without
//! blocking; [`ShardedEmbedding::complete_lookup`] serves and runs the
//! embedding exchange. The trainer posts micro-batch *k+1*'s IDs while
//! micro-batch *k* computes, overlapping ID communication with work —
//! the TurboGR-style overlap the `--overlap` ablation toggles.

use crate::collective::comm::{CommHandle, Message, PendingAllToAll, LANE_EMB, LANE_IDS};
use crate::embedding::dedup::{gather_rows, scatter_accumulate, Dedup, DedupStrategy, DedupVolume};
use crate::embedding::hash::hash_id;
use crate::embedding::{EmbeddingStore, GlobalId};

/// Seed for the shard-placement hash (distinct from table hashing so
/// shard residence and slot probing are independent).
const SHARD_SEED: u64 = 0x5A4D;

/// Per-rank shard of a (merged) embedding table plus the exchange logic.
pub struct ShardedEmbedding<S: EmbeddingStore> {
    table: S,
    dim: usize,
    pub strategy: DedupStrategy,
    /// Cumulative communication-volume accounting (drives Fig. 16).
    pub volume: DedupVolume,
    /// Per-pair bytes of the most recently *completed* lookup (for the
    /// net cost model): `last_id_bytes[dst]`, `last_emb_bytes[dst]`.
    /// Both meters update together in `complete_lookup`, so they always
    /// describe the same exchange even when several are posted.
    pub last_id_bytes: Vec<usize>,
    pub last_emb_bytes: Vec<usize>,
}

/// Which rank owns `id`.
pub fn shard_owner(id: GlobalId, world: usize) -> usize {
    (hash_id(id, SHARD_SEED) % world as u64) as usize
}

/// In-flight state of a posted sharded lookup: the ID all-to-all is on
/// the wire; the partition layout needed to serve and scatter rides
/// along until [`ShardedEmbedding::complete_lookup`] consumes it.
#[must_use = "a posted lookup must be completed or peers deadlock"]
pub struct PendingLookup {
    num_ids: usize,
    pos_by_dst: Vec<Vec<u32>>,
    stage1_inverse: Vec<Option<Vec<u32>>>,
    /// Per-destination unique (post-stage-1) id counts.
    sent_lens: Vec<usize>,
    /// Per-destination raw occurrence counts.
    raw_lens: Vec<usize>,
    /// Per-destination ID bytes posted (installed into
    /// `last_id_bytes` at completion so the `last_*_bytes` pair always
    /// describes the same exchange, even under pipelining).
    id_bytes: Vec<usize>,
    pending: PendingAllToAll,
}

impl<S: EmbeddingStore> ShardedEmbedding<S> {
    pub fn new(table: S, strategy: DedupStrategy) -> Self {
        let dim = table.dim();
        ShardedEmbedding {
            table,
            dim,
            strategy,
            volume: DedupVolume::default(),
            last_id_bytes: Vec::new(),
            last_emb_bytes: Vec::new(),
        }
    }

    pub fn table(&self) -> &S {
        &self.table
    }

    pub fn table_mut(&mut self) -> &mut S {
        &mut self.table
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Distributed lookup: returns rows in occurrence order
    /// (`ids.len() × dim`). `train` controls insert-on-miss semantics.
    ///
    /// All ranks must call this collectively (it contains two
    /// all-to-alls), even with an empty `ids` list. Equivalent to
    /// [`post_ids`](Self::post_ids) immediately followed by
    /// [`complete_lookup`](Self::complete_lookup).
    pub fn lookup(&mut self, comm: &mut CommHandle, ids: &[GlobalId], train: bool) -> Vec<f32> {
        let pending = self.post_ids(comm, ids);
        self.complete_lookup(comm, pending, train)
    }

    /// Phase 1 of the pipelined lookup: partition `ids` by owner, apply
    /// stage-1 dedup, and *post* the ID all-to-all (sends enqueue
    /// immediately; nothing blocks). The returned [`PendingLookup`] must
    /// be passed to [`complete_lookup`](Self::complete_lookup) — and
    /// because posted exchanges ride dedicated comm lanes, the trainer
    /// may post micro-batch *k+1*'s IDs before completing micro-batch
    /// *k*, hiding ID communication behind compute (§3's overlap).
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_ids(&mut self, comm: &mut CommHandle, ids: &[GlobalId]) -> PendingLookup {
        let world = comm.world;

        // ---- partition by owner ------------------------------------
        let mut ids_by_dst: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
        let mut pos_by_dst: Vec<Vec<u32>> = vec![Vec::new(); world];
        for (i, &id) in ids.iter().enumerate() {
            let d = shard_owner(id, world);
            ids_by_dst[d].push(id);
            pos_by_dst[d].push(i as u32);
        }

        // ---- stage 1: per-destination dedup -------------------------
        let mut send_ids: Vec<Vec<GlobalId>> = Vec::with_capacity(world);
        let mut stage1_inverse: Vec<Option<Vec<u32>>> = Vec::with_capacity(world);
        for bucket in &ids_by_dst {
            self.volume.ids_raw += bucket.len();
            if self.strategy.stage1() {
                let d = Dedup::of(bucket);
                self.volume.ids_sent += d.unique.len();
                send_ids.push(d.unique);
                stage1_inverse.push(Some(d.inverse));
            } else {
                self.volume.ids_sent += bucket.len();
                send_ids.push(bucket.clone());
                stage1_inverse.push(None);
            }
        }
        let id_bytes: Vec<usize> = send_ids.iter().map(|v| v.len() * 8).collect();
        let sent_lens: Vec<usize> = send_ids.iter().map(|v| v.len()).collect();
        let raw_lens: Vec<usize> = ids_by_dst.iter().map(|v| v.len()).collect();

        // ---- ID all-to-all (posted, non-blocking) --------------------
        let pending = comm.post_all_to_all_on(
            LANE_IDS,
            send_ids.into_iter().map(Message::Ids).collect(),
        );
        PendingLookup {
            num_ids: ids.len(),
            pos_by_dst,
            stage1_inverse,
            sent_lens,
            raw_lens,
            id_bytes,
            pending,
        }
    }

    /// Phase 2 of the pipelined lookup: receive the requested IDs, serve
    /// them from the local shard (stage-2 dedup), run the embedding
    /// all-to-all, and scatter rows back to occurrence order.
    pub fn complete_lookup(
        &mut self,
        comm: &mut CommHandle,
        lookup: PendingLookup,
        train: bool,
    ) -> Vec<f32> {
        let world = comm.world;
        let dim = self.dim;
        let PendingLookup {
            num_ids,
            pos_by_dst,
            stage1_inverse,
            sent_lens,
            raw_lens,
            id_bytes,
            pending,
        } = lookup;
        self.last_id_bytes = id_bytes;
        let requested: Vec<Vec<GlobalId>> = comm
            .complete_all_to_all(pending)
            .into_iter()
            .map(Message::into_ids)
            .collect();

        // ---- serve: stage-2 dedup + local table lookup ---------------
        let total_req: usize = requested.iter().map(|r| r.len()).sum();
        self.volume.lookups_raw += total_req;
        let replies: Vec<Vec<f32>> = if self.strategy.stage2() {
            // Dedup the union across sources, fetch once per unique id.
            let flat: Vec<GlobalId> = requested.iter().flatten().copied().collect();
            let d = Dedup::of(&flat);
            self.volume.lookups_done += d.unique.len();
            let mut unique_rows = vec![0.0f32; d.unique.len() * dim];
            for (u, &id) in d.unique.iter().enumerate() {
                self.fetch(id, train, &mut unique_rows[u * dim..(u + 1) * dim]);
            }
            // Slice the expanded rows back per source.
            let mut out = Vec::with_capacity(world);
            let mut off = 0usize;
            for req in &requested {
                let inv = &d.inverse[off..off + req.len()];
                let mut rows = vec![0.0f32; req.len() * dim];
                gather_rows(&unique_rows, dim, inv, &mut rows);
                out.push(rows);
                off += req.len();
            }
            out
        } else {
            self.volume.lookups_done += total_req;
            requested
                .iter()
                .map(|req| {
                    let mut rows = vec![0.0f32; req.len() * dim];
                    for (i, &id) in req.iter().enumerate() {
                        self.fetch(id, train, &mut rows[i * dim..(i + 1) * dim]);
                    }
                    rows
                })
                .collect()
        };

        // ---- embedding all-to-all ------------------------------------
        // Reply row counts mirror the *received* id counts; the raw
        // (no-stage-1) counterpart is what we would have sent without
        // dedup — accounted for Fig. 16.
        for dst in 0..world {
            self.volume.emb_rows_raw += raw_lens[dst];
            self.volume.emb_rows_sent += sent_lens[dst];
        }
        self.last_emb_bytes = replies.iter().map(|r| r.len() * 4).collect();
        let emb_pending = comm.post_all_to_all_on(
            LANE_EMB,
            replies.into_iter().map(Message::Floats).collect(),
        );
        let returned: Vec<Vec<f32>> = comm
            .complete_all_to_all(emb_pending)
            .into_iter()
            .map(Message::into_floats)
            .collect();

        // ---- scatter back to occurrence order ------------------------
        let mut out = vec![0.0f32; num_ids * dim];
        for dst in 0..world {
            let rows = &returned[dst];
            // Expand through the stage-1 inverse if we deduped.
            let expanded: Vec<f32> = match &stage1_inverse[dst] {
                Some(inv) => {
                    let mut e = vec![0.0f32; inv.len() * dim];
                    gather_rows(rows, dim, inv, &mut e);
                    e
                }
                None => rows.clone(),
            };
            for (j, &pos) in pos_by_dst[dst].iter().enumerate() {
                out[pos as usize * dim..(pos as usize + 1) * dim]
                    .copy_from_slice(&expanded[j * dim..(j + 1) * dim]);
            }
        }
        out
    }

    fn fetch(&mut self, id: GlobalId, train: bool, out: &mut [f32]) {
        if train {
            self.table.lookup_or_insert(id, out);
        } else {
            self.table.lookup(id, out);
        }
    }

    /// Distributed backward: exchange occurrence-order gradients so each
    /// shard receives the *aggregated* gradient for the ids it owns.
    /// Returns `(ids, grads)` for the local shard (grads in id order,
    /// `ids.len() × dim`); the caller feeds these to the sparse optimizer.
    ///
    /// Collective: all ranks must call.
    pub fn backward(
        &mut self,
        comm: &mut CommHandle,
        ids: &[GlobalId],
        grads: &[f32],
    ) -> (Vec<GlobalId>, Vec<f32>) {
        assert_eq!(grads.len(), ids.len() * self.dim);
        let world = comm.world;
        let dim = self.dim;

        // Partition occurrences by owner, aggregating duplicates per
        // destination (sparse gradient accumulation, §5.2) when stage-1
        // dedup is on; otherwise raw occurrence gradients go on the wire.
        let mut ids_by_dst: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
        let mut grad_by_dst: Vec<Vec<f32>> = vec![Vec::new(); world];
        {
            let mut occ_ids: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
            let mut occ_grads: Vec<Vec<f32>> = vec![Vec::new(); world];
            for (i, &id) in ids.iter().enumerate() {
                let d = shard_owner(id, world);
                occ_ids[d].push(id);
                occ_grads[d].extend_from_slice(&grads[i * dim..(i + 1) * dim]);
            }
            for d in 0..world {
                if self.strategy.stage1() {
                    let dd = Dedup::of(&occ_ids[d]);
                    let mut agg = vec![0.0f32; dd.unique.len() * dim];
                    scatter_accumulate(&occ_grads[d], dim, &dd.inverse, &mut agg);
                    ids_by_dst[d] = dd.unique;
                    grad_by_dst[d] = agg;
                } else {
                    ids_by_dst[d] = std::mem::take(&mut occ_ids[d]);
                    grad_by_dst[d] = std::mem::take(&mut occ_grads[d]);
                }
            }
        }

        // Two all-to-alls: ids then gradients (same wire pattern as
        // forward, reversed direction for the payload).
        let recv_ids: Vec<Vec<GlobalId>> = comm
            .all_to_all(ids_by_dst.iter().cloned().map(Message::Ids).collect())
            .into_iter()
            .map(Message::into_ids)
            .collect();
        let recv_grads: Vec<Vec<f32>> = comm
            .all_to_all(grad_by_dst.into_iter().map(Message::Floats).collect())
            .into_iter()
            .map(Message::into_floats)
            .collect();

        // Aggregate across sources (always — correctness requires the
        // owner to apply each id's total gradient once).
        let flat_ids: Vec<GlobalId> = recv_ids.iter().flatten().copied().collect();
        let flat_grads: Vec<f32> = recv_grads.into_iter().flatten().collect();
        let d = Dedup::of(&flat_ids);
        let mut agg = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate(&flat_grads, dim, &d.inverse, &mut agg);
        (d.unique, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::comm::CommGroup;
    use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
    use std::sync::Arc;
    use std::thread;

    const DIM: usize = 4;

    fn run_sharded<T: Send + 'static>(
        world: usize,
        strategy: DedupStrategy,
        f: impl Fn(usize, &mut ShardedEmbedding<DynamicEmbeddingTable>, &mut CommHandle) -> T
            + Send
            + Sync
            + 'static,
    ) -> Vec<T> {
        let handles = CommGroup::new(world);
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for (rank, mut h) in handles.into_iter().enumerate() {
            let f = Arc::clone(&f);
            joins.push(thread::spawn(move || {
                let table = DynamicEmbeddingTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
                );
                let mut se = ShardedEmbedding::new(table, strategy);
                f(rank, &mut se, &mut h)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    /// Reference: what a single unsharded table would return. Row init is
    /// a pure function of (id, seed), so the expected rows are computable
    /// independently.
    fn expected_row(id: GlobalId) -> Vec<f32> {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
        );
        let mut out = vec![0.0; DIM];
        t.lookup_or_insert(id, &mut out);
        out
    }

    #[test]
    fn lookup_matches_unsharded_reference_all_strategies() {
        for strategy in [
            DedupStrategy::None,
            DedupStrategy::CommUnique,
            DedupStrategy::LookupUnique,
            DedupStrategy::TwoStage,
        ] {
            let out = run_sharded(4, strategy, |rank, se, comm| {
                // Overlapping id lists across ranks, with duplicates.
                let ids: Vec<u64> =
                    vec![1, 2, 3, 1, 2, 100 + rank as u64, 3, 1, 50, 50];
                let rows = se.lookup(comm, &ids, true);
                (ids, rows)
            });
            for (ids, rows) in out {
                for (i, &id) in ids.iter().enumerate() {
                    assert_eq!(
                        &rows[i * DIM..(i + 1) * DIM],
                        expected_row(id).as_slice(),
                        "strategy {strategy:?} id {id}"
                    );
                }
            }
        }
    }

    #[test]
    fn dedup_strategies_reduce_volume_in_order() {
        // two-stage ≤ comm-unique ≤ none for ids_sent; lookups_done
        // minimized by stage2.
        let mut results = Vec::new();
        for strategy in [
            DedupStrategy::None,
            DedupStrategy::CommUnique,
            DedupStrategy::TwoStage,
        ] {
            let out = run_sharded(4, strategy, |_rank, se, comm| {
                let ids: Vec<u64> = (0..1000).map(|i| (i % 37) as u64).collect();
                let _ = se.lookup(comm, &ids, true);
                se.volume
            });
            results.push((strategy, out[0]));
        }
        let none = results[0].1;
        let comm_u = results[1].1;
        let two = results[2].1;
        assert_eq!(none.ids_sent, none.ids_raw);
        assert!(comm_u.ids_sent < none.ids_sent);
        assert_eq!(two.ids_sent, comm_u.ids_sent);
        assert!(two.lookups_done < comm_u.lookups_done);
        assert!(comm_u.emb_rows_sent < none.emb_rows_raw);
    }

    #[test]
    fn empty_ranks_participate() {
        let out = run_sharded(3, DedupStrategy::TwoStage, |rank, se, comm| {
            let ids: Vec<u64> = if rank == 0 { vec![9, 9, 9] } else { vec![] };
            se.lookup(comm, &ids, true)
        });
        assert_eq!(out[0].len(), 3 * DIM);
        assert_eq!(&out[0][0..DIM], expected_row(9).as_slice());
        assert!(out[1].is_empty() && out[2].is_empty());
    }

    #[test]
    fn pipelined_lookup_matches_blocking_lookup() {
        // Two micro-batches per rank: post batch 1's IDs before
        // completing batch 0 (the overlap schedule), and verify rows are
        // bitwise identical to the blocking schedule.
        let out = run_sharded(4, DedupStrategy::TwoStage, |rank, se, comm| {
            let batch0: Vec<u64> = vec![1, 2, 3, 1, 50 + rank as u64];
            let batch1: Vec<u64> = vec![2, 9, 9, 70 + rank as u64];
            let p0 = se.post_ids(comm, &batch0);
            let p1 = se.post_ids(comm, &batch1); // posted before completing p0
            let rows0 = se.complete_lookup(comm, p0, true);
            let rows1 = se.complete_lookup(comm, p1, true);
            (batch0, rows0, batch1, rows1)
        });
        for (batch0, rows0, batch1, rows1) in out {
            for (i, &id) in batch0.iter().enumerate() {
                assert_eq!(&rows0[i * DIM..(i + 1) * DIM], expected_row(id).as_slice());
            }
            for (i, &id) in batch1.iter().enumerate() {
                assert_eq!(&rows1[i * DIM..(i + 1) * DIM], expected_row(id).as_slice());
            }
        }
    }

    #[test]
    fn pipelined_volume_accounting_matches_blocking() {
        let run = |pipelined: bool| {
            run_sharded(2, DedupStrategy::TwoStage, move |_rank, se, comm| {
                let batch0: Vec<u64> = (0..200).map(|i| (i % 17) as u64).collect();
                let batch1: Vec<u64> = (0..100).map(|i| (i % 5) as u64).collect();
                if pipelined {
                    let p0 = se.post_ids(comm, &batch0);
                    let p1 = se.post_ids(comm, &batch1);
                    let _ = se.complete_lookup(comm, p0, true);
                    let _ = se.complete_lookup(comm, p1, true);
                } else {
                    let _ = se.lookup(comm, &batch0, true);
                    let _ = se.lookup(comm, &batch1, true);
                }
                se.volume
            })
        };
        let blocking = run(false);
        let pipelined = run(true);
        for (b, p) in blocking.iter().zip(&pipelined) {
            assert_eq!(b, p, "volume accounting must not depend on scheduling");
        }
    }

    #[test]
    fn backward_aggregates_across_ranks_and_duplicates() {
        // Every rank contributes gradient 1.0 for id 5 twice, and rank r
        // contributes r for id 6 once. Total for id 5 = 2×world, for
        // id 6 = sum of ranks.
        let world = 4;
        let out = run_sharded(world, DedupStrategy::TwoStage, |rank, se, comm| {
            // Forward to materialize rows.
            let ids = vec![5u64, 5, 6];
            let _ = se.lookup(comm, &ids, true);
            let mut grads = vec![0.0f32; ids.len() * DIM];
            grads[0..DIM].fill(1.0);
            grads[DIM..2 * DIM].fill(1.0);
            grads[2 * DIM..3 * DIM].fill(rank as f32);
            let (lids, lgrads) = se.backward(comm, &ids, &grads);
            (lids, lgrads)
        });
        // Exactly one rank owns id 5 and one owns id 6.
        let mut seen5 = 0;
        let mut seen6 = 0;
        for (lids, lgrads) in out {
            for (i, &id) in lids.iter().enumerate() {
                let g = &lgrads[i * DIM..(i + 1) * DIM];
                if id == 5 {
                    seen5 += 1;
                    assert_eq!(g, vec![2.0 * world as f32; DIM].as_slice());
                } else if id == 6 {
                    seen6 += 1;
                    assert_eq!(g, vec![0.0 + 1.0 + 2.0 + 3.0; DIM].as_slice());
                } else {
                    panic!("unexpected id {id}");
                }
            }
        }
        assert_eq!(seen5, 1);
        assert_eq!(seen6, 1);
    }

    #[test]
    fn backward_same_totals_without_stage1() {
        let world = 2;
        for strategy in [DedupStrategy::None, DedupStrategy::TwoStage] {
            let out = run_sharded(world, strategy, |_rank, se, comm| {
                let ids = vec![1u64, 1, 2];
                let _ = se.lookup(comm, &ids, true);
                let grads = vec![0.5f32; ids.len() * DIM];
                se.backward(comm, &ids, &grads)
            });
            let mut total: f32 = 0.0;
            for (_ids, grads) in out {
                total += grads.iter().sum::<f32>();
            }
            // 3 occurrences × 2 ranks × 0.5 × DIM dims.
            assert_eq!(total, 3.0 * 2.0 * 0.5 * DIM as f32, "{strategy:?}");
        }
    }

    #[test]
    fn shard_owner_balanced() {
        let world = 8;
        let mut counts = vec![0usize; world];
        for id in 0..80_000u64 {
            counts[shard_owner(id, world)] += 1;
        }
        for &c in &counts {
            let dev = (c as f64 - 10_000.0).abs() / 10_000.0;
            assert!(dev < 0.05, "shard imbalance {c}");
        }
    }
}
