//! Model-parallel sharded embedding lookup/update (§3 Fig. 5, §4.3).
//!
//! Embedding tables are sharded across devices by `hash(id) % world`.
//! Each lookup performs the paper's two all-to-alls — **ID communication**
//! then **embedding communication** — with the two-stage deduplication of
//! §4.3 applied according to a [`DedupStrategy`]:
//!
//! 1. *Stage 1* (requester): deduplicate the IDs headed to each peer
//!    before the ID all-to-all, shrinking both the ID payload and —
//!    decisively — the embedding payload coming back.
//! 2. *Stage 2* (server): the IDs received from different peers overlap;
//!    deduplicate the union before touching the hash table so each row is
//!    fetched once.
//!
//! Backward mirrors forward: occurrence gradients are aggregated per
//! destination (sparse accumulation), exchanged via all-to-all, and
//! aggregated again on the owning shard.
//!
//! The lookup is a **three-phase, double-buffered pipeline**:
//! [`ShardedEmbedding::post_ids`] partitions + stage-1 dedups and posts
//! the ID all-to-all without blocking; [`ShardedEmbedding::serve_reply`]
//! receives the requested IDs, serves the local shard (fanning the
//! fetch across [`crate::util::pool::WorkerPool`] stripes when one is
//! attached) and *posts* the embedding reply; and
//! [`ShardedEmbedding::complete_reply`] collects the reply and scatters
//! rows back to occurrence order. Backward splits the same way
//! ([`ShardedEmbedding::post_backward`] /
//! [`ShardedEmbedding::complete_backward`]). The trainer exploits the
//! splits so that micro-batch *k+1*'s ID exchange, *k*'s embedding
//! reply, and *k−1*'s gradient push are simultaneously in flight — the
//! TurboGR-style overlap the `--overlap` ablation toggles. Every
//! parallel path is bit-identical to the serial reference for every
//! pool size (disjoint writes; per-row accumulation order preserved).

use std::sync::Arc;

use crate::collective::comm::{
    CommHandle, Message, PendingAllToAll, LANE_EMB, LANE_GRAD, LANE_GRAD_IDS, LANE_IDS,
};
use crate::embedding::dedup::{
    gather_rows_par, scatter_accumulate_par, Dedup, DedupStrategy, DedupVolume,
};
use crate::embedding::hash::hash_id;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::pool::WorkerPool;

/// Seed for the shard-placement hash (distinct from table hashing so
/// shard residence and slot probing are independent).
const SHARD_SEED: u64 = 0x5A4D;

/// Per-rank shard of a (merged) embedding table plus the exchange logic.
pub struct ShardedEmbedding<S: EmbeddingStore> {
    table: S,
    dim: usize,
    pub strategy: DedupStrategy,
    /// Cumulative communication-volume accounting (drives Fig. 16).
    pub volume: DedupVolume,
    /// Per-pair bytes of the most recently *served* lookup (for the
    /// net cost model): `last_id_bytes[dst]`, `last_emb_bytes[dst]`.
    /// Both meters update together in `serve_reply`, so they always
    /// describe the same exchange even when several are posted.
    pub last_id_bytes: Vec<usize>,
    pub last_emb_bytes: Vec<usize>,
    /// Worker pool shared by dedup, the stage-2 serve fetch, row
    /// expansion and gradient aggregation; `None` = serial reference.
    pool: Option<Arc<WorkerPool>>,
}

/// Which rank owns `id`.
pub fn shard_owner(id: GlobalId, world: usize) -> usize {
    (hash_id(id, SHARD_SEED) % world as u64) as usize
}

/// In-flight state of a posted sharded lookup: the ID all-to-all is on
/// the wire; the partition layout needed to serve and scatter rides
/// along until [`ShardedEmbedding::complete_lookup`] consumes it.
#[must_use = "a posted lookup must be completed or peers deadlock"]
pub struct PendingLookup {
    num_ids: usize,
    pos_by_dst: Vec<Vec<u32>>,
    stage1_inverse: Vec<Option<Vec<u32>>>,
    /// Per-destination unique (post-stage-1) id counts.
    sent_lens: Vec<usize>,
    /// Per-destination raw occurrence counts.
    raw_lens: Vec<usize>,
    /// Per-destination ID bytes posted (installed into
    /// `last_id_bytes` at completion so the `last_*_bytes` pair always
    /// describes the same exchange, even under pipelining).
    id_bytes: Vec<usize>,
    pending: PendingAllToAll,
}

/// In-flight state of a served lookup: the embedding reply all-to-all
/// is on the wire; the scatter layout rides along until
/// [`ShardedEmbedding::complete_reply`] consumes it.
#[must_use = "a served lookup must be completed or peers deadlock"]
pub struct PendingReply {
    num_ids: usize,
    pos_by_dst: Vec<Vec<u32>>,
    stage1_inverse: Vec<Option<Vec<u32>>>,
    pending: PendingAllToAll,
}

/// In-flight state of a posted backward gradient exchange (IDs +
/// payloads on dedicated lanes); completed by
/// [`ShardedEmbedding::complete_backward`].
#[must_use = "a posted backward must be completed or peers deadlock"]
pub struct PendingBackward {
    ids_pending: PendingAllToAll,
    grads_pending: PendingAllToAll,
}

impl<S: EmbeddingStore> ShardedEmbedding<S> {
    pub fn new(table: S, strategy: DedupStrategy) -> Self {
        let dim = table.dim();
        ShardedEmbedding {
            table,
            dim,
            strategy,
            volume: DedupVolume::default(),
            last_id_bytes: Vec::new(),
            last_emb_bytes: Vec::new(),
            pool: None,
        }
    }

    /// Attach a worker pool; dedup, the serve-side fetch, row expansion
    /// and gradient aggregation then fan out across it. Results are
    /// bit-identical with and without a pool.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_deref()
    }

    pub fn table(&self) -> &S {
        &self.table
    }

    pub fn table_mut(&mut self) -> &mut S {
        &mut self.table
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Distributed lookup: returns rows in occurrence order
    /// (`ids.len() × dim`). `train` controls insert-on-miss semantics.
    ///
    /// All ranks must call this collectively (it contains two
    /// all-to-alls), even with an empty `ids` list. Equivalent to
    /// [`post_ids`](Self::post_ids) immediately followed by
    /// [`complete_lookup`](Self::complete_lookup).
    pub fn lookup(&mut self, comm: &mut CommHandle, ids: &[GlobalId], train: bool) -> Vec<f32> {
        let pending = self.post_ids(comm, ids);
        self.complete_lookup(comm, pending, train)
    }

    /// Phase 1 of the pipelined lookup: partition `ids` by owner, apply
    /// stage-1 dedup, and *post* the ID all-to-all (sends enqueue
    /// immediately; nothing blocks). The returned [`PendingLookup`] must
    /// be passed to [`complete_lookup`](Self::complete_lookup) — and
    /// because posted exchanges ride dedicated comm lanes, the trainer
    /// may post micro-batch *k+1*'s IDs before completing micro-batch
    /// *k*, hiding ID communication behind compute (§3's overlap).
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_ids(&mut self, comm: &mut CommHandle, ids: &[GlobalId]) -> PendingLookup {
        let world = comm.world;

        // ---- partition by owner ------------------------------------
        let mut ids_by_dst: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
        let mut pos_by_dst: Vec<Vec<u32>> = vec![Vec::new(); world];
        for (i, &id) in ids.iter().enumerate() {
            let d = shard_owner(id, world);
            ids_by_dst[d].push(id);
            pos_by_dst[d].push(i as u32);
        }

        // ---- stage 1: per-destination dedup -------------------------
        let pool = self.pool.clone();
        let mut send_ids: Vec<Vec<GlobalId>> = Vec::with_capacity(world);
        let mut stage1_inverse: Vec<Option<Vec<u32>>> = Vec::with_capacity(world);
        for bucket in &ids_by_dst {
            self.volume.ids_raw += bucket.len();
            if self.strategy.stage1() {
                let d = Dedup::of_auto(bucket, pool.as_deref());
                self.volume.ids_sent += d.unique.len();
                send_ids.push(d.unique);
                stage1_inverse.push(Some(d.inverse));
            } else {
                self.volume.ids_sent += bucket.len();
                send_ids.push(bucket.clone());
                stage1_inverse.push(None);
            }
        }
        let id_bytes: Vec<usize> = send_ids.iter().map(|v| v.len() * 8).collect();
        let sent_lens: Vec<usize> = send_ids.iter().map(|v| v.len()).collect();
        let raw_lens: Vec<usize> = ids_by_dst.iter().map(|v| v.len()).collect();

        // ---- ID all-to-all (posted, non-blocking) --------------------
        let pending = comm.post_all_to_all_on(
            LANE_IDS,
            send_ids.into_iter().map(Message::Ids).collect(),
        );
        PendingLookup {
            num_ids: ids.len(),
            pos_by_dst,
            stage1_inverse,
            sent_lens,
            raw_lens,
            id_bytes,
            pending,
        }
    }

    /// Phase 2 of the pipelined lookup: receive the requested IDs,
    /// serve them from the local shard (stage-2 dedup; the fetch fans
    /// out across the attached pool), and *post* the embedding reply
    /// all-to-all without waiting for it. Returning before the reply
    /// lands is what lets the trainer push the next round's ID exchange
    /// onto the wire while this round's reply drains — the
    /// double-buffered round.
    pub fn serve_reply(
        &mut self,
        comm: &mut CommHandle,
        lookup: PendingLookup,
        train: bool,
    ) -> PendingReply {
        let world = comm.world;
        let dim = self.dim;
        let pool = self.pool.clone();
        let PendingLookup {
            num_ids,
            pos_by_dst,
            stage1_inverse,
            sent_lens,
            raw_lens,
            id_bytes,
            pending,
        } = lookup;
        self.last_id_bytes = id_bytes;
        let requested: Vec<Vec<GlobalId>> = comm
            .complete_all_to_all(pending)
            .into_iter()
            .map(Message::into_ids)
            .collect();

        // ---- serve: stage-2 dedup + local table lookup ---------------
        let total_req: usize = requested.iter().map(|r| r.len()).sum();
        self.volume.lookups_raw += total_req;
        let replies: Vec<Vec<f32>> = if self.strategy.stage2() {
            // Dedup the union across sources, fetch once per unique id.
            let flat: Vec<GlobalId> = requested.iter().flatten().copied().collect();
            let d = Dedup::of_auto(&flat, pool.as_deref());
            self.volume.lookups_done += d.unique.len();
            let mut unique_rows = vec![0.0f32; d.unique.len() * dim];
            self.table
                .fetch_rows(&d.unique, train, &mut unique_rows, pool.as_deref());
            // Slice the expanded rows back per source.
            let mut out = Vec::with_capacity(world);
            let mut off = 0usize;
            for req in &requested {
                let inv = &d.inverse[off..off + req.len()];
                let mut rows = vec![0.0f32; req.len() * dim];
                gather_rows_par(&unique_rows, dim, inv, &mut rows, pool.as_deref());
                out.push(rows);
                off += req.len();
            }
            out
        } else {
            self.volume.lookups_done += total_req;
            requested
                .iter()
                .map(|req| {
                    let mut rows = vec![0.0f32; req.len() * dim];
                    self.table.fetch_rows(req, train, &mut rows, pool.as_deref());
                    rows
                })
                .collect()
        };

        // ---- embedding all-to-all (posted) ---------------------------
        // Reply row counts mirror the *received* id counts; the raw
        // (no-stage-1) counterpart is what we would have sent without
        // dedup — accounted for Fig. 16.
        for dst in 0..world {
            self.volume.emb_rows_raw += raw_lens[dst];
            self.volume.emb_rows_sent += sent_lens[dst];
        }
        self.last_emb_bytes = replies.iter().map(|r| r.len() * 4).collect();
        let pending = comm.post_all_to_all_on(
            LANE_EMB,
            replies.into_iter().map(Message::Floats).collect(),
        );
        PendingReply {
            num_ids,
            pos_by_dst,
            stage1_inverse,
            pending,
        }
    }

    /// Phase 3 of the pipelined lookup: receive the embedding reply and
    /// scatter rows back to occurrence order (`num_ids × dim`).
    pub fn complete_reply(&mut self, comm: &mut CommHandle, reply: PendingReply) -> Vec<f32> {
        let world = comm.world;
        let dim = self.dim;
        let pool = self.pool.clone();
        let PendingReply {
            num_ids,
            pos_by_dst,
            stage1_inverse,
            pending,
        } = reply;
        let returned: Vec<Vec<f32>> = comm
            .complete_all_to_all(pending)
            .into_iter()
            .map(Message::into_floats)
            .collect();

        // ---- scatter back to occurrence order ------------------------
        let mut out = vec![0.0f32; num_ids * dim];
        for dst in 0..world {
            let rows = &returned[dst];
            // Expand through the stage-1 inverse if we deduped.
            let expanded: Vec<f32> = match &stage1_inverse[dst] {
                Some(inv) => {
                    let mut e = vec![0.0f32; inv.len() * dim];
                    gather_rows_par(rows, dim, inv, &mut e, pool.as_deref());
                    e
                }
                None => rows.clone(),
            };
            for (j, &pos) in pos_by_dst[dst].iter().enumerate() {
                out[pos as usize * dim..(pos as usize + 1) * dim]
                    .copy_from_slice(&expanded[j * dim..(j + 1) * dim]);
            }
        }
        out
    }

    /// Phases 2+3 back to back: serve, exchange, scatter. Equivalent to
    /// [`serve_reply`](Self::serve_reply) immediately followed by
    /// [`complete_reply`](Self::complete_reply).
    pub fn complete_lookup(
        &mut self,
        comm: &mut CommHandle,
        lookup: PendingLookup,
        train: bool,
    ) -> Vec<f32> {
        let reply = self.serve_reply(comm, lookup, train);
        self.complete_reply(comm, reply)
    }

    /// Phase 1 of the distributed backward: partition occurrence-order
    /// gradients by owner, aggregate duplicates per destination (sparse
    /// gradient accumulation, §5.2) when stage-1 dedup is on, and *post*
    /// both the ID and gradient all-to-alls on their dedicated lanes
    /// without blocking. The trainer posts micro-batch *k*'s gradients
    /// here and completes them only after *k+1*'s forward, hiding the
    /// gradient exchange behind compute.
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_backward(
        &mut self,
        comm: &mut CommHandle,
        ids: &[GlobalId],
        grads: &[f32],
    ) -> PendingBackward {
        assert_eq!(grads.len(), ids.len() * self.dim);
        let world = comm.world;
        let dim = self.dim;
        let pool = self.pool.clone();

        let mut ids_by_dst: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
        let mut grad_by_dst: Vec<Vec<f32>> = vec![Vec::new(); world];
        {
            let mut occ_ids: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
            let mut occ_grads: Vec<Vec<f32>> = vec![Vec::new(); world];
            for (i, &id) in ids.iter().enumerate() {
                let d = shard_owner(id, world);
                occ_ids[d].push(id);
                occ_grads[d].extend_from_slice(&grads[i * dim..(i + 1) * dim]);
            }
            for d in 0..world {
                if self.strategy.stage1() {
                    let dd = Dedup::of_auto(&occ_ids[d], pool.as_deref());
                    let mut agg = vec![0.0f32; dd.unique.len() * dim];
                    scatter_accumulate_par(
                        &occ_grads[d],
                        dim,
                        &dd.inverse,
                        &mut agg,
                        pool.as_deref(),
                    );
                    ids_by_dst[d] = dd.unique;
                    grad_by_dst[d] = agg;
                } else {
                    ids_by_dst[d] = std::mem::take(&mut occ_ids[d]);
                    grad_by_dst[d] = std::mem::take(&mut occ_grads[d]);
                }
            }
        }

        // Two posted all-to-alls: ids then gradients (same wire pattern
        // as forward, reversed direction for the payload), on dedicated
        // lanes so they can stay in flight across rounds.
        let ids_pending = comm.post_all_to_all_on(
            LANE_GRAD_IDS,
            ids_by_dst.into_iter().map(Message::Ids).collect(),
        );
        let grads_pending = comm.post_all_to_all_on(
            LANE_GRAD,
            grad_by_dst.into_iter().map(Message::Floats).collect(),
        );
        PendingBackward {
            ids_pending,
            grads_pending,
        }
    }

    /// Phase 2 of the distributed backward: receive the exchanged
    /// gradients and aggregate across sources (always — correctness
    /// requires the owner to apply each id's total gradient once).
    /// Returns `(ids, grads)` for the local shard (grads in id order,
    /// `ids.len() × dim`); the caller feeds these to the sparse
    /// optimizer.
    pub fn complete_backward(
        &mut self,
        comm: &mut CommHandle,
        pending: PendingBackward,
    ) -> (Vec<GlobalId>, Vec<f32>) {
        let dim = self.dim;
        let pool = self.pool.clone();
        let PendingBackward {
            ids_pending,
            grads_pending,
        } = pending;
        let recv_ids: Vec<Vec<GlobalId>> = comm
            .complete_all_to_all(ids_pending)
            .into_iter()
            .map(Message::into_ids)
            .collect();
        let recv_grads: Vec<Vec<f32>> = comm
            .complete_all_to_all(grads_pending)
            .into_iter()
            .map(Message::into_floats)
            .collect();

        let flat_ids: Vec<GlobalId> = recv_ids.iter().flatten().copied().collect();
        let flat_grads: Vec<f32> = recv_grads.into_iter().flatten().collect();
        let d = Dedup::of_auto(&flat_ids, pool.as_deref());
        let mut agg = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate_par(&flat_grads, dim, &d.inverse, &mut agg, pool.as_deref());
        (d.unique, agg)
    }

    /// Distributed backward, blocking: post + complete in one call.
    ///
    /// Collective: all ranks must call.
    pub fn backward(
        &mut self,
        comm: &mut CommHandle,
        ids: &[GlobalId],
        grads: &[f32],
    ) -> (Vec<GlobalId>, Vec<f32>) {
        let pending = self.post_backward(comm, ids, grads);
        self.complete_backward(comm, pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::comm::CommGroup;
    use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
    use std::sync::Arc;
    use std::thread;

    const DIM: usize = 4;

    fn run_sharded<T: Send + 'static>(
        world: usize,
        strategy: DedupStrategy,
        f: impl Fn(usize, &mut ShardedEmbedding<DynamicEmbeddingTable>, &mut CommHandle) -> T
            + Send
            + Sync
            + 'static,
    ) -> Vec<T> {
        let handles = CommGroup::new(world);
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for (rank, mut h) in handles.into_iter().enumerate() {
            let f = Arc::clone(&f);
            joins.push(thread::spawn(move || {
                let table = DynamicEmbeddingTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
                );
                let mut se = ShardedEmbedding::new(table, strategy);
                f(rank, &mut se, &mut h)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    /// Reference: what a single unsharded table would return. Row init is
    /// a pure function of (id, seed), so the expected rows are computable
    /// independently.
    fn expected_row(id: GlobalId) -> Vec<f32> {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
        );
        let mut out = vec![0.0; DIM];
        t.lookup_or_insert(id, &mut out);
        out
    }

    #[test]
    fn lookup_matches_unsharded_reference_all_strategies() {
        for strategy in [
            DedupStrategy::None,
            DedupStrategy::CommUnique,
            DedupStrategy::LookupUnique,
            DedupStrategy::TwoStage,
        ] {
            let out = run_sharded(4, strategy, |rank, se, comm| {
                // Overlapping id lists across ranks, with duplicates.
                let ids: Vec<u64> =
                    vec![1, 2, 3, 1, 2, 100 + rank as u64, 3, 1, 50, 50];
                let rows = se.lookup(comm, &ids, true);
                (ids, rows)
            });
            for (ids, rows) in out {
                for (i, &id) in ids.iter().enumerate() {
                    assert_eq!(
                        &rows[i * DIM..(i + 1) * DIM],
                        expected_row(id).as_slice(),
                        "strategy {strategy:?} id {id}"
                    );
                }
            }
        }
    }

    #[test]
    fn dedup_strategies_reduce_volume_in_order() {
        // two-stage ≤ comm-unique ≤ none for ids_sent; lookups_done
        // minimized by stage2.
        let mut results = Vec::new();
        for strategy in [
            DedupStrategy::None,
            DedupStrategy::CommUnique,
            DedupStrategy::TwoStage,
        ] {
            let out = run_sharded(4, strategy, |_rank, se, comm| {
                let ids: Vec<u64> = (0..1000).map(|i| (i % 37) as u64).collect();
                let _ = se.lookup(comm, &ids, true);
                se.volume
            });
            results.push((strategy, out[0]));
        }
        let none = results[0].1;
        let comm_u = results[1].1;
        let two = results[2].1;
        assert_eq!(none.ids_sent, none.ids_raw);
        assert!(comm_u.ids_sent < none.ids_sent);
        assert_eq!(two.ids_sent, comm_u.ids_sent);
        assert!(two.lookups_done < comm_u.lookups_done);
        assert!(comm_u.emb_rows_sent < none.emb_rows_raw);
    }

    #[test]
    fn empty_ranks_participate() {
        let out = run_sharded(3, DedupStrategy::TwoStage, |rank, se, comm| {
            let ids: Vec<u64> = if rank == 0 { vec![9, 9, 9] } else { vec![] };
            se.lookup(comm, &ids, true)
        });
        assert_eq!(out[0].len(), 3 * DIM);
        assert_eq!(&out[0][0..DIM], expected_row(9).as_slice());
        assert!(out[1].is_empty() && out[2].is_empty());
    }

    #[test]
    fn pipelined_lookup_matches_blocking_lookup() {
        // Two micro-batches per rank: post batch 1's IDs before
        // completing batch 0 (the overlap schedule), and verify rows are
        // bitwise identical to the blocking schedule.
        let out = run_sharded(4, DedupStrategy::TwoStage, |rank, se, comm| {
            let batch0: Vec<u64> = vec![1, 2, 3, 1, 50 + rank as u64];
            let batch1: Vec<u64> = vec![2, 9, 9, 70 + rank as u64];
            let p0 = se.post_ids(comm, &batch0);
            let p1 = se.post_ids(comm, &batch1); // posted before completing p0
            let rows0 = se.complete_lookup(comm, p0, true);
            let rows1 = se.complete_lookup(comm, p1, true);
            (batch0, rows0, batch1, rows1)
        });
        for (batch0, rows0, batch1, rows1) in out {
            for (i, &id) in batch0.iter().enumerate() {
                assert_eq!(&rows0[i * DIM..(i + 1) * DIM], expected_row(id).as_slice());
            }
            for (i, &id) in batch1.iter().enumerate() {
                assert_eq!(&rows1[i * DIM..(i + 1) * DIM], expected_row(id).as_slice());
            }
        }
    }

    #[test]
    fn pipelined_volume_accounting_matches_blocking() {
        let run = |pipelined: bool| {
            run_sharded(2, DedupStrategy::TwoStage, move |_rank, se, comm| {
                let batch0: Vec<u64> = (0..200).map(|i| (i % 17) as u64).collect();
                let batch1: Vec<u64> = (0..100).map(|i| (i % 5) as u64).collect();
                if pipelined {
                    let p0 = se.post_ids(comm, &batch0);
                    let p1 = se.post_ids(comm, &batch1);
                    let _ = se.complete_lookup(comm, p0, true);
                    let _ = se.complete_lookup(comm, p1, true);
                } else {
                    let _ = se.lookup(comm, &batch0, true);
                    let _ = se.lookup(comm, &batch1, true);
                }
                se.volume
            })
        };
        let blocking = run(false);
        let pipelined = run(true);
        for (b, p) in blocking.iter().zip(&pipelined) {
            assert_eq!(b, p, "volume accounting must not depend on scheduling");
        }
    }

    /// Canonicalize a backward result for comparison (id-sorted rows).
    fn sorted_pairs(lids: &[u64], lgrads: &[f32]) -> Vec<(u64, Vec<f32>)> {
        let mut pairs: Vec<(u64, Vec<f32>)> = lids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, lgrads[i * DIM..(i + 1) * DIM].to_vec()))
            .collect();
        pairs.sort_by_key(|p| p.0);
        pairs
    }

    type RoundResults = (Vec<Vec<f32>>, Vec<Vec<(u64, Vec<f32>)>>);

    /// Three rounds of lookup+backward per rank under the given
    /// schedule; returns per-round rows and id-sorted shard gradients.
    fn run_schedule(double_buffered: bool) -> Vec<RoundResults> {
        run_sharded(4, DedupStrategy::TwoStage, move |rank, se, comm| {
            let batches: Vec<Vec<u64>> = (0..3)
                .map(|b| vec![1 + b as u64, 2, 3, 40 + rank as u64, 2])
                .collect();
            let mut rows_all = Vec::new();
            let mut grads_all: Vec<Vec<(u64, Vec<f32>)>> = Vec::new();
            if !double_buffered {
                for b in &batches {
                    let rows = se.lookup(comm, b, true);
                    let grads = vec![0.25f32; b.len() * DIM];
                    let (lids, lgrads) = se.backward(comm, b, &grads);
                    rows_all.push(rows);
                    grads_all.push(sorted_pairs(&lids, &lgrads));
                }
            } else {
                // The PR-2 trainer schedule: serve round k, post round
                // k+1's IDs while k's reply is in flight, and complete
                // round k's gradient exchange only during round k+1.
                let mut posted = Some(se.post_ids(comm, &batches[0]));
                let mut posted_bwd: Option<PendingBackward> = None;
                for (round, b) in batches.iter().enumerate() {
                    let pending = posted.take().unwrap();
                    let reply = se.serve_reply(comm, pending, true);
                    if round + 1 < batches.len() {
                        posted = Some(se.post_ids(comm, &batches[round + 1]));
                    }
                    let rows = se.complete_reply(comm, reply);
                    rows_all.push(rows);
                    if let Some(pb) = posted_bwd.take() {
                        let (lids, lgrads) = se.complete_backward(comm, pb);
                        grads_all.push(sorted_pairs(&lids, &lgrads));
                    }
                    let grads = vec![0.25f32; b.len() * DIM];
                    posted_bwd = Some(se.post_backward(comm, b, &grads));
                }
                let (lids, lgrads) = se.complete_backward(comm, posted_bwd.take().unwrap());
                grads_all.push(sorted_pairs(&lids, &lgrads));
            }
            (rows_all, grads_all)
        })
    }

    #[test]
    fn double_buffered_schedule_bit_identical_to_blocking() {
        let blocking = run_schedule(false);
        let pipelined = run_schedule(true);
        for (rank, (b, p)) in blocking.iter().zip(&pipelined).enumerate() {
            assert_eq!(b.0, p.0, "rank {rank}: forward rows diverged");
            assert_eq!(b.1, p.1, "rank {rank}: backward gradients diverged");
        }
    }

    #[test]
    fn pooled_concurrent_lookup_matches_reference_rows() {
        use crate::embedding::concurrent::ConcurrentDynamicTable;
        let handles = CommGroup::new(2);
        let mut joins = Vec::new();
        for (rank, mut comm) in handles.into_iter().enumerate() {
            joins.push(thread::spawn(move || {
                let table = ConcurrentDynamicTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
                    8,
                );
                let pool = Arc::new(WorkerPool::new(2));
                let mut se =
                    ShardedEmbedding::new(table, DedupStrategy::TwoStage).with_pool(pool);
                // Large batch: clears the parallel-fetch and sorted-dedup
                // thresholds, so the pooled paths actually engage.
                let ids: Vec<u64> = (0..10_000u64)
                    .map(|i| (i * 31 + rank as u64) % 500)
                    .collect();
                let rows = se.lookup(&mut comm, &ids, true);
                let grads = vec![0.5f32; ids.len() * DIM];
                let (lids, lgrads) = se.backward(&mut comm, &ids, &grads);
                (ids, rows, lids, lgrads)
            }));
        }
        for j in joins {
            let (ids, rows, lids, lgrads) = j.join().unwrap();
            for (i, &id) in ids.iter().enumerate() {
                assert_eq!(
                    &rows[i * DIM..(i + 1) * DIM],
                    expected_row(id).as_slice(),
                    "id {id}"
                );
            }
            assert_eq!(lgrads.len(), lids.len() * DIM);
        }
    }

    #[test]
    fn backward_aggregates_across_ranks_and_duplicates() {
        // Every rank contributes gradient 1.0 for id 5 twice, and rank r
        // contributes r for id 6 once. Total for id 5 = 2×world, for
        // id 6 = sum of ranks.
        let world = 4;
        let out = run_sharded(world, DedupStrategy::TwoStage, |rank, se, comm| {
            // Forward to materialize rows.
            let ids = vec![5u64, 5, 6];
            let _ = se.lookup(comm, &ids, true);
            let mut grads = vec![0.0f32; ids.len() * DIM];
            grads[0..DIM].fill(1.0);
            grads[DIM..2 * DIM].fill(1.0);
            grads[2 * DIM..3 * DIM].fill(rank as f32);
            let (lids, lgrads) = se.backward(comm, &ids, &grads);
            (lids, lgrads)
        });
        // Exactly one rank owns id 5 and one owns id 6.
        let mut seen5 = 0;
        let mut seen6 = 0;
        for (lids, lgrads) in out {
            for (i, &id) in lids.iter().enumerate() {
                let g = &lgrads[i * DIM..(i + 1) * DIM];
                if id == 5 {
                    seen5 += 1;
                    assert_eq!(g, vec![2.0 * world as f32; DIM].as_slice());
                } else if id == 6 {
                    seen6 += 1;
                    assert_eq!(g, vec![0.0 + 1.0 + 2.0 + 3.0; DIM].as_slice());
                } else {
                    panic!("unexpected id {id}");
                }
            }
        }
        assert_eq!(seen5, 1);
        assert_eq!(seen6, 1);
    }

    #[test]
    fn backward_same_totals_without_stage1() {
        let world = 2;
        for strategy in [DedupStrategy::None, DedupStrategy::TwoStage] {
            let out = run_sharded(world, strategy, |_rank, se, comm| {
                let ids = vec![1u64, 1, 2];
                let _ = se.lookup(comm, &ids, true);
                let grads = vec![0.5f32; ids.len() * DIM];
                se.backward(comm, &ids, &grads)
            });
            let mut total: f32 = 0.0;
            for (_ids, grads) in out {
                total += grads.iter().sum::<f32>();
            }
            // 3 occurrences × 2 ranks × 0.5 × DIM dims.
            assert_eq!(total, 3.0 * 2.0 * 0.5 * DIM as f32, "{strategy:?}");
        }
    }

    #[test]
    fn shard_owner_balanced() {
        let world = 8;
        let mut counts = vec![0usize; world];
        for id in 0..80_000u64 {
            counts[shard_owner(id, world)] += 1;
        }
        for &c in &counts {
            let dev = (c as f64 - 10_000.0).abs() / 10_000.0;
            assert!(dev < 0.05, "shard imbalance {c}");
        }
    }
}
