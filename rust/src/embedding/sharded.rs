//! Model-parallel sharded embedding lookup/update (§3 Fig. 5, §4.3).
//!
//! Embedding tables are sharded across devices by `hash(id) % world`.
//! Each lookup performs the paper's two all-to-alls — **ID communication**
//! then **embedding communication** — with the two-stage deduplication of
//! §4.3 applied according to a [`DedupStrategy`]:
//!
//! 1. *Stage 1* (requester): deduplicate the IDs headed to each peer
//!    before the ID all-to-all, shrinking both the ID payload and —
//!    decisively — the embedding payload coming back.
//! 2. *Stage 2* (server): the IDs received from different peers overlap;
//!    deduplicate the union before touching the hash table so each row is
//!    fetched once.
//!
//! Backward mirrors forward: occurrence gradients are aggregated per
//! destination (sparse accumulation), exchanged via all-to-all, and
//! aggregated again on the owning shard.
//!
//! The lookup is a **three-phase, double-buffered pipeline**:
//! [`ShardedEmbedding::post_ids`] partitions + stage-1 dedups and posts
//! the ID all-to-all without blocking; [`ShardedEmbedding::serve_reply`]
//! receives the requested IDs, serves the local shard (fanning the
//! fetch across [`crate::util::pool::WorkerPool`] stripes when one is
//! attached) and *posts* the embedding reply; and
//! [`ShardedEmbedding::complete_reply`] collects the reply and scatters
//! rows back to occurrence order. Backward splits the same way
//! ([`ShardedEmbedding::post_backward`] /
//! [`ShardedEmbedding::complete_backward`]). The trainer exploits the
//! splits so that micro-batch *k+1*'s ID exchange, *k*'s embedding
//! reply, and *k−1*'s gradient push are simultaneously in flight — the
//! TurboGR-style overlap the `--overlap` ablation toggles. Every
//! parallel path is bit-identical to the serial reference for every
//! pool size (disjoint writes; per-row accumulation order preserved).
//!
//! With several merge groups (heterogeneous schemas), [`GroupExchange`]
//! **multiplexes** the per-group exchanges: all groups' payloads ride
//! ONE message per comm lane with u64 section headers on the ID lanes,
//! so each pipeline phase costs one all-to-all regardless of the group
//! count. Single-group runs keep the historical per-group wire format
//! byte for byte.

use std::collections::HashMap;
use std::sync::Arc;

use crate::collective::comm::{
    CommHandle, Message, PendingAllToAll, LANES, LANE_EMB, LANE_GRAD, LANE_GRAD_IDS, LANE_IDS,
};
use crate::embedding::dedup::{
    gather_rows_par, scatter_accumulate_par, Dedup, DedupStrategy, DedupVolume,
};
use crate::embedding::hash::hash_id;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use crate::util::pool::WorkerPool;

/// Seed for the shard-placement hash (distinct from table hashing so
/// shard residence and slot probing are independent).
const SHARD_SEED: u64 = 0x5A4D;

// ---- mixed-precision wire format -----------------------------------
//
// When the store's precision policy is enabled (`--precision mixed`),
// the two float lanes compress cold rows to binary16 on the wire:
//
// * **Embedding replies** (owner → requester): each per-destination
//   section becomes `[cold-tag bitmask: ⌈n/32⌉ words][row data]` where
//   hot rows stay `dim` f32 words and cold rows pack two binary16
//   values per word. Cold stored bits are already on the f16 grid (the
//   storage invariant), so the compression is lossless. The requester
//   knows `n` (its own stage-1 unique count), parses the tags, and
//   derives the section length — the same sequential walk in the
//   per-group and multiplexed schedules, so their payloads stay
//   byte-identical section by section.
// * **Gradient pushes** (requester → owner): the gradient-ID section
//   becomes `[n][ids…][cold-tag bitmask: ⌈n/64⌉ words]` and the
//   gradient payload packs cold rows as round-to-nearest-even binary16
//   (the deliberately lossy half). The owner decodes with the
//   requester-sent tags, never its own (possibly newer)
//   classification, so the wire is self-describing and the decode can
//   never tear a row.
//
// Pure-FP32 stores keep the historical wire format byte for byte.

/// Words of one packed cold row: two binary16 values per 32-bit word.
fn cold_row_words(dim: usize) -> usize {
    dim.div_ceil(2)
}

/// Words of the cold-tag bitmask prefixing a mixed reply section.
fn tag_words_f32(n: usize) -> usize {
    n.div_ceil(32)
}

/// Words of the cold-tag bitmask closing a mixed gradient-ID section.
fn tag_words_u64(n: usize) -> usize {
    n.div_ceil(64)
}

/// Quantize `row` to binary16 and pack two values per f32-bit word
/// (odd dims zero-fill the last high half).
fn push_packed_f16(row: &[f32], out: &mut Vec<f32>) {
    for pair in row.chunks(2) {
        let lo = f32_to_f16_bits(pair[0]) as u32;
        let hi = pair.get(1).map_or(0, |&v| f32_to_f16_bits(v) as u32);
        out.push(f32::from_bits(hi << 16 | lo));
    }
}

/// Unpack `dim` binary16 values from bit-packed words onto `out`.
fn unpack_packed_f16(words: &[f32], dim: usize, out: &mut Vec<f32>) {
    for i in 0..dim {
        let w = words[i / 2].to_bits();
        let half = if i % 2 == 0 { w & 0xFFFF } else { w >> 16 };
        out.push(f16_bits_to_f32(half as u16));
    }
}

/// Encode one mixed reply section: cold-tag bitmask (bit `j` of word
/// `j/32` set = row `j` cold), then tag-selected row data.
fn encode_reply_mixed(rows: &[f32], hot: &[bool], dim: usize, out: &mut Vec<f32>) {
    let n = hot.len();
    debug_assert_eq!(rows.len(), n * dim);
    let base = out.len();
    out.resize(base + tag_words_f32(n), 0.0);
    for (j, &h) in hot.iter().enumerate() {
        if !h {
            let w = base + j / 32;
            out[w] = f32::from_bits(out[w].to_bits() | 1u32 << (j % 32));
        }
    }
    for (j, &h) in hot.iter().enumerate() {
        let row = &rows[j * dim..(j + 1) * dim];
        if h {
            out.extend_from_slice(row);
        } else {
            push_packed_f16(row, out);
        }
    }
}

/// Decode one mixed reply section at `*off` (`n` rows of `dim`),
/// appending the f32 rows and per-row hot tags; advances `*off` past
/// the section.
fn decode_reply_mixed(
    packed: &[f32],
    off: &mut usize,
    n: usize,
    dim: usize,
    rows: &mut Vec<f32>,
    hot: &mut Vec<bool>,
) {
    let tagw = tag_words_f32(n);
    let tags = &packed[*off..*off + tagw];
    *off += tagw;
    for j in 0..n {
        let cold = (tags[j / 32].to_bits() >> (j % 32)) & 1 == 1;
        hot.push(!cold);
        if cold {
            let words = cold_row_words(dim);
            unpack_packed_f16(&packed[*off..*off + words], dim, rows);
            *off += words;
        } else {
            rows.extend_from_slice(&packed[*off..*off + dim]);
            *off += dim;
        }
    }
}

/// Encode one mixed gradient-ID section: `[n][ids…][cold-tag bitmask]`.
fn encode_grad_ids_mixed(ids: &[GlobalId], hot: &[bool], out: &mut Vec<u64>) {
    let n = ids.len();
    out.push(n as u64);
    out.extend_from_slice(ids);
    let base = out.len();
    out.resize(base + tag_words_u64(n), 0);
    for (j, &h) in hot.iter().enumerate() {
        if !h {
            out[base + j / 64] |= 1u64 << (j % 64);
        }
    }
}

/// Decode one mixed gradient-ID section at `*off`; returns the ids and
/// per-id hot tags and advances `*off` past the section.
fn decode_grad_ids_mixed(packed: &[u64], off: &mut usize) -> (Vec<GlobalId>, Vec<bool>) {
    let n = packed[*off] as usize;
    *off += 1;
    let ids = packed[*off..*off + n].to_vec();
    *off += n;
    let tagw = tag_words_u64(n);
    let tags = &packed[*off..*off + tagw];
    *off += tagw;
    let hot = (0..n)
        .map(|j| (tags[j / 64] >> (j % 64)) & 1 == 0)
        .collect();
    (ids, hot)
}

/// Encode one mixed gradient section: hot rows verbatim, cold rows
/// quantized to binary16 (round-to-nearest-even) and packed.
fn encode_grads_mixed(grads: &[f32], hot: &[bool], dim: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(grads.len(), hot.len() * dim);
    for (j, &h) in hot.iter().enumerate() {
        let row = &grads[j * dim..(j + 1) * dim];
        if h {
            out.extend_from_slice(row);
        } else {
            push_packed_f16(row, out);
        }
    }
}

/// Decode one mixed gradient section at `*off` back to `hot.len() × dim`
/// f32 values using the requester-sent tags.
fn decode_grads_mixed(packed: &[f32], off: &mut usize, hot: &[bool], dim: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(hot.len() * dim);
    for &h in hot {
        if h {
            out.extend_from_slice(&packed[*off..*off + dim]);
            *off += dim;
        } else {
            let words = cold_row_words(dim);
            unpack_packed_f16(&packed[*off..*off + words], dim, &mut out);
            *off += words;
        }
    }
    out
}

/// Cumulative per-precision wire-payload meters for the mixed format
/// (all zero in pure-FP32 mode, where the historical format is
/// untouched). Counts every destination *including the local loopback
/// chunk* — a pure function of the served batches, independent of
/// schedule — unlike `CommStats`, which meters remote chunks only.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrecisionWireBytes {
    /// Bytes of hot (full-FP32) reply and gradient rows.
    pub fp32_row_bytes: u64,
    /// Bytes of cold rows packed two binary16 values per word.
    pub fp16_row_bytes: u64,
    /// Framing the mixed format adds: reply-lane tag bitmasks plus the
    /// `[n]…[tags]` words on the gradient-ID lane.
    pub tag_bytes: u64,
}

impl PrecisionWireBytes {
    pub fn merge(&mut self, other: &PrecisionWireBytes) {
        self.fp32_row_bytes += other.fp32_row_bytes;
        self.fp16_row_bytes += other.fp16_row_bytes;
        self.tag_bytes += other.tag_bytes;
    }

    /// Total mixed-format payload bytes (rows + framing).
    pub fn total(&self) -> u64 {
        self.fp32_row_bytes + self.fp16_row_bytes + self.tag_bytes
    }
}

/// Per-rank shard of a (merged) embedding table plus the exchange logic.
pub struct ShardedEmbedding<S: EmbeddingStore> {
    table: S,
    dim: usize,
    pub strategy: DedupStrategy,
    /// Cumulative communication-volume accounting (drives Fig. 16).
    pub volume: DedupVolume,
    /// Per-pair bytes of the most recently *served* lookup (for the
    /// net cost model): `last_id_bytes[dst]`, `last_emb_bytes[dst]`.
    /// Both meters update together in `serve_reply`, so they always
    /// describe the same exchange even when several are posted.
    pub last_id_bytes: Vec<usize>,
    pub last_emb_bytes: Vec<usize>,
    /// Per-precision wire-payload meters (nonzero only when the store's
    /// precision policy is enabled — the mixed wire format).
    pub precision_wire: PrecisionWireBytes,
    /// Hot/cold tags learned from the most recently completed embedding
    /// reply, keyed by id — consumed by the next `post_backward` to
    /// pick each pushed gradient row's wire precision. The trainer
    /// completes reply *k* right before posting backward *k* in every
    /// schedule (overlap / cross-step only move other phases), so one
    /// slot suffices; ids absent here push FP32 — a lossless fallback,
    /// never a correctness hazard, because the owner decodes with the
    /// requester-sent tags.
    reply_hot: HashMap<GlobalId, bool>,
    /// Worker pool shared by dedup, the stage-2 serve fetch, row
    /// expansion and gradient aggregation; `None` = serial reference.
    pool: Option<Arc<WorkerPool>>,
}

/// Which rank owns `id`.
pub fn shard_owner(id: GlobalId, world: usize) -> usize {
    (hash_id(id, SHARD_SEED) % world as u64) as usize
}

/// Partition/dedup layout captured when a lookup is prepared, consumed
/// when it is served and scattered. Shared by the per-group and the
/// multiplexed ([`GroupExchange`]) schedules.
struct LookupLayout {
    num_ids: usize,
    pos_by_dst: Vec<Vec<u32>>,
    stage1_inverse: Vec<Option<Vec<u32>>>,
    /// Per-destination unique (post-stage-1) id counts.
    sent_lens: Vec<usize>,
    /// Per-destination unique id lists — kept only under the mixed wire
    /// format (empty otherwise), so the reply's hot/cold tags can be
    /// keyed back to ids for the following gradient push.
    sent_ids: Vec<Vec<GlobalId>>,
    /// Per-destination raw occurrence counts.
    raw_lens: Vec<usize>,
    /// Per-destination ID bytes posted (installed into
    /// `last_id_bytes` at serve time so the `last_*_bytes` pair always
    /// describes the same exchange, even under pipelining).
    id_bytes: Vec<usize>,
}

/// Scatter layout of a served lookup (what
/// [`ShardedEmbedding::complete_reply`] needs).
struct ReplyLayout {
    num_ids: usize,
    pos_by_dst: Vec<Vec<u32>>,
    stage1_inverse: Vec<Option<Vec<u32>>>,
    /// Per-destination unique id counts — the reply row counts, which
    /// the multiplexed schedule uses to split packed reply sections.
    sent_lens: Vec<usize>,
    /// Per-destination unique id lists (mixed wire format only).
    sent_ids: Vec<Vec<GlobalId>>,
}

impl LookupLayout {
    fn into_reply(self) -> ReplyLayout {
        ReplyLayout {
            num_ids: self.num_ids,
            pos_by_dst: self.pos_by_dst,
            stage1_inverse: self.stage1_inverse,
            sent_lens: self.sent_lens,
            sent_ids: self.sent_ids,
        }
    }
}

/// In-flight state of a posted sharded lookup: the ID all-to-all is on
/// the wire; the partition layout needed to serve and scatter rides
/// along until [`ShardedEmbedding::complete_lookup`] consumes it.
#[must_use = "a posted lookup must be completed or peers deadlock"]
pub struct PendingLookup {
    layout: LookupLayout,
    pending: PendingAllToAll,
}

/// In-flight state of a served lookup: the embedding reply all-to-all
/// is on the wire; the scatter layout rides along until
/// [`ShardedEmbedding::complete_reply`] consumes it.
#[must_use = "a served lookup must be completed or peers deadlock"]
pub struct PendingReply {
    layout: ReplyLayout,
    pending: PendingAllToAll,
}

/// In-flight state of a posted backward gradient exchange (IDs +
/// payloads on dedicated lanes); completed by
/// [`ShardedEmbedding::complete_backward`].
#[must_use = "a posted backward must be completed or peers deadlock"]
pub struct PendingBackward {
    ids_pending: PendingAllToAll,
    grads_pending: PendingAllToAll,
}

impl<S: EmbeddingStore> ShardedEmbedding<S> {
    pub fn new(table: S, strategy: DedupStrategy) -> Self {
        let dim = table.dim();
        ShardedEmbedding {
            table,
            dim,
            strategy,
            volume: DedupVolume::default(),
            last_id_bytes: Vec::new(),
            last_emb_bytes: Vec::new(),
            precision_wire: PrecisionWireBytes::default(),
            reply_hot: HashMap::new(),
            pool: None,
        }
    }

    /// Whether exchanges use the FP16-compressed mixed wire format.
    /// Keyed off the store's precision policy, which comes from shared
    /// run options — so every rank agrees by construction.
    fn mixed_wire(&self) -> bool {
        self.table.precision_policy().enabled
    }

    /// Attach a worker pool; dedup, the serve-side fetch, row expansion
    /// and gradient aggregation then fan out across it. Results are
    /// bit-identical with and without a pool.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_deref()
    }

    pub fn table(&self) -> &S {
        &self.table
    }

    pub fn table_mut(&mut self) -> &mut S {
        &mut self.table
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Distributed lookup: returns rows in occurrence order
    /// (`ids.len() × dim`). `train` controls insert-on-miss semantics.
    ///
    /// All ranks must call this collectively (it contains two
    /// all-to-alls), even with an empty `ids` list. Equivalent to
    /// [`post_ids`](Self::post_ids) immediately followed by
    /// [`complete_lookup`](Self::complete_lookup).
    pub fn lookup(&mut self, comm: &mut CommHandle, ids: &[GlobalId], train: bool) -> Vec<f32> {
        let pending = self.post_ids(comm, ids);
        self.complete_lookup(comm, pending, train)
    }

    /// Phase 1 of the pipelined lookup: partition `ids` by owner, apply
    /// stage-1 dedup, and *post* the ID all-to-all (sends enqueue
    /// immediately; nothing blocks). The returned [`PendingLookup`] must
    /// be passed to [`complete_lookup`](Self::complete_lookup) — and
    /// because posted exchanges ride dedicated comm lanes, the trainer
    /// may post micro-batch *k+1*'s IDs before completing micro-batch
    /// *k*, hiding ID communication behind compute (§3's overlap).
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_ids(&mut self, comm: &mut CommHandle, ids: &[GlobalId]) -> PendingLookup {
        let (send_ids, layout) = self.prepare_lookup(comm.world, ids);

        // ---- ID all-to-all (posted, non-blocking) --------------------
        let pending = comm.post_all_to_all_on(
            LANE_IDS,
            send_ids.into_iter().map(Message::Ids).collect(),
        );
        PendingLookup { layout, pending }
    }

    /// Partition `ids` by owner and apply stage-1 dedup; returns the
    /// per-destination send lists plus the layout needed to serve and
    /// scatter. Pure bookkeeping — nothing touches the wire, so the
    /// multiplexed schedule can pack several groups' send lists into one
    /// message.
    fn prepare_lookup(
        &mut self,
        world: usize,
        ids: &[GlobalId],
    ) -> (Vec<Vec<GlobalId>>, LookupLayout) {
        // ---- partition by owner ------------------------------------
        let mut ids_by_dst: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
        let mut pos_by_dst: Vec<Vec<u32>> = vec![Vec::new(); world];
        for (i, &id) in ids.iter().enumerate() {
            let d = shard_owner(id, world);
            ids_by_dst[d].push(id);
            pos_by_dst[d].push(i as u32);
        }

        // ---- stage 1: per-destination dedup -------------------------
        let pool = self.pool.clone();
        let mut send_ids: Vec<Vec<GlobalId>> = Vec::with_capacity(world);
        let mut stage1_inverse: Vec<Option<Vec<u32>>> = Vec::with_capacity(world);
        for bucket in &ids_by_dst {
            self.volume.ids_raw += bucket.len();
            if self.strategy.stage1() {
                let d = Dedup::of_auto(bucket, pool.as_deref());
                self.volume.ids_sent += d.unique.len();
                send_ids.push(d.unique);
                stage1_inverse.push(Some(d.inverse));
            } else {
                self.volume.ids_sent += bucket.len();
                send_ids.push(bucket.clone());
                stage1_inverse.push(None);
            }
        }
        let id_bytes: Vec<usize> = send_ids.iter().map(|v| v.len() * 8).collect();
        let sent_lens: Vec<usize> = send_ids.iter().map(|v| v.len()).collect();
        let raw_lens: Vec<usize> = ids_by_dst.iter().map(|v| v.len()).collect();
        let sent_ids = if self.mixed_wire() {
            send_ids.clone()
        } else {
            Vec::new()
        };
        let layout = LookupLayout {
            num_ids: ids.len(),
            pos_by_dst,
            stage1_inverse,
            sent_lens,
            sent_ids,
            raw_lens,
            id_bytes,
        };
        (send_ids, layout)
    }

    /// Phase 2 of the pipelined lookup: receive the requested IDs,
    /// serve them from the local shard (stage-2 dedup; the fetch fans
    /// out across the attached pool), and *post* the embedding reply
    /// all-to-all without waiting for it. Returning before the reply
    /// lands is what lets the trainer push the next round's ID exchange
    /// onto the wire while this round's reply drains — the
    /// double-buffered round.
    pub fn serve_reply(
        &mut self,
        comm: &mut CommHandle,
        lookup: PendingLookup,
        train: bool,
    ) -> PendingReply {
        let world = comm.world;
        let PendingLookup { mut layout, pending } = lookup;
        self.last_id_bytes = std::mem::take(&mut layout.id_bytes);
        let requested: Vec<Vec<GlobalId>> = comm
            .complete_all_to_all(pending)
            .into_iter()
            .map(Message::into_ids)
            .collect();
        let replies =
            self.serve_requested(world, requested, &layout.sent_lens, &layout.raw_lens, train);

        // ---- embedding all-to-all (posted) ---------------------------
        let pending = comm.post_all_to_all_on(
            LANE_EMB,
            replies.into_iter().map(Message::Floats).collect(),
        );
        PendingReply {
            layout: layout.into_reply(),
            pending,
        }
    }

    /// Serve a received request set from the local shard: stage-2 dedup,
    /// batched fetch, per-source expansion. Updates the volume meters and
    /// `last_emb_bytes`; returns the per-destination reply rows (the wire
    /// payload, whatever schedule carries it).
    fn serve_requested(
        &mut self,
        world: usize,
        requested: Vec<Vec<GlobalId>>,
        sent_lens: &[usize],
        raw_lens: &[usize],
        train: bool,
    ) -> Vec<Vec<f32>> {
        let dim = self.dim;
        let pool = self.pool.clone();

        // ---- serve: stage-2 dedup + local table lookup ---------------
        let total_req: usize = requested.iter().map(|r| r.len()).sum();
        self.volume.lookups_raw += total_req;
        let replies: Vec<Vec<f32>> = if self.strategy.stage2() {
            // Dedup the union across sources, fetch once per unique id.
            let flat: Vec<GlobalId> = requested.iter().flatten().copied().collect();
            let d = Dedup::of_auto(&flat, pool.as_deref());
            self.volume.lookups_done += d.unique.len();
            let mut unique_rows = vec![0.0f32; d.unique.len() * dim];
            self.table
                .fetch_rows(&d.unique, train, &mut unique_rows, pool.as_deref());
            // Slice the expanded rows back per source.
            let mut out = Vec::with_capacity(world);
            let mut off = 0usize;
            for req in &requested {
                let inv = &d.inverse[off..off + req.len()];
                let mut rows = vec![0.0f32; req.len() * dim];
                gather_rows_par(&unique_rows, dim, inv, &mut rows, pool.as_deref());
                out.push(rows);
                off += req.len();
            }
            out
        } else {
            self.volume.lookups_done += total_req;
            requested
                .iter()
                .map(|req| {
                    let mut rows = vec![0.0f32; req.len() * dim];
                    self.table.fetch_rows(req, train, &mut rows, pool.as_deref());
                    rows
                })
                .collect()
        };

        // Mixed wire format: classify every requested id post-fetch
        // (`row_is_hot` is side-effect free and the fetch above bumped
        // each unique id exactly once, so the tag matches the
        // classification the fetch quantized under) and re-encode each
        // section with cold rows packed to binary16. Absent rows —
        // eval-mode misses — tag hot and ship their default row exact.
        let replies: Vec<Vec<f32>> = if self.mixed_wire() {
            let mut encoded = Vec::with_capacity(world);
            for (req, rows) in requested.iter().zip(&replies) {
                let hot: Vec<bool> = req
                    .iter()
                    .map(|&id| self.table.row_is_hot(id).unwrap_or(true))
                    .collect();
                let mut buf =
                    Vec::with_capacity(tag_words_f32(req.len()) + rows.len());
                encode_reply_mixed(rows, &hot, dim, &mut buf);
                self.precision_wire.tag_bytes += tag_words_f32(req.len()) as u64 * 4;
                for &h in &hot {
                    if h {
                        self.precision_wire.fp32_row_bytes += dim as u64 * 4;
                    } else {
                        self.precision_wire.fp16_row_bytes += cold_row_words(dim) as u64 * 4;
                    }
                }
                encoded.push(buf);
            }
            encoded
        } else {
            replies
        };

        // Reply row counts mirror the *received* id counts; the raw
        // (no-stage-1) counterpart is what we would have sent without
        // dedup — accounted for Fig. 16.
        for dst in 0..world {
            self.volume.emb_rows_raw += raw_lens[dst];
            self.volume.emb_rows_sent += sent_lens[dst];
        }
        self.last_emb_bytes = replies.iter().map(|r| r.len() * 4).collect();
        replies
    }

    /// Phase 3 of the pipelined lookup: receive the embedding reply and
    /// scatter rows back to occurrence order (`num_ids × dim`).
    pub fn complete_reply(&mut self, comm: &mut CommHandle, reply: PendingReply) -> Vec<f32> {
        let PendingReply { layout, pending } = reply;
        let returned: Vec<Vec<f32>> = comm
            .complete_all_to_all(pending)
            .into_iter()
            .map(Message::into_floats)
            .collect();
        if self.mixed_wire() {
            self.reply_hot.clear();
            let decoded: Vec<Vec<f32>> = returned
                .iter()
                .enumerate()
                .map(|(src, buf)| {
                    let mut off = 0usize;
                    let rows = self.decode_reply_section(&layout, src, buf, &mut off);
                    debug_assert_eq!(off, buf.len());
                    rows
                })
                .collect();
            self.scatter_reply(&layout, &decoded)
        } else {
            self.scatter_reply(&layout, &returned)
        }
    }

    /// Decode ONE mixed reply section from `packed` at `*off` (the row
    /// count is the stage-1 unique count this rank sent to `src`) and
    /// record its hot/cold tags for the next gradient push. The caller
    /// clears `reply_hot` once per completed reply before walking the
    /// sources.
    fn decode_reply_section(
        &mut self,
        layout: &ReplyLayout,
        src: usize,
        packed: &[f32],
        off: &mut usize,
    ) -> Vec<f32> {
        let n = layout.sent_lens[src];
        let dim = self.dim;
        let mut rows = Vec::with_capacity(n * dim);
        let mut hot = Vec::with_capacity(n);
        decode_reply_mixed(packed, off, n, dim, &mut rows, &mut hot);
        for (&id, &h) in layout.sent_ids[src].iter().zip(&hot) {
            self.reply_hot.insert(id, h);
        }
        rows
    }

    /// Scatter received reply rows back to occurrence order
    /// (`num_ids × dim`), expanding through the stage-1 inverse where
    /// the requester deduped.
    fn scatter_reply(&self, layout: &ReplyLayout, returned: &[Vec<f32>]) -> Vec<f32> {
        let dim = self.dim;
        let pool = self.pool.clone();
        let mut out = vec![0.0f32; layout.num_ids * dim];
        for (dst, rows) in returned.iter().enumerate() {
            // Expand through the stage-1 inverse if we deduped.
            let expanded: Vec<f32> = match &layout.stage1_inverse[dst] {
                Some(inv) => {
                    let mut e = vec![0.0f32; inv.len() * dim];
                    gather_rows_par(rows, dim, inv, &mut e, pool.as_deref());
                    e
                }
                None => rows.clone(),
            };
            for (j, &pos) in layout.pos_by_dst[dst].iter().enumerate() {
                out[pos as usize * dim..(pos as usize + 1) * dim]
                    .copy_from_slice(&expanded[j * dim..(j + 1) * dim]);
            }
        }
        out
    }

    /// Phases 2+3 back to back: serve, exchange, scatter. Equivalent to
    /// [`serve_reply`](Self::serve_reply) immediately followed by
    /// [`complete_reply`](Self::complete_reply).
    pub fn complete_lookup(
        &mut self,
        comm: &mut CommHandle,
        lookup: PendingLookup,
        train: bool,
    ) -> Vec<f32> {
        let reply = self.serve_reply(comm, lookup, train);
        self.complete_reply(comm, reply)
    }

    /// Phase 1 of the distributed backward: partition occurrence-order
    /// gradients by owner, aggregate duplicates per destination (sparse
    /// gradient accumulation, §5.2) when stage-1 dedup is on, and *post*
    /// both the ID and gradient all-to-alls on their dedicated lanes
    /// without blocking. The trainer posts micro-batch *k*'s gradients
    /// here and completes them only after *k+1*'s forward, hiding the
    /// gradient exchange behind compute.
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_backward(
        &mut self,
        comm: &mut CommHandle,
        ids: &[GlobalId],
        grads: &[f32],
    ) -> PendingBackward {
        let (ids_by_dst, grad_by_dst) = self.prepare_backward(comm.world, ids, grads);
        let (id_secs, grad_secs) = self.backward_sections(ids_by_dst, grad_by_dst);

        // Two posted all-to-alls: ids then gradients (same wire pattern
        // as forward, reversed direction for the payload), on dedicated
        // lanes so they can stay in flight across rounds.
        let ids_pending = comm.post_all_to_all_on(
            LANE_GRAD_IDS,
            id_secs.into_iter().map(Message::Ids).collect(),
        );
        let grads_pending = comm.post_all_to_all_on(
            LANE_GRAD,
            grad_secs.into_iter().map(Message::Floats).collect(),
        );
        PendingBackward {
            ids_pending,
            grads_pending,
        }
    }

    /// Per-destination backward wire sections: the historical raw
    /// id/grad lists in FP32 mode (byte-identical pass-through), or
    /// `[n][ids][tags]` + tag-selected FP32/FP16 gradient rows in mixed
    /// mode. Shared by the per-group and multiplexed schedules so their
    /// payloads stay identical section by section.
    fn backward_sections(
        &mut self,
        ids_by_dst: Vec<Vec<GlobalId>>,
        grad_by_dst: Vec<Vec<f32>>,
    ) -> (Vec<Vec<u64>>, Vec<Vec<f32>>) {
        if !self.mixed_wire() {
            return (ids_by_dst, grad_by_dst);
        }
        let dim = self.dim;
        let mut id_secs = Vec::with_capacity(ids_by_dst.len());
        let mut grad_secs = Vec::with_capacity(grad_by_dst.len());
        for (ids, grads) in ids_by_dst.iter().zip(&grad_by_dst) {
            let hot: Vec<bool> = ids
                .iter()
                .map(|&id| self.reply_hot.get(&id).copied().unwrap_or(true))
                .collect();
            let mut sec_ids =
                Vec::with_capacity(1 + ids.len() + tag_words_u64(ids.len()));
            encode_grad_ids_mixed(ids, &hot, &mut sec_ids);
            let mut sec_grads = Vec::with_capacity(grads.len());
            encode_grads_mixed(grads, &hot, dim, &mut sec_grads);
            self.precision_wire.tag_bytes += (1 + tag_words_u64(ids.len())) as u64 * 8;
            for &h in &hot {
                if h {
                    self.precision_wire.fp32_row_bytes += dim as u64 * 4;
                } else {
                    self.precision_wire.fp16_row_bytes += cold_row_words(dim) as u64 * 4;
                }
            }
            id_secs.push(sec_ids);
            grad_secs.push(sec_grads);
        }
        (id_secs, grad_secs)
    }

    /// Partition occurrence-order gradients by owner and aggregate
    /// duplicates per destination; returns `(ids_by_dst, grad_by_dst)`
    /// ready for the wire. Pure bookkeeping — no communication.
    fn prepare_backward(
        &mut self,
        world: usize,
        ids: &[GlobalId],
        grads: &[f32],
    ) -> (Vec<Vec<GlobalId>>, Vec<Vec<f32>>) {
        assert_eq!(grads.len(), ids.len() * self.dim);
        let dim = self.dim;
        let pool = self.pool.clone();

        let mut ids_by_dst: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
        let mut grad_by_dst: Vec<Vec<f32>> = vec![Vec::new(); world];
        {
            let mut occ_ids: Vec<Vec<GlobalId>> = vec![Vec::new(); world];
            let mut occ_grads: Vec<Vec<f32>> = vec![Vec::new(); world];
            for (i, &id) in ids.iter().enumerate() {
                let d = shard_owner(id, world);
                occ_ids[d].push(id);
                occ_grads[d].extend_from_slice(&grads[i * dim..(i + 1) * dim]);
            }
            for d in 0..world {
                if self.strategy.stage1() {
                    let dd = Dedup::of_auto(&occ_ids[d], pool.as_deref());
                    let mut agg = vec![0.0f32; dd.unique.len() * dim];
                    scatter_accumulate_par(
                        &occ_grads[d],
                        dim,
                        &dd.inverse,
                        &mut agg,
                        pool.as_deref(),
                    );
                    ids_by_dst[d] = dd.unique;
                    grad_by_dst[d] = agg;
                } else {
                    ids_by_dst[d] = std::mem::take(&mut occ_ids[d]);
                    grad_by_dst[d] = std::mem::take(&mut occ_grads[d]);
                }
            }
        }
        (ids_by_dst, grad_by_dst)
    }

    /// Phase 2 of the distributed backward: receive the exchanged
    /// gradients and aggregate across sources (always — correctness
    /// requires the owner to apply each id's total gradient once).
    /// Returns `(ids, grads)` for the local shard (grads in id order,
    /// `ids.len() × dim`); the caller feeds these to the sparse
    /// optimizer.
    pub fn complete_backward(
        &mut self,
        comm: &mut CommHandle,
        pending: PendingBackward,
    ) -> (Vec<GlobalId>, Vec<f32>) {
        let PendingBackward {
            ids_pending,
            grads_pending,
        } = pending;
        let recv_ids: Vec<Vec<GlobalId>> = comm
            .complete_all_to_all(ids_pending)
            .into_iter()
            .map(Message::into_ids)
            .collect();
        let recv_grads: Vec<Vec<f32>> = comm
            .complete_all_to_all(grads_pending)
            .into_iter()
            .map(Message::into_floats)
            .collect();
        if self.mixed_wire() {
            // Decode `[n][ids][tags]` sections and expand the
            // tag-selected gradient rows back to f32 with the
            // requester-sent tags.
            let mut ids = Vec::with_capacity(recv_ids.len());
            let mut grads = Vec::with_capacity(recv_grads.len());
            for (id_buf, grad_buf) in recv_ids.iter().zip(&recv_grads) {
                let mut off = 0usize;
                let (src_ids, hot) = decode_grad_ids_mixed(id_buf, &mut off);
                debug_assert_eq!(off, id_buf.len());
                let mut goff = 0usize;
                let src_grads = decode_grads_mixed(grad_buf, &mut goff, &hot, self.dim);
                debug_assert_eq!(goff, grad_buf.len());
                ids.push(src_ids);
                grads.push(src_grads);
            }
            self.aggregate_backward(ids, grads)
        } else {
            self.aggregate_backward(recv_ids, recv_grads)
        }
    }

    /// Aggregate exchanged gradients across sources (always —
    /// correctness requires the owner to apply each id's total gradient
    /// once). The per-source flatten order is fixed, so every schedule
    /// that delivers the same per-source lists gets bit-identical sums.
    fn aggregate_backward(
        &mut self,
        recv_ids: Vec<Vec<GlobalId>>,
        recv_grads: Vec<Vec<f32>>,
    ) -> (Vec<GlobalId>, Vec<f32>) {
        let dim = self.dim;
        let pool = self.pool.clone();
        let flat_ids: Vec<GlobalId> = recv_ids.iter().flatten().copied().collect();
        let flat_grads: Vec<f32> = recv_grads.into_iter().flatten().collect();
        let d = Dedup::of_auto(&flat_ids, pool.as_deref());
        let mut agg = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate_par(&flat_grads, dim, &d.inverse, &mut agg, pool.as_deref());
        (d.unique, agg)
    }

    /// Distributed backward, blocking: post + complete in one call.
    ///
    /// Collective: all ranks must call.
    pub fn backward(
        &mut self,
        comm: &mut CommHandle,
        ids: &[GlobalId],
        grads: &[f32],
    ) -> (Vec<GlobalId>, Vec<f32>) {
        let pending = self.post_backward(comm, ids, grads);
        self.complete_backward(comm, pending)
    }
}

/// In-flight state of a multi-group posted lookup (forward ID lane).
#[must_use = "a posted lookup must be completed or peers deadlock"]
pub struct MultiLookup(MultiLookupInner);

enum MultiLookupInner {
    PerGroup(Vec<PendingLookup>),
    Packed {
        layouts: Vec<LookupLayout>,
        pending: PendingAllToAll,
    },
}

/// In-flight state of a multi-group served lookup (embedding reply lane).
#[must_use = "a served lookup must be completed or peers deadlock"]
pub struct MultiReply(MultiReplyInner);

enum MultiReplyInner {
    PerGroup(Vec<PendingReply>),
    Packed {
        layouts: Vec<ReplyLayout>,
        pending: PendingAllToAll,
    },
}

/// In-flight state of a multi-group posted backward (gradient lanes).
#[must_use = "a posted backward must be completed or peers deadlock"]
pub struct MultiBackward(MultiBackwardInner);

enum MultiBackwardInner {
    PerGroup(Vec<PendingBackward>),
    Packed {
        ids_pending: PendingAllToAll,
        grads_pending: PendingAllToAll,
    },
}

/// Multiplexed multi-group exchange: packs every merge group's payload
/// into ONE message per comm lane instead of running one all-to-all per
/// group, cutting the per-exchange message count (and thus per-message
/// latency cost) from O(groups) to O(1).
///
/// Packed wire format (`groups > 1` with multiplexing on): each ID-lane
/// chunk carries `groups` u64 section-length headers followed by the
/// concatenated per-group id sections. Float lanes carry bare
/// concatenated sections — the receiver derives section lengths from
/// layout it already holds (its own stage-1 unique counts for replies;
/// the parsed ID headers for gradients), so replies and gradients pay
/// zero framing overhead. With one group — or with multiplexing
/// disabled — every call delegates to the historical per-group methods,
/// so the wire bytes are byte-identical to the unmultiplexed path (the
/// single-group compatibility guarantee).
///
/// The numerical results are bit-identical in both modes: packing only
/// reorders which wire message carries a section, never the per-source
/// section contents or the order they are folded in.
pub struct GroupExchange {
    mux: bool,
    /// Cumulative packing-header bytes per lane, counted with the same
    /// convention as [`crate::collective::comm::CommStats`] (remote
    /// chunks only). Subtract from `CommStats::lane_bytes` deltas to
    /// recover pure payload bytes — the trainer's wire-conservation
    /// accounting.
    pub header_bytes: [u64; LANES],
}

impl GroupExchange {
    pub fn new(mux: bool) -> Self {
        GroupExchange {
            mux,
            header_bytes: [0; LANES],
        }
    }

    /// Whether exchanges over `groups` merge groups take the packed path.
    pub fn packed(&self, groups: usize) -> bool {
        self.mux && groups > 1
    }

    /// Post every group's ID all-to-all — one packed message per lane in
    /// multiplexed mode, one exchange per group otherwise.
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_ids<S: EmbeddingStore>(
        &mut self,
        comm: &mut CommHandle,
        sharded: &mut [ShardedEmbedding<S>],
        ids_per_group: &[&[GlobalId]],
    ) -> MultiLookup {
        assert_eq!(ids_per_group.len(), sharded.len());
        let world = comm.world;
        if !self.packed(sharded.len()) {
            return MultiLookup(MultiLookupInner::PerGroup(
                sharded
                    .iter_mut()
                    .zip(ids_per_group)
                    .map(|(se, ids)| se.post_ids(comm, ids))
                    .collect(),
            ));
        }
        let groups = sharded.len();
        let prepared: Vec<(Vec<Vec<GlobalId>>, LookupLayout)> = sharded
            .iter_mut()
            .zip(ids_per_group)
            .map(|(se, ids)| se.prepare_lookup(world, ids))
            .collect();
        let mut chunks: Vec<Message> = Vec::with_capacity(world);
        for dst in 0..world {
            let sections: usize = prepared.iter().map(|(s, _)| s[dst].len()).sum();
            let mut packed: Vec<u64> = Vec::with_capacity(groups + sections);
            for (send_ids, _) in &prepared {
                packed.push(send_ids[dst].len() as u64);
            }
            for (send_ids, _) in &prepared {
                packed.extend_from_slice(&send_ids[dst]);
            }
            if dst != comm.rank {
                self.header_bytes[LANE_IDS] += groups as u64 * 8;
            }
            chunks.push(Message::Ids(packed));
        }
        let pending = comm.post_all_to_all_on(LANE_IDS, chunks);
        let layouts = prepared.into_iter().map(|(_, l)| l).collect();
        MultiLookup(MultiLookupInner::Packed { layouts, pending })
    }

    /// Serve every group's received requests and post the (packed)
    /// embedding reply.
    pub fn serve_reply<S: EmbeddingStore>(
        &mut self,
        comm: &mut CommHandle,
        sharded: &mut [ShardedEmbedding<S>],
        lookup: MultiLookup,
        train: bool,
    ) -> MultiReply {
        let world = comm.world;
        match lookup.0 {
            MultiLookupInner::PerGroup(pendings) => MultiReply(MultiReplyInner::PerGroup(
                sharded
                    .iter_mut()
                    .zip(pendings)
                    .map(|(se, p)| se.serve_reply(comm, p, train))
                    .collect(),
            )),
            MultiLookupInner::Packed {
                mut layouts,
                pending,
            } => {
                let groups = sharded.len();
                assert_eq!(layouts.len(), groups);
                for (se, layout) in sharded.iter_mut().zip(&mut layouts) {
                    se.last_id_bytes = std::mem::take(&mut layout.id_bytes);
                }
                // Unpack: `groups` section-length headers, then sections.
                let mut requested: Vec<Vec<Vec<GlobalId>>> =
                    (0..groups).map(|_| Vec::with_capacity(world)).collect();
                for msg in comm.complete_all_to_all(pending) {
                    let packed = msg.into_ids();
                    let mut off = groups;
                    for (g, req) in requested.iter_mut().enumerate() {
                        let len = packed[g] as usize;
                        req.push(packed[off..off + len].to_vec());
                        off += len;
                    }
                    debug_assert_eq!(off, packed.len());
                }
                // Serve every group, then concatenate the replies per
                // destination — no headers: the requester splits by its
                // own stage-1 unique counts.
                let replies: Vec<Vec<Vec<f32>>> = sharded
                    .iter_mut()
                    .zip(&layouts)
                    .zip(requested)
                    .map(|((se, layout), req)| {
                        se.serve_requested(world, req, &layout.sent_lens, &layout.raw_lens, train)
                    })
                    .collect();
                let mut chunks: Vec<Message> = Vec::with_capacity(world);
                for dst in 0..world {
                    let total: usize = replies.iter().map(|r| r[dst].len()).sum();
                    let mut packed = Vec::with_capacity(total);
                    for r in &replies {
                        packed.extend_from_slice(&r[dst]);
                    }
                    chunks.push(Message::Floats(packed));
                }
                let pending = comm.post_all_to_all_on(LANE_EMB, chunks);
                let layouts = layouts.into_iter().map(LookupLayout::into_reply).collect();
                MultiReply(MultiReplyInner::Packed { layouts, pending })
            }
        }
    }

    /// Complete every group's embedding reply; returns occurrence-order
    /// rows per group.
    pub fn complete_reply<S: EmbeddingStore>(
        &mut self,
        comm: &mut CommHandle,
        sharded: &mut [ShardedEmbedding<S>],
        reply: MultiReply,
    ) -> Vec<Vec<f32>> {
        match reply.0 {
            MultiReplyInner::PerGroup(pendings) => sharded
                .iter_mut()
                .zip(pendings)
                .map(|(se, p)| se.complete_reply(comm, p))
                .collect(),
            MultiReplyInner::Packed { layouts, pending } => {
                let groups = sharded.len();
                // Mixed groups refresh their reply-tag slot from this
                // reply; clear before walking the sources.
                for se in sharded.iter_mut() {
                    if se.mixed_wire() {
                        se.reply_hot.clear();
                    }
                }
                let mut returned: Vec<Vec<Vec<f32>>> = (0..groups).map(|_| Vec::new()).collect();
                for (src, msg) in comm.complete_all_to_all(pending).into_iter().enumerate() {
                    let packed = msg.into_floats();
                    let mut off = 0usize;
                    for (g, ret) in returned.iter_mut().enumerate() {
                        if sharded[g].mixed_wire() {
                            // Variable-length mixed section: the tag
                            // bitmask determines the row widths, so the
                            // walk is sequential — same section bytes
                            // as the per-group schedule.
                            ret.push(sharded[g].decode_reply_section(
                                &layouts[g],
                                src,
                                &packed,
                                &mut off,
                            ));
                        } else {
                            let len = layouts[g].sent_lens[src] * sharded[g].dim;
                            ret.push(packed[off..off + len].to_vec());
                            off += len;
                        }
                    }
                    debug_assert_eq!(off, packed.len());
                }
                sharded
                    .iter_mut()
                    .zip(&layouts)
                    .zip(returned)
                    .map(|((se, layout), rows)| se.scatter_reply(layout, &rows))
                    .collect()
            }
        }
    }

    /// Post every group's backward gradient exchange — packed ID and
    /// gradient lanes in multiplexed mode.
    ///
    /// Collective: all ranks must post and complete in the same order.
    pub fn post_backward<S: EmbeddingStore>(
        &mut self,
        comm: &mut CommHandle,
        sharded: &mut [ShardedEmbedding<S>],
        ids_per_group: &[&[GlobalId]],
        grads_per_group: &[&[f32]],
    ) -> MultiBackward {
        assert_eq!(ids_per_group.len(), sharded.len());
        assert_eq!(grads_per_group.len(), sharded.len());
        let world = comm.world;
        if !self.packed(sharded.len()) {
            return MultiBackward(MultiBackwardInner::PerGroup(
                sharded
                    .iter_mut()
                    .zip(ids_per_group.iter().zip(grads_per_group))
                    .map(|(se, (ids, grads))| se.post_backward(comm, ids, grads))
                    .collect(),
            ));
        }
        let groups = sharded.len();
        let parts: Vec<(Vec<Vec<u64>>, Vec<Vec<f32>>)> = sharded
            .iter_mut()
            .zip(ids_per_group.iter().zip(grads_per_group))
            .map(|(se, (ids, grads))| {
                let (ids_by_dst, grad_by_dst) = se.prepare_backward(world, ids, grads);
                se.backward_sections(ids_by_dst, grad_by_dst)
            })
            .collect();
        let mut id_chunks: Vec<Message> = Vec::with_capacity(world);
        let mut grad_chunks: Vec<Message> = Vec::with_capacity(world);
        for dst in 0..world {
            let sections: usize = parts.iter().map(|(i, _)| i[dst].len()).sum();
            let mut packed_ids: Vec<u64> = Vec::with_capacity(groups + sections);
            // One header word per group: the WORD length of the group's
            // id section. For fp32 groups the section is the raw id
            // list, so the header value (and the wire bytes) are
            // unchanged from the id-count scheme; mixed sections carry
            // their own `[n][ids][tags]` framing inside.
            for (id_secs, _) in &parts {
                packed_ids.push(id_secs[dst].len() as u64);
            }
            for (id_secs, _) in &parts {
                packed_ids.extend_from_slice(&id_secs[dst]);
            }
            let floats: usize = parts.iter().map(|(_, g)| g[dst].len()).sum();
            let mut packed_grads: Vec<f32> = Vec::with_capacity(floats);
            for (_, grad_by_dst) in &parts {
                packed_grads.extend_from_slice(&grad_by_dst[dst]);
            }
            if dst != comm.rank {
                self.header_bytes[LANE_GRAD_IDS] += groups as u64 * 8;
            }
            id_chunks.push(Message::Ids(packed_ids));
            grad_chunks.push(Message::Floats(packed_grads));
        }
        let ids_pending = comm.post_all_to_all_on(LANE_GRAD_IDS, id_chunks);
        let grads_pending = comm.post_all_to_all_on(LANE_GRAD, grad_chunks);
        MultiBackward(MultiBackwardInner::Packed {
            ids_pending,
            grads_pending,
        })
    }

    /// Complete every group's backward exchange; returns per-group
    /// `(ids, grads)` for the local shards.
    pub fn complete_backward<S: EmbeddingStore>(
        &mut self,
        comm: &mut CommHandle,
        sharded: &mut [ShardedEmbedding<S>],
        pending: MultiBackward,
    ) -> Vec<(Vec<GlobalId>, Vec<f32>)> {
        match pending.0 {
            MultiBackwardInner::PerGroup(pendings) => sharded
                .iter_mut()
                .zip(pendings)
                .map(|(se, pb)| se.complete_backward(comm, pb))
                .collect(),
            MultiBackwardInner::Packed {
                ids_pending,
                grads_pending,
            } => {
                let groups = sharded.len();
                let mixed: Vec<bool> = sharded.iter().map(|se| se.mixed_wire()).collect();
                let mut recv_ids: Vec<Vec<Vec<GlobalId>>> =
                    (0..groups).map(|_| Vec::new()).collect();
                let mut recv_hot: Vec<Vec<Vec<bool>>> =
                    (0..groups).map(|_| Vec::new()).collect();
                for msg in comm.complete_all_to_all(ids_pending) {
                    let packed = msg.into_ids();
                    let mut off = groups;
                    for (g, (recv, hot_recv)) in
                        recv_ids.iter_mut().zip(recv_hot.iter_mut()).enumerate()
                    {
                        let len = packed[g] as usize;
                        let section = &packed[off..off + len];
                        if mixed[g] {
                            let mut soff = 0usize;
                            let (ids, hot) = decode_grad_ids_mixed(section, &mut soff);
                            debug_assert_eq!(soff, len);
                            recv.push(ids);
                            hot_recv.push(hot);
                        } else {
                            recv.push(section.to_vec());
                            hot_recv.push(Vec::new());
                        }
                        off += len;
                    }
                    debug_assert_eq!(off, packed.len());
                }
                let mut recv_grads: Vec<Vec<Vec<f32>>> =
                    (0..groups).map(|_| Vec::new()).collect();
                for (src, msg) in comm.complete_all_to_all(grads_pending).into_iter().enumerate()
                {
                    let packed = msg.into_floats();
                    let mut off = 0usize;
                    for (g, recv) in recv_grads.iter_mut().enumerate() {
                        if mixed[g] {
                            recv.push(decode_grads_mixed(
                                &packed,
                                &mut off,
                                &recv_hot[g][src],
                                sharded[g].dim,
                            ));
                        } else {
                            let len = recv_ids[g][src].len() * sharded[g].dim;
                            recv.push(packed[off..off + len].to_vec());
                            off += len;
                        }
                    }
                    debug_assert_eq!(off, packed.len());
                }
                sharded
                    .iter_mut()
                    .zip(recv_ids.into_iter().zip(recv_grads))
                    .map(|(se, (ids, grads))| se.aggregate_backward(ids, grads))
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::comm::{CommGroup, CommStats};
    use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
    use std::sync::Arc;
    use std::thread;

    const DIM: usize = 4;

    fn run_sharded<T: Send + 'static>(
        world: usize,
        strategy: DedupStrategy,
        f: impl Fn(usize, &mut ShardedEmbedding<DynamicEmbeddingTable>, &mut CommHandle) -> T
            + Send
            + Sync
            + 'static,
    ) -> Vec<T> {
        let handles = CommGroup::new(world);
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for (rank, mut h) in handles.into_iter().enumerate() {
            let f = Arc::clone(&f);
            joins.push(thread::spawn(move || {
                let table = DynamicEmbeddingTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
                );
                let mut se = ShardedEmbedding::new(table, strategy);
                f(rank, &mut se, &mut h)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    /// Reference: what a single unsharded table would return. Row init is
    /// a pure function of (id, seed), so the expected rows are computable
    /// independently.
    fn expected_row(id: GlobalId) -> Vec<f32> {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
        );
        let mut out = vec![0.0; DIM];
        t.lookup_or_insert(id, &mut out);
        out
    }

    #[test]
    fn lookup_matches_unsharded_reference_all_strategies() {
        for strategy in [
            DedupStrategy::None,
            DedupStrategy::CommUnique,
            DedupStrategy::LookupUnique,
            DedupStrategy::TwoStage,
        ] {
            let out = run_sharded(4, strategy, |rank, se, comm| {
                // Overlapping id lists across ranks, with duplicates.
                let ids: Vec<u64> =
                    vec![1, 2, 3, 1, 2, 100 + rank as u64, 3, 1, 50, 50];
                let rows = se.lookup(comm, &ids, true);
                (ids, rows)
            });
            for (ids, rows) in out {
                for (i, &id) in ids.iter().enumerate() {
                    assert_eq!(
                        &rows[i * DIM..(i + 1) * DIM],
                        expected_row(id).as_slice(),
                        "strategy {strategy:?} id {id}"
                    );
                }
            }
        }
    }

    #[test]
    fn dedup_strategies_reduce_volume_in_order() {
        // two-stage ≤ comm-unique ≤ none for ids_sent; lookups_done
        // minimized by stage2.
        let mut results = Vec::new();
        for strategy in [
            DedupStrategy::None,
            DedupStrategy::CommUnique,
            DedupStrategy::TwoStage,
        ] {
            let out = run_sharded(4, strategy, |_rank, se, comm| {
                let ids: Vec<u64> = (0..1000).map(|i| (i % 37) as u64).collect();
                let _ = se.lookup(comm, &ids, true);
                se.volume
            });
            results.push((strategy, out[0]));
        }
        let none = results[0].1;
        let comm_u = results[1].1;
        let two = results[2].1;
        assert_eq!(none.ids_sent, none.ids_raw);
        assert!(comm_u.ids_sent < none.ids_sent);
        assert_eq!(two.ids_sent, comm_u.ids_sent);
        assert!(two.lookups_done < comm_u.lookups_done);
        assert!(comm_u.emb_rows_sent < none.emb_rows_raw);
    }

    #[test]
    fn empty_ranks_participate() {
        let out = run_sharded(3, DedupStrategy::TwoStage, |rank, se, comm| {
            let ids: Vec<u64> = if rank == 0 { vec![9, 9, 9] } else { vec![] };
            se.lookup(comm, &ids, true)
        });
        assert_eq!(out[0].len(), 3 * DIM);
        assert_eq!(&out[0][0..DIM], expected_row(9).as_slice());
        assert!(out[1].is_empty() && out[2].is_empty());
    }

    #[test]
    fn pipelined_lookup_matches_blocking_lookup() {
        // Two micro-batches per rank: post batch 1's IDs before
        // completing batch 0 (the overlap schedule), and verify rows are
        // bitwise identical to the blocking schedule.
        let out = run_sharded(4, DedupStrategy::TwoStage, |rank, se, comm| {
            let batch0: Vec<u64> = vec![1, 2, 3, 1, 50 + rank as u64];
            let batch1: Vec<u64> = vec![2, 9, 9, 70 + rank as u64];
            let p0 = se.post_ids(comm, &batch0);
            let p1 = se.post_ids(comm, &batch1); // posted before completing p0
            let rows0 = se.complete_lookup(comm, p0, true);
            let rows1 = se.complete_lookup(comm, p1, true);
            (batch0, rows0, batch1, rows1)
        });
        for (batch0, rows0, batch1, rows1) in out {
            for (i, &id) in batch0.iter().enumerate() {
                assert_eq!(&rows0[i * DIM..(i + 1) * DIM], expected_row(id).as_slice());
            }
            for (i, &id) in batch1.iter().enumerate() {
                assert_eq!(&rows1[i * DIM..(i + 1) * DIM], expected_row(id).as_slice());
            }
        }
    }

    #[test]
    fn pipelined_volume_accounting_matches_blocking() {
        let run = |pipelined: bool| {
            run_sharded(2, DedupStrategy::TwoStage, move |_rank, se, comm| {
                let batch0: Vec<u64> = (0..200).map(|i| (i % 17) as u64).collect();
                let batch1: Vec<u64> = (0..100).map(|i| (i % 5) as u64).collect();
                if pipelined {
                    let p0 = se.post_ids(comm, &batch0);
                    let p1 = se.post_ids(comm, &batch1);
                    let _ = se.complete_lookup(comm, p0, true);
                    let _ = se.complete_lookup(comm, p1, true);
                } else {
                    let _ = se.lookup(comm, &batch0, true);
                    let _ = se.lookup(comm, &batch1, true);
                }
                se.volume
            })
        };
        let blocking = run(false);
        let pipelined = run(true);
        for (b, p) in blocking.iter().zip(&pipelined) {
            assert_eq!(b, p, "volume accounting must not depend on scheduling");
        }
    }

    /// Canonicalize a backward result for comparison (id-sorted rows).
    fn sorted_pairs(lids: &[u64], lgrads: &[f32]) -> Vec<(u64, Vec<f32>)> {
        let mut pairs: Vec<(u64, Vec<f32>)> = lids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, lgrads[i * DIM..(i + 1) * DIM].to_vec()))
            .collect();
        pairs.sort_by_key(|p| p.0);
        pairs
    }

    type RoundResults = (Vec<Vec<f32>>, Vec<Vec<(u64, Vec<f32>)>>);

    /// Three rounds of lookup+backward per rank under the given
    /// schedule; returns per-round rows and id-sorted shard gradients.
    fn run_schedule(double_buffered: bool) -> Vec<RoundResults> {
        run_sharded(4, DedupStrategy::TwoStage, move |rank, se, comm| {
            let batches: Vec<Vec<u64>> = (0..3)
                .map(|b| vec![1 + b as u64, 2, 3, 40 + rank as u64, 2])
                .collect();
            let mut rows_all = Vec::new();
            let mut grads_all: Vec<Vec<(u64, Vec<f32>)>> = Vec::new();
            if !double_buffered {
                for b in &batches {
                    let rows = se.lookup(comm, b, true);
                    let grads = vec![0.25f32; b.len() * DIM];
                    let (lids, lgrads) = se.backward(comm, b, &grads);
                    rows_all.push(rows);
                    grads_all.push(sorted_pairs(&lids, &lgrads));
                }
            } else {
                // The PR-2 trainer schedule: serve round k, post round
                // k+1's IDs while k's reply is in flight, and complete
                // round k's gradient exchange only during round k+1.
                let mut posted = Some(se.post_ids(comm, &batches[0]));
                let mut posted_bwd: Option<PendingBackward> = None;
                for (round, b) in batches.iter().enumerate() {
                    let pending = posted.take().unwrap();
                    let reply = se.serve_reply(comm, pending, true);
                    if round + 1 < batches.len() {
                        posted = Some(se.post_ids(comm, &batches[round + 1]));
                    }
                    let rows = se.complete_reply(comm, reply);
                    rows_all.push(rows);
                    if let Some(pb) = posted_bwd.take() {
                        let (lids, lgrads) = se.complete_backward(comm, pb);
                        grads_all.push(sorted_pairs(&lids, &lgrads));
                    }
                    let grads = vec![0.25f32; b.len() * DIM];
                    posted_bwd = Some(se.post_backward(comm, b, &grads));
                }
                let (lids, lgrads) = se.complete_backward(comm, posted_bwd.take().unwrap());
                grads_all.push(sorted_pairs(&lids, &lgrads));
            }
            (rows_all, grads_all)
        })
    }

    #[test]
    fn double_buffered_schedule_bit_identical_to_blocking() {
        let blocking = run_schedule(false);
        let pipelined = run_schedule(true);
        for (rank, (b, p)) in blocking.iter().zip(&pipelined).enumerate() {
            assert_eq!(b.0, p.0, "rank {rank}: forward rows diverged");
            assert_eq!(b.1, p.1, "rank {rank}: backward gradients diverged");
        }
    }

    #[test]
    fn pooled_concurrent_lookup_matches_reference_rows() {
        use crate::embedding::concurrent::ConcurrentDynamicTable;
        let handles = CommGroup::new(2);
        let mut joins = Vec::new();
        for (rank, mut comm) in handles.into_iter().enumerate() {
            joins.push(thread::spawn(move || {
                let table = ConcurrentDynamicTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
                    8,
                );
                let pool = Arc::new(WorkerPool::new(2));
                let mut se =
                    ShardedEmbedding::new(table, DedupStrategy::TwoStage).with_pool(pool);
                // Large batch: clears the parallel-fetch and sorted-dedup
                // thresholds, so the pooled paths actually engage.
                let ids: Vec<u64> = (0..10_000u64)
                    .map(|i| (i * 31 + rank as u64) % 500)
                    .collect();
                let rows = se.lookup(&mut comm, &ids, true);
                let grads = vec![0.5f32; ids.len() * DIM];
                let (lids, lgrads) = se.backward(&mut comm, &ids, &grads);
                (ids, rows, lids, lgrads)
            }));
        }
        for j in joins {
            let (ids, rows, lids, lgrads) = j.join().unwrap();
            for (i, &id) in ids.iter().enumerate() {
                assert_eq!(
                    &rows[i * DIM..(i + 1) * DIM],
                    expected_row(id).as_slice(),
                    "id {id}"
                );
            }
            assert_eq!(lgrads.len(), lids.len() * DIM);
        }
    }

    #[test]
    fn backward_aggregates_across_ranks_and_duplicates() {
        // Every rank contributes gradient 1.0 for id 5 twice, and rank r
        // contributes r for id 6 once. Total for id 5 = 2×world, for
        // id 6 = sum of ranks.
        let world = 4;
        let out = run_sharded(world, DedupStrategy::TwoStage, |rank, se, comm| {
            // Forward to materialize rows.
            let ids = vec![5u64, 5, 6];
            let _ = se.lookup(comm, &ids, true);
            let mut grads = vec![0.0f32; ids.len() * DIM];
            grads[0..DIM].fill(1.0);
            grads[DIM..2 * DIM].fill(1.0);
            grads[2 * DIM..3 * DIM].fill(rank as f32);
            let (lids, lgrads) = se.backward(comm, &ids, &grads);
            (lids, lgrads)
        });
        // Exactly one rank owns id 5 and one owns id 6.
        let mut seen5 = 0;
        let mut seen6 = 0;
        for (lids, lgrads) in out {
            for (i, &id) in lids.iter().enumerate() {
                let g = &lgrads[i * DIM..(i + 1) * DIM];
                if id == 5 {
                    seen5 += 1;
                    assert_eq!(g, vec![2.0 * world as f32; DIM].as_slice());
                } else if id == 6 {
                    seen6 += 1;
                    assert_eq!(g, vec![0.0 + 1.0 + 2.0 + 3.0; DIM].as_slice());
                } else {
                    panic!("unexpected id {id}");
                }
            }
        }
        assert_eq!(seen5, 1);
        assert_eq!(seen6, 1);
    }

    #[test]
    fn backward_same_totals_without_stage1() {
        let world = 2;
        for strategy in [DedupStrategy::None, DedupStrategy::TwoStage] {
            let out = run_sharded(world, strategy, |_rank, se, comm| {
                let ids = vec![1u64, 1, 2];
                let _ = se.lookup(comm, &ids, true);
                let grads = vec![0.5f32; ids.len() * DIM];
                se.backward(comm, &ids, &grads)
            });
            let mut total: f32 = 0.0;
            for (_ids, grads) in out {
                total += grads.iter().sum::<f32>();
            }
            // 3 occurrences × 2 ranks × 0.5 × DIM dims.
            assert_eq!(total, 3.0 * 2.0 * 0.5 * DIM as f32, "{strategy:?}");
        }
    }

    #[test]
    fn shard_owner_balanced() {
        let world = 8;
        let mut counts = vec![0usize; world];
        for id in 0..80_000u64 {
            counts[shard_owner(id, world)] += 1;
        }
        for &c in &counts {
            let dev = (c as f64 - 10_000.0).abs() / 10_000.0;
            assert!(dev < 0.05, "shard imbalance {c}");
        }
    }

    /// Dim-parametric unsharded reference row.
    fn expected_row_dim(dim: usize, id: GlobalId) -> Vec<f32> {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(dim).with_capacity(256).with_seed(7),
        );
        let mut out = vec![0.0; dim];
        t.lookup_or_insert(id, &mut out);
        out
    }

    /// Canonical backward result at an arbitrary dim (id-sorted rows).
    fn sorted_pairs_dim(dim: usize, lids: &[u64], lgrads: &[f32]) -> Vec<(u64, Vec<f32>)> {
        let mut pairs: Vec<(u64, Vec<f32>)> = lids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, lgrads[i * dim..(i + 1) * dim].to_vec()))
            .collect();
        pairs.sort_by_key(|p| p.0);
        pairs
    }

    /// Per-rank output of a three-round two-group schedule: rows per
    /// round per group, sorted backward pairs per round per group, comm
    /// stats, exchange header bytes, per-group volume.
    type GroupRun = (
        Vec<Vec<Vec<f32>>>,
        Vec<Vec<Vec<(u64, Vec<f32>)>>>,
        CommStats,
        [u64; LANES],
        Vec<DedupVolume>,
    );

    /// Three forward+backward rounds over two merge groups (dims 4 and
    /// 8) through [`GroupExchange`], multiplexed or per-group.
    fn run_group_exchange(mux: bool) -> Vec<GroupRun> {
        let world = 4;
        let handles = CommGroup::new(world);
        let mut joins = Vec::new();
        for (rank, mut comm) in handles.into_iter().enumerate() {
            joins.push(thread::spawn(move || {
                let dims = [4usize, 8];
                let mut groups: Vec<ShardedEmbedding<DynamicEmbeddingTable>> = dims
                    .iter()
                    .map(|&d| {
                        ShardedEmbedding::new(
                            DynamicEmbeddingTable::new(
                                DynamicTableConfig::new(d).with_capacity(256).with_seed(7),
                            ),
                            DedupStrategy::TwoStage,
                        )
                    })
                    .collect();
                let mut ex = GroupExchange::new(mux);
                let mut rows_all = Vec::new();
                let mut grads_all = Vec::new();
                for round in 0..3u64 {
                    let ids0: Vec<u64> = vec![1 + round, 2, 3, 40 + rank as u64, 2];
                    let ids1: Vec<u64> = vec![7, 7, 9 + round, 100 + rank as u64];
                    let lookup = ex.post_ids(&mut comm, &mut groups, &[&ids0, &ids1]);
                    let reply = ex.serve_reply(&mut comm, &mut groups, lookup, true);
                    let rows = ex.complete_reply(&mut comm, &mut groups, reply);
                    for (g, ids) in [&ids0, &ids1].into_iter().enumerate() {
                        for (i, &id) in ids.iter().enumerate() {
                            assert_eq!(
                                &rows[g][i * dims[g]..(i + 1) * dims[g]],
                                expected_row_dim(dims[g], id).as_slice(),
                                "mux {mux} group {g} id {id}"
                            );
                        }
                    }
                    let g0 = vec![0.25f32; ids0.len() * dims[0]];
                    let g1 = vec![0.5f32; ids1.len() * dims[1]];
                    let pb =
                        ex.post_backward(&mut comm, &mut groups, &[&ids0, &ids1], &[&g0, &g1]);
                    let bwd = ex.complete_backward(&mut comm, &mut groups, pb);
                    rows_all.push(rows);
                    grads_all.push(
                        bwd.iter()
                            .enumerate()
                            .map(|(g, (lids, lgrads))| sorted_pairs_dim(dims[g], lids, lgrads))
                            .collect::<Vec<_>>(),
                    );
                }
                let volumes = groups.iter().map(|g| g.volume).collect::<Vec<_>>();
                (rows_all, grads_all, comm.stats, ex.header_bytes, volumes)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn multiplexed_exchange_bit_identical_to_per_group() {
        let per_group = run_group_exchange(false);
        let muxed = run_group_exchange(true);
        for (rank, (p, m)) in per_group.iter().zip(&muxed).enumerate() {
            assert_eq!(p.0, m.0, "rank {rank}: forward rows diverged");
            assert_eq!(p.1, m.1, "rank {rank}: backward gradients diverged");
            assert_eq!(p.4, m.4, "rank {rank}: volume accounting diverged");
            // Payload conservation: per-lane wire bytes minus the packing
            // headers must equal the unmultiplexed bytes exactly.
            for lane in [LANE_IDS, LANE_EMB, LANE_GRAD_IDS, LANE_GRAD] {
                assert_eq!(
                    m.2.lane_bytes[lane] - m.3[lane],
                    p.2.lane_bytes[lane] - p.3[lane],
                    "rank {rank}: lane {lane} payload bytes not conserved"
                );
            }
            // The point of multiplexing: per round, 4 messages instead of
            // 2 groups × 4 lanes = 8.
            assert_eq!(p.2.all_to_all_ops, 3 * 2 * 4);
            assert_eq!(m.2.all_to_all_ops, 3 * 4);
            // Headers: `groups` u64 section-length words per remote chunk
            // on each ID lane, per round; float lanes are frameless.
            assert_eq!(m.3[LANE_IDS], 3 * 3 * 2 * 8);
            assert_eq!(m.3[LANE_GRAD_IDS], 3 * 3 * 2 * 8);
            assert_eq!(m.3[LANE_EMB], 0);
            assert_eq!(m.3[LANE_GRAD], 0);
            assert_eq!(p.3, [0u64; LANES], "per-group mode never adds headers");
        }
    }

    /// Sharded run over mixed-precision concurrent tables (the store
    /// the trainer actually shards), with a per-rank policy.
    fn run_sharded_mixed<T: Send + 'static>(
        world: usize,
        policy: crate::embedding::precision::PrecisionPolicy,
        f: impl Fn(
                usize,
                &mut ShardedEmbedding<crate::embedding::concurrent::ConcurrentDynamicTable>,
                &mut CommHandle,
            ) -> T
            + Send
            + Sync
            + 'static,
    ) -> Vec<T> {
        use crate::embedding::concurrent::ConcurrentDynamicTable;
        let handles = CommGroup::new(world);
        let f = Arc::new(f);
        let mut joins = Vec::new();
        for (rank, mut h) in handles.into_iter().enumerate() {
            let f = Arc::clone(&f);
            joins.push(thread::spawn(move || {
                let table = ConcurrentDynamicTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(7),
                    8,
                )
                .with_precision(policy);
                let mut se = ShardedEmbedding::new(table, DedupStrategy::TwoStage);
                f(rank, &mut se, &mut h)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn mixed_wire_cold_replies_lossless_on_f16_grid() {
        use crate::embedding::precision::PrecisionPolicy;
        use crate::util::f16::quantize_f16_slice;
        // Threshold far above any access count: every row stays cold,
        // every reply row rides the wire as packed binary16. The store
        // quantized the fetched copy too (the storage invariant), so the
        // decoded rows must equal the f16-quantized reference exactly —
        // the compression itself is lossless.
        let out = run_sharded_mixed(2, PrecisionPolicy::mixed(100), |rank, se, comm| {
            let ids: Vec<u64> = vec![1, 2, 3, 1, 50 + rank as u64];
            let rows = se.lookup(comm, &ids, true);
            (ids, rows, se.precision_wire)
        });
        for (ids, rows, wire) in out {
            for (i, &id) in ids.iter().enumerate() {
                let mut want = expected_row(id);
                quantize_f16_slice(&mut want);
                assert_eq!(
                    &rows[i * DIM..(i + 1) * DIM],
                    want.as_slice(),
                    "cold row for id {id} must round-trip on the f16 grid"
                );
            }
            assert_eq!(wire.fp32_row_bytes, 0, "no hot rows at threshold 100");
            assert!(wire.fp16_row_bytes > 0);
            assert!(wire.tag_bytes > 0);
        }
    }

    #[test]
    fn mixed_wire_backward_quantizes_cold_pushes_and_falls_back_hot() {
        use crate::embedding::precision::PrecisionPolicy;
        use crate::util::f16::quantize_f16;
        // All-cold pushes: each rank aggregates id 5's two occurrences
        // to 0.2 (stage 1), quantizes the push to binary16 (the lossy
        // half), and the owner sums the decoded pushes. Id 7 skipped
        // forward, so it carries no reply tag and must fall back to a
        // lossless FP32 push.
        let world = 2;
        let out = run_sharded_mixed(world, PrecisionPolicy::mixed(100), |_rank, se, comm| {
            let fwd = vec![5u64, 5, 6];
            let _ = se.lookup(comm, &fwd, true);
            let ids = vec![5u64, 5, 6, 7];
            let mut grads = vec![0.1f32; ids.len() * DIM];
            grads[3 * DIM..4 * DIM].fill(0.3);
            se.backward(comm, &ids, &grads)
        });
        let q2 = quantize_f16(0.1f32 + 0.1f32);
        let q1 = quantize_f16(0.1f32);
        let mut seen = 0;
        for (lids, lgrads) in out {
            for (i, &id) in lids.iter().enumerate() {
                let g = &lgrads[i * DIM..(i + 1) * DIM];
                seen += 1;
                match id {
                    5 => assert_eq!(g, vec![world as f32 * q2; DIM].as_slice()),
                    6 => assert_eq!(g, vec![world as f32 * q1; DIM].as_slice()),
                    // Untagged id: exact FP32 sum, no quantization.
                    7 => assert_eq!(g, vec![world as f32 * 0.3; DIM].as_slice()),
                    _ => panic!("unexpected id {id}"),
                }
            }
        }
        assert_eq!(seen, 3, "each id owned by exactly one rank");
    }

    /// Per-rank output of the heterogeneous-precision group schedule.
    type MixedGroupRun = (
        Vec<Vec<Vec<f32>>>,
        Vec<Vec<Vec<(u64, Vec<f32>)>>>,
        CommStats,
        [u64; LANES],
        Vec<PrecisionWireBytes>,
    );

    /// Three forward+backward rounds over two merge groups — group 0
    /// mixed (threshold 2, so classifications evolve across rounds),
    /// group 1 pure FP32 — through [`GroupExchange`].
    fn run_group_exchange_mixed(mux: bool) -> Vec<MixedGroupRun> {
        use crate::embedding::concurrent::ConcurrentDynamicTable;
        use crate::embedding::precision::PrecisionPolicy;
        let world = 4;
        let handles = CommGroup::new(world);
        let mut joins = Vec::new();
        for (rank, mut comm) in handles.into_iter().enumerate() {
            joins.push(thread::spawn(move || {
                let dims = [4usize, 8];
                let policies = [PrecisionPolicy::mixed(2), PrecisionPolicy::fp32()];
                let mut groups: Vec<ShardedEmbedding<ConcurrentDynamicTable>> = dims
                    .iter()
                    .zip(policies)
                    .map(|(&d, p)| {
                        ShardedEmbedding::new(
                            ConcurrentDynamicTable::new(
                                DynamicTableConfig::new(d).with_capacity(256).with_seed(7),
                                8,
                            )
                            .with_precision(p),
                            DedupStrategy::TwoStage,
                        )
                    })
                    .collect();
                let mut ex = GroupExchange::new(mux);
                let mut rows_all = Vec::new();
                let mut grads_all = Vec::new();
                for round in 0..3u64 {
                    let ids0: Vec<u64> = vec![1 + round, 2, 3, 40 + rank as u64, 2];
                    let ids1: Vec<u64> = vec![7, 7, 9 + round, 100 + rank as u64];
                    let lookup = ex.post_ids(&mut comm, &mut groups, &[&ids0, &ids1]);
                    let reply = ex.serve_reply(&mut comm, &mut groups, lookup, true);
                    let rows = ex.complete_reply(&mut comm, &mut groups, reply);
                    let g0 = vec![0.1f32; ids0.len() * dims[0]];
                    let g1 = vec![0.5f32; ids1.len() * dims[1]];
                    let pb =
                        ex.post_backward(&mut comm, &mut groups, &[&ids0, &ids1], &[&g0, &g1]);
                    let bwd = ex.complete_backward(&mut comm, &mut groups, pb);
                    rows_all.push(rows);
                    grads_all.push(
                        bwd.iter()
                            .enumerate()
                            .map(|(g, (lids, lgrads))| sorted_pairs_dim(dims[g], lids, lgrads))
                            .collect::<Vec<_>>(),
                    );
                }
                let wires = groups.iter().map(|g| g.precision_wire).collect::<Vec<_>>();
                (rows_all, grads_all, comm.stats, ex.header_bytes, wires)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn mixed_multiplexed_exchange_bit_identical_to_per_group() {
        let per_group = run_group_exchange_mixed(false);
        let muxed = run_group_exchange_mixed(true);
        let mut mixed_total = PrecisionWireBytes::default();
        for (rank, (p, m)) in per_group.iter().zip(&muxed).enumerate() {
            assert_eq!(p.0, m.0, "rank {rank}: forward rows diverged");
            assert_eq!(p.1, m.1, "rank {rank}: backward gradients diverged");
            // The per-precision meters count every destination including
            // loopback — a pure function of the served batches, so they
            // must not depend on the schedule either.
            assert_eq!(p.4, m.4, "rank {rank}: precision wire meters diverged");
            assert_eq!(p.4[1], PrecisionWireBytes::default(), "fp32 group meters stay zero");
            mixed_total.merge(&p.4[0]);
            // Payload conservation holds for the mixed format too: the
            // packed sections are byte-identical to the per-group ones,
            // and only the u64 section headers differ.
            for lane in [LANE_IDS, LANE_EMB, LANE_GRAD_IDS, LANE_GRAD] {
                assert_eq!(
                    m.2.lane_bytes[lane] - m.3[lane],
                    p.2.lane_bytes[lane] - p.3[lane],
                    "rank {rank}: lane {lane} payload bytes not conserved"
                );
            }
        }
        // Threshold 2 with three rounds: round 0 serves cold rows,
        // repeated ids promote and later rounds serve full width.
        assert!(mixed_total.fp16_row_bytes > 0, "cold rounds must compress");
        assert!(mixed_total.fp32_row_bytes > 0, "post-promotion rounds go full width");
        assert!(mixed_total.tag_bytes > 0);
    }

    #[test]
    fn single_group_multiplexed_wire_identical() {
        // One merge group: GroupExchange (mux on) must degenerate to the
        // historical wire format — same op count, same per-lane bytes,
        // zero headers — and produce the same rows.
        let run = |via_exchange: bool| {
            run_sharded(2, DedupStrategy::TwoStage, move |rank, se, comm| {
                let ids: Vec<u64> = vec![1, 2, 3, 1, 50 + rank as u64];
                let rows = if via_exchange {
                    let mut ex = GroupExchange::new(true);
                    let groups = std::slice::from_mut(se);
                    let lookup = ex.post_ids(comm, groups, &[&ids]);
                    let reply = ex.serve_reply(comm, groups, lookup, true);
                    let mut rows = ex.complete_reply(comm, groups, reply);
                    assert_eq!(ex.header_bytes, [0u64; LANES]);
                    rows.pop().unwrap()
                } else {
                    se.lookup(comm, &ids, true)
                };
                (rows, comm.stats)
            })
        };
        let direct = run(false);
        let muxed = run(true);
        for ((r_d, s_d), (r_m, s_m)) in direct.iter().zip(&muxed) {
            assert_eq!(r_d, r_m, "rows must match the direct path");
            assert_eq!(s_d.lane_bytes, s_m.lane_bytes, "wire bytes must be identical");
            assert_eq!(s_d.all_to_all_ops, s_m.all_to_all_ops);
        }
    }
}
