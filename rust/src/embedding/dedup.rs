//! Two-stage ID deduplication (§4.3).
//!
//! A sequence batch contains many duplicate feature IDs (Zipf-skewed item
//! popularity plus repeated in-sequence items). Each sharded lookup does
//! two all-to-alls — ID exchange then embedding exchange — and duplicates
//! inflate both, with embedding payloads (dim × 4 bytes per occurrence)
//! dominating.
//!
//! - **Stage 1** (before the ID all-to-all): each device deduplicates the
//!   IDs it is about to send *per destination shard*, so peers receive —
//!   and later return embeddings for — each ID at most once per source.
//! - **Stage 2** (after the ID all-to-all): the IDs a device received
//!   from its peers still overlap across sources; deduplicate the union
//!   before touching the hash table so each row is fetched once.
//!
//! This module provides the dedup kernel (with an inverse index so
//! embeddings can be scattered back to occurrence order), the gradient
//! counterpart (duplicate occurrences' gradients *accumulate* into the
//! unique row — also the sparse-gradient-accumulation primitive of §5.2),
//! and volume accounting used by the Figure 16 experiment.

use crate::embedding::hash::fmix64;
use crate::embedding::GlobalId;
use crate::util::pool::WorkerPool;
use crate::util::tuning::TunableThreshold;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Single-shot fmix64 hasher for u64 keys — bypasses SipHash on the
/// dedup hot path (§Perf: ~1.7x faster deduplication; IDs are already
/// well-mixed by Eq. 8 packing so DoS-resistance is irrelevant here).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Only u64 keys are ever hashed; fall back defensively.
        let mut buf = [0u8; 8];
        buf[..bytes.len().min(8)].copy_from_slice(&bytes[..bytes.len().min(8)]);
        self.0 = fmix64(u64::from_le_bytes(buf));
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.0 = fmix64(x);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

/// `HashMap` keyed by ids with the fast hasher.
pub type IdMap<V> = HashMap<GlobalId, V, BuildHasherDefault<IdHasher>>;

/// Which dedup kernel [`Dedup::of`] picks for a given input size
/// (exposed so benches can report the strategy actually exercised).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DedupKernel {
    /// fmix64 hash map, first-occurrence unique order — wins on small
    /// batches (cache-resident map, no O(n log n) sort).
    Hash,
    /// Sort + run-length unique, ascending unique order — wins on large
    /// batches (branch-predictable, parallelizable chunk sort + merge).
    Sort,
}

/// Default occurrence count above which [`Dedup::of`] switches from the
/// hash kernel to the sorted kernel. The live value is the
/// runtime-tunable [`DEDUP_SORT`] (env `MTGR_DEDUP_SORT_THRESHOLD`);
/// `bench_parallel_lookup --calibrate` sweeps the crossover.
pub const DEDUP_SORT_THRESHOLD: usize = crate::util::tuning::calibrated::DEDUP_SORT;

/// Runtime knob for the hash→sort dedup switch.
pub static DEDUP_SORT: TunableThreshold =
    TunableThreshold::new("MTGR_DEDUP_SORT_THRESHOLD", DEDUP_SORT_THRESHOLD);

/// Live hash→sort switch point (env/setter override, else the default).
pub fn dedup_sort_threshold() -> usize {
    DEDUP_SORT.get()
}

/// Result of deduplicating an ID list: the unique IDs plus, for every
/// original position, the index of its unique representative.
#[derive(Clone, Debug, PartialEq)]
pub struct Dedup {
    pub unique: Vec<GlobalId>,
    pub inverse: Vec<u32>,
}

impl Dedup {
    /// Kernel [`Dedup::of`] / [`Dedup::of_auto`] will use for `n`
    /// occurrences.
    pub fn kernel_for(n: usize) -> DedupKernel {
        if n >= dedup_sort_threshold() {
            DedupKernel::Sort
        } else {
            DedupKernel::Hash
        }
    }

    /// Deduplicate, choosing the kernel by input size (serial).
    ///
    /// Small inputs keep the hash kernel's first-occurrence unique
    /// order; large inputs use the sorted kernel (unique ascending).
    /// Both contracts agree on `inverse` semantics and round-trip via
    /// [`reconstruct`](Self::reconstruct); no consumer depends on the
    /// unique *order* (embeddings scatter back through `inverse`).
    pub fn of(ids: &[GlobalId]) -> Dedup {
        Dedup::of_auto(ids, None)
    }

    /// [`Dedup::of`] with an optional worker pool: the sorted kernel
    /// sorts chunks in parallel and k-way merges. Output is identical
    /// for every pool size (ties between equal ids cannot affect
    /// `unique` or `inverse`).
    pub fn of_auto(ids: &[GlobalId], pool: Option<&WorkerPool>) -> Dedup {
        match Dedup::kernel_for(ids.len()) {
            DedupKernel::Hash => Dedup::of_hash(ids),
            DedupKernel::Sort => Dedup::of_sorted_with(ids, pool),
        }
    }

    /// Hash-kernel deduplication preserving first-occurrence order.
    pub fn of_hash(ids: &[GlobalId]) -> Dedup {
        let mut map: IdMap<u32> =
            IdMap::with_capacity_and_hasher(ids.len(), Default::default());
        let mut unique = Vec::new();
        let mut inverse = Vec::with_capacity(ids.len());
        for &id in ids {
            let next = unique.len() as u32;
            let idx = *map.entry(id).or_insert_with(|| {
                unique.push(id);
                next
            });
            inverse.push(idx);
        }
        Dedup { unique, inverse }
    }

    /// Sort-based deduplication (unique list is sorted ascending).
    pub fn of_sorted(ids: &[GlobalId]) -> Dedup {
        Dedup::of_sorted_with(ids, None)
    }

    /// Sorted kernel with optional parallel chunk sort + k-way merge.
    pub fn of_sorted_with(ids: &[GlobalId], pool: Option<&WorkerPool>) -> Dedup {
        let n = ids.len();
        let mut order: Vec<u32> = (0..n as u32).collect();
        match pool {
            Some(p) if p.threads() > 1 && n >= dedup_sort_threshold() => {
                // Cap the run count: the merge's linear head scan costs
                // O(n·runs), so unbounded pool sizes would erase the
                // parallel-sort win. The SAME `ranges` drive both the
                // pool split (passed explicitly, cannot drift) and the
                // merge boundaries.
                let runs = p.threads().min(MERGE_MAX_RUNS);
                let ranges = WorkerPool::chunk_ranges(n, runs);
                p.parallel_for_ranges_mut(&mut order, 1, &ranges, |_r, chunk| {
                    chunk.sort_unstable_by_key(|&i| ids[i as usize]);
                });
                order = merge_sorted_runs(ids, &order, &ranges);
            }
            _ => order.sort_unstable_by_key(|&i| ids[i as usize]),
        }
        let mut unique = Vec::new();
        let mut inverse = vec![0u32; n];
        let mut prev: Option<GlobalId> = None;
        for &pos in &order {
            let id = ids[pos as usize];
            if prev != Some(id) {
                unique.push(id);
                prev = Some(id);
            }
            inverse[pos as usize] = (unique.len() - 1) as u32;
        }
        Dedup { unique, inverse }
    }

    pub fn num_duplicates(&self) -> usize {
        self.inverse.len() - self.unique.len()
    }

    /// Fraction of the original list that was redundant.
    pub fn dup_ratio(&self) -> f64 {
        if self.inverse.is_empty() {
            0.0
        } else {
            self.num_duplicates() as f64 / self.inverse.len() as f64
        }
    }

    /// Reconstruct the original list (round-trip check/debugging).
    pub fn reconstruct(&self) -> Vec<GlobalId> {
        self.inverse
            .iter()
            .map(|&i| self.unique[i as usize])
            .collect()
    }
}

/// Merge `k` sorted runs of `order` (run `r` = `order[ranges[r]]`,
/// each already sorted by id) into one id-sorted permutation. Tie order
/// between equal ids is irrelevant to every consumer (run-length unique
/// and per-position inverse are tie-invariant), so the merged result is
/// interchangeable with a monolithic sort.
fn merge_sorted_runs(
    ids: &[GlobalId],
    order: &[u32],
    ranges: &[std::ops::Range<usize>],
) -> Vec<u32> {
    let mut heads: Vec<usize> = ranges.iter().map(|r| r.start).collect();
    let mut out = Vec::with_capacity(order.len());
    loop {
        let mut best: Option<(GlobalId, usize)> = None;
        for (k, r) in ranges.iter().enumerate() {
            if heads[k] < r.end {
                let id = ids[order[heads[k]] as usize];
                let better = match best {
                    None => true,
                    Some((b, _)) => id < b,
                };
                if better {
                    best = Some((id, k));
                }
            }
        }
        match best {
            Some((_, k)) => {
                out.push(order[heads[k]]);
                heads[k] += 1;
            }
            None => break,
        }
    }
    out
}

/// Maximum sorted runs for the parallel dedup sort: the k-way merge
/// scans every run head per output element, so runs stay bounded even
/// on machine-sized pools.
const MERGE_MAX_RUNS: usize = 8;

/// Default row count above which the parallel gather/scatter kernels
/// split across the pool (below it, fork/join overhead dominates). The
/// live value is [`PAR_ROWS`] (env `MTGR_PAR_ROWS_THRESHOLD`).
pub const PAR_ROWS_THRESHOLD: usize = crate::util::tuning::calibrated::PAR_ROWS;

/// Runtime knob for the serial→parallel gather/scatter switch.
pub static PAR_ROWS: TunableThreshold =
    TunableThreshold::new("MTGR_PAR_ROWS_THRESHOLD", PAR_ROWS_THRESHOLD);

/// Live gather/scatter parallel switch point.
pub fn par_rows_threshold() -> usize {
    PAR_ROWS.get()
}

/// Width of the straight-line inner blocks the gather/scatter/Adam
/// kernels unroll to (8 f32 lanes = one AVX2 register / two NEON
/// registers). Blocking only regroups independent per-element ops, so
/// every blocked kernel stays bit-identical to its scalar reference.
pub const SIMD_BLOCK: usize = 8;

/// `dst[k] += src[k]` split into [`SIMD_BLOCK`]-wide exact chunks (the
/// array conversion pins the length so the autovectorizer emits
/// straight vector adds) plus a scalar tail for non-multiple lengths.
/// Element order and pairing are unchanged — bit-identical to the naive
/// zip loop.
#[inline]
pub fn add_assign_blocked(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let mut dc = dst.chunks_exact_mut(SIMD_BLOCK);
    let mut sc = src.chunks_exact(SIMD_BLOCK);
    for (db, sb) in (&mut dc).zip(&mut sc) {
        let db: &mut [f32; SIMD_BLOCK] = db.try_into().unwrap();
        let sb: &[f32; SIMD_BLOCK] = sb.try_into().unwrap();
        for (d, s) in db.iter_mut().zip(sb) {
            *d += *s;
        }
    }
    for (d, s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *d += *s;
    }
}

/// Fixed-width gather body: monomorphized `[f32; D]` row moves compile
/// to straight vector loads/stores (no per-row length dispatch) for the
/// power-of-two dims the schema presets use.
#[inline]
fn gather_rows_fixed<const D: usize>(rows: &[f32], inverse: &[u32], out: &mut [f32]) {
    let n_unique = rows.len() / D;
    for (dst, &u) in out.chunks_exact_mut(D).zip(inverse) {
        debug_assert!(
            (u as usize) < n_unique,
            "inverse index {u} out of bounds ({n_unique} unique rows)"
        );
        let src: &[f32; D] = rows[u as usize * D..(u as usize + 1) * D]
            .try_into()
            .unwrap();
        let dst: &mut [f32; D] = dst.try_into().unwrap();
        *dst = *src;
    }
}

/// Expand unique embedding rows back to occurrence order:
/// `out[i] = rows[inverse[i]]`. (The forward scatter after lookup.)
/// Common power-of-two dims dispatch to a monomorphized fixed-width
/// copy; other dims keep the generic `copy_from_slice` row moves.
/// `inverse` bounds are debug-asserted against the unique-row count.
pub fn gather_rows(rows: &[f32], dim: usize, inverse: &[u32], out: &mut [f32]) {
    assert!(dim > 0, "gather_rows requires dim > 0");
    assert_eq!(out.len(), inverse.len() * dim);
    assert_eq!(rows.len() % dim, 0);
    match dim {
        8 => return gather_rows_fixed::<8>(rows, inverse, out),
        16 => return gather_rows_fixed::<16>(rows, inverse, out),
        32 => return gather_rows_fixed::<32>(rows, inverse, out),
        64 => return gather_rows_fixed::<64>(rows, inverse, out),
        _ => {}
    }
    let n_unique = rows.len() / dim;
    for (dst, &u) in out.chunks_exact_mut(dim).zip(inverse) {
        debug_assert!(
            (u as usize) < n_unique,
            "inverse index {u} out of bounds ({n_unique} unique rows)"
        );
        dst.copy_from_slice(&rows[u as usize * dim..(u as usize + 1) * dim]);
    }
}

/// [`gather_rows`] parallelized over occurrence chunks (disjoint output
/// slices; bit-identical to the serial kernel for any pool size).
pub fn gather_rows_par(
    rows: &[f32],
    dim: usize,
    inverse: &[u32],
    out: &mut [f32],
    pool: Option<&WorkerPool>,
) {
    match pool {
        Some(p) if p.threads() > 1 && inverse.len() >= par_rows_threshold() => {
            assert_eq!(out.len(), inverse.len() * dim);
            p.parallel_for_chunks_mut(out, inverse.len(), dim, |r, chunk| {
                gather_rows(rows, dim, &inverse[r], chunk);
            });
        }
        _ => gather_rows(rows, dim, inverse, out),
    }
}

/// Accumulate occurrence-order gradients into unique rows:
/// `out[inverse[i]] += grads[i]`. (The backward counterpart: duplicate
/// occurrences of an ID sum their gradients — §5.2 sparse accumulation.)
/// Row additions go through the blocked kernel
/// ([`add_assign_blocked`]); per-row accumulation order is the
/// occurrence order, same as ever, so results are bit-identical to the
/// historical scalar loop.
pub fn scatter_accumulate(grads: &[f32], dim: usize, inverse: &[u32], out: &mut [f32]) {
    assert!(dim > 0, "scatter_accumulate requires dim > 0");
    assert_eq!(grads.len(), inverse.len() * dim);
    assert_eq!(out.len() % dim, 0);
    let n_unique = out.len() / dim;
    for (g, &u) in grads.chunks_exact(dim).zip(inverse) {
        debug_assert!(
            (u as usize) < n_unique,
            "inverse index {u} out of bounds ({n_unique} unique rows)"
        );
        let dst = &mut out[u as usize * dim..(u as usize + 1) * dim];
        add_assign_blocked(dst, g);
    }
}

/// [`scatter_accumulate`] parallelized over *unique-row* chunks.
///
/// Occurrences are first counting-sorted into per-row lists that
/// preserve occurrence order, so each row accumulates its gradients in
/// exactly the serial order — the result is **bit-identical** to
/// [`scatter_accumulate`] for every pool size (rows are independent
/// accumulators; only the per-row addition order could matter, and it
/// is unchanged).
pub fn scatter_accumulate_par(
    grads: &[f32],
    dim: usize,
    inverse: &[u32],
    out: &mut [f32],
    pool: Option<&WorkerPool>,
) {
    let n_unique = if dim == 0 { 0 } else { out.len() / dim };
    let parallel = matches!(pool, Some(p) if p.threads() > 1)
        && inverse.len() >= par_rows_threshold()
        && n_unique >= 2;
    if !parallel {
        scatter_accumulate(grads, dim, inverse, out);
        return;
    }
    let p = pool.unwrap();
    assert_eq!(grads.len(), inverse.len() * dim);
    assert_eq!(out.len(), n_unique * dim);
    // Counting sort: occ_by_row[starts[u]..starts[u+1]] lists the
    // occurrence indices of unique row u in increasing occurrence order.
    let mut starts = vec![0u32; n_unique + 1];
    for &u in inverse {
        starts[u as usize + 1] += 1;
    }
    for i in 0..n_unique {
        starts[i + 1] += starts[i];
    }
    let mut occ_by_row = vec![0u32; inverse.len()];
    let mut cursor = starts.clone();
    for (i, &u) in inverse.iter().enumerate() {
        let c = &mut cursor[u as usize];
        occ_by_row[*c as usize] = i as u32;
        *c += 1;
    }
    p.parallel_for_chunks_mut(out, n_unique, dim, |rows, chunk| {
        for (j, u) in rows.enumerate() {
            let dst = &mut chunk[j * dim..(j + 1) * dim];
            for &occ in &occ_by_row[starts[u] as usize..starts[u + 1] as usize] {
                let g = &grads[occ as usize * dim..(occ as usize + 1) * dim];
                add_assign_blocked(dst, g);
            }
        }
    });
}

/// Communication-volume accounting for one lookup round — drives the
/// Figure 16 reproduction. All byte counts assume f32 embeddings.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DedupVolume {
    /// IDs sent before / after stage-1 dedup.
    pub ids_raw: usize,
    pub ids_sent: usize,
    /// Embedding rows returned before / after stage-1 dedup (peers answer
    /// once per received ID).
    pub emb_rows_raw: usize,
    pub emb_rows_sent: usize,
    /// Table lookups before / after stage-2 dedup.
    pub lookups_raw: usize,
    pub lookups_done: usize,
}

impl DedupVolume {
    /// Accumulate another volume (field-wise sum) — used to fold
    /// per-group and per-worker volumes into aggregates.
    pub fn merge(&mut self, other: &DedupVolume) {
        self.ids_raw += other.ids_raw;
        self.ids_sent += other.ids_sent;
        self.emb_rows_raw += other.emb_rows_raw;
        self.emb_rows_sent += other.emb_rows_sent;
        self.lookups_raw += other.lookups_raw;
        self.lookups_done += other.lookups_done;
    }

    pub fn id_bytes_saved(&self) -> usize {
        (self.ids_raw - self.ids_sent) * 8
    }

    pub fn emb_bytes_saved(&self, dim: usize) -> usize {
        (self.emb_rows_raw - self.emb_rows_sent) * dim * 4
    }
}

/// Deduplication strategy toggles for the Figure 16 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DedupStrategy {
    /// (a) no deduplication at all.
    None,
    /// (b) stage-1 only: dedup before the ID all-to-all.
    CommUnique,
    /// (c) stage-2 only: dedup received IDs before table lookup.
    LookupUnique,
    /// (d) both stages (the MTGRBoost default).
    TwoStage,
}

impl DedupStrategy {
    pub fn stage1(&self) -> bool {
        matches!(self, DedupStrategy::CommUnique | DedupStrategy::TwoStage)
    }

    pub fn stage2(&self) -> bool {
        matches!(self, DedupStrategy::LookupUnique | DedupStrategy::TwoStage)
    }

    pub fn label(&self) -> &'static str {
        match self {
            DedupStrategy::None => "w/o unique",
            DedupStrategy::CommUnique => "Comm. unique",
            DedupStrategy::LookupUnique => "Lookup unique",
            DedupStrategy::TwoStage => "Two-stage unique",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Xoshiro256, Zipf};

    #[test]
    fn dedup_basic_and_roundtrip() {
        let ids = vec![5, 3, 5, 5, 9, 3];
        let d = Dedup::of(&ids);
        assert_eq!(d.unique, vec![5, 3, 9]);
        assert_eq!(d.inverse, vec![0, 1, 0, 0, 2, 1]);
        assert_eq!(d.num_duplicates(), 3);
        assert_eq!(d.reconstruct(), ids);
    }

    #[test]
    fn sorted_variant_equivalent() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..50 {
            let n = rng.range_usize(0, 200);
            let ids: Vec<u64> = (0..n).map(|_| rng.gen_range(40)).collect();
            let a = Dedup::of(&ids);
            let b = Dedup::of_sorted(&ids);
            assert_eq!(a.reconstruct(), ids);
            assert_eq!(b.reconstruct(), ids);
            let mut ua = a.unique.clone();
            ua.sort_unstable();
            assert_eq!(ua, b.unique, "same unique set");
        }
    }

    #[test]
    fn empty_input() {
        let d = Dedup::of(&[]);
        assert!(d.unique.is_empty() && d.inverse.is_empty());
        assert_eq!(d.dup_ratio(), 0.0);
    }

    #[test]
    fn gather_scatter_are_adjoint() {
        // <gather(rows), grads> == <rows, scatter(grads)> — the defining
        // property that makes backward correct.
        let mut rng = Xoshiro256::new(9);
        let dim = 3;
        let ids: Vec<u64> = (0..40).map(|_| rng.gen_range(10)).collect();
        let d = Dedup::of(&ids);
        let rows: Vec<f32> = (0..d.unique.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let grads: Vec<f32> = (0..ids.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();

        let mut expanded = vec![0.0f32; ids.len() * dim];
        gather_rows(&rows, dim, &d.inverse, &mut expanded);
        let mut accum = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate(&grads, dim, &d.inverse, &mut accum);

        let lhs: f64 = expanded
            .iter()
            .zip(&grads)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let rhs: f64 = rows
            .iter()
            .zip(&accum)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        assert!((lhs - rhs).abs() < 1e-4, "adjoint mismatch {lhs} vs {rhs}");
    }

    #[test]
    fn gather_places_correct_rows() {
        let d = Dedup::of(&[7, 8, 7]);
        let rows = vec![1.0, 1.0, 2.0, 2.0]; // dim 2: row0 = [1,1], row1 = [2,2]
        let mut out = vec![0.0; 6];
        gather_rows(&rows, 2, &d.inverse, &mut out);
        assert_eq!(out, vec![1.0, 1.0, 2.0, 2.0, 1.0, 1.0]);
    }

    #[test]
    fn kernel_switches_at_threshold() {
        assert_eq!(Dedup::kernel_for(DEDUP_SORT_THRESHOLD - 1), DedupKernel::Hash);
        assert_eq!(Dedup::kernel_for(DEDUP_SORT_THRESHOLD), DedupKernel::Sort);
        // A large input goes through the sorted kernel: unique ascending.
        let ids: Vec<u64> = (0..DEDUP_SORT_THRESHOLD as u64).map(|i| i % 97).collect();
        let d = Dedup::of(&ids);
        assert!(d.unique.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert_eq!(d.unique.len(), 97);
        assert_eq!(d.reconstruct(), ids);
    }

    #[test]
    fn parallel_dedup_identical_for_every_pool_size() {
        let mut rng = Xoshiro256::new(77);
        let ids: Vec<u64> = (0..20_000).map(|_| rng.gen_range(512)).collect();
        let serial = Dedup::of_auto(&ids, None);
        assert_eq!(serial.reconstruct(), ids);
        for threads in [1, 2, 4] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            let par = Dedup::of_auto(&ids, Some(&pool));
            assert_eq!(par, serial, "{threads} threads");
        }
    }

    #[test]
    fn parallel_gather_scatter_bit_identical_to_serial() {
        let mut rng = Xoshiro256::new(5);
        let dim = 8;
        let ids: Vec<u64> = (0..6000).map(|_| rng.gen_range(700)).collect();
        let d = Dedup::of_hash(&ids);
        let rows: Vec<f32> = (0..d.unique.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let grads: Vec<f32> = (0..ids.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let mut out_serial = vec![0.0f32; ids.len() * dim];
        gather_rows(&rows, dim, &d.inverse, &mut out_serial);
        let mut acc_serial = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate(&grads, dim, &d.inverse, &mut acc_serial);
        for threads in [1, 2, 4] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            let mut out = vec![0.0f32; ids.len() * dim];
            gather_rows_par(&rows, dim, &d.inverse, &mut out, Some(&pool));
            assert_eq!(out, out_serial, "{threads} threads gather");
            let mut acc = vec![0.0f32; d.unique.len() * dim];
            scatter_accumulate_par(&grads, dim, &d.inverse, &mut acc, Some(&pool));
            assert_eq!(acc, acc_serial, "{threads} threads scatter");
        }
    }

    #[test]
    fn blocked_kernels_match_naive_for_odd_shapes() {
        // Odd dims, non-block-multiple dims and the fixed-dim
        // specializations (8/16/32/64) must all reproduce the naive
        // scalar loops bit for bit.
        let mut rng = Xoshiro256::new(21);
        for &dim in &[1usize, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65] {
            let ids: Vec<u64> = (0..57).map(|_| rng.gen_range(13)).collect();
            let d = Dedup::of(&ids);
            let rows: Vec<f32> = (0..d.unique.len() * dim)
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let grads: Vec<f32> = (0..ids.len() * dim)
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let mut exp_ref = vec![0.0f32; ids.len() * dim];
            for (i, &u) in d.inverse.iter().enumerate() {
                exp_ref[i * dim..(i + 1) * dim]
                    .copy_from_slice(&rows[u as usize * dim..(u as usize + 1) * dim]);
            }
            let mut acc_ref = vec![0.0f32; d.unique.len() * dim];
            for (i, &u) in d.inverse.iter().enumerate() {
                for (j, &g) in grads[i * dim..(i + 1) * dim].iter().enumerate() {
                    acc_ref[u as usize * dim + j] += g;
                }
            }
            let mut exp = vec![0.0f32; ids.len() * dim];
            gather_rows(&rows, dim, &d.inverse, &mut exp);
            assert_eq!(exp, exp_ref, "dim {dim} gather");
            let mut acc = vec![0.0f32; d.unique.len() * dim];
            scatter_accumulate(&grads, dim, &d.inverse, &mut acc);
            assert_eq!(acc, acc_ref, "dim {dim} scatter");
        }
        // Empty inverse map: both kernels are no-ops on empty outputs.
        let mut empty_out: Vec<f32> = Vec::new();
        gather_rows(&[1.0; 8], 8, &[], &mut empty_out);
        assert!(empty_out.is_empty());
        let mut acc = vec![3.0f32; 8];
        scatter_accumulate(&[], 8, &[], &mut acc);
        assert_eq!(acc, vec![3.0f32; 8], "no grads → rows untouched");
    }

    #[test]
    fn zipf_batches_have_high_dup_ratio() {
        // The premise of §4.3: realistic skewed batches are highly
        // redundant, so dedup saves most of the embedding traffic.
        let z = Zipf::new(100_000, 1.2);
        let mut rng = Xoshiro256::new(3);
        let ids: Vec<u64> = (0..50_000).map(|_| z.sample(&mut rng) as u64).collect();
        let d = Dedup::of(&ids);
        assert!(
            d.dup_ratio() > 0.5,
            "expected >50% duplicates, got {:.2}",
            d.dup_ratio()
        );
    }

    #[test]
    fn volume_accounting() {
        let v = DedupVolume {
            ids_raw: 1000,
            ids_sent: 400,
            emb_rows_raw: 1000,
            emb_rows_sent: 400,
            lookups_raw: 400,
            lookups_done: 300,
        };
        assert_eq!(v.id_bytes_saved(), 600 * 8);
        assert_eq!(v.emb_bytes_saved(64), 600 * 64 * 4);
    }

    #[test]
    fn strategy_stage_flags() {
        assert!(!DedupStrategy::None.stage1() && !DedupStrategy::None.stage2());
        assert!(DedupStrategy::CommUnique.stage1() && !DedupStrategy::CommUnique.stage2());
        assert!(!DedupStrategy::LookupUnique.stage1() && DedupStrategy::LookupUnique.stage2());
        assert!(DedupStrategy::TwoStage.stage1() && DedupStrategy::TwoStage.stage2());
    }
}
