//! Two-stage ID deduplication (§4.3).
//!
//! A sequence batch contains many duplicate feature IDs (Zipf-skewed item
//! popularity plus repeated in-sequence items). Each sharded lookup does
//! two all-to-alls — ID exchange then embedding exchange — and duplicates
//! inflate both, with embedding payloads (dim × 4 bytes per occurrence)
//! dominating.
//!
//! - **Stage 1** (before the ID all-to-all): each device deduplicates the
//!   IDs it is about to send *per destination shard*, so peers receive —
//!   and later return embeddings for — each ID at most once per source.
//! - **Stage 2** (after the ID all-to-all): the IDs a device received
//!   from its peers still overlap across sources; deduplicate the union
//!   before touching the hash table so each row is fetched once.
//!
//! This module provides the dedup kernel (with an inverse index so
//! embeddings can be scattered back to occurrence order), the gradient
//! counterpart (duplicate occurrences' gradients *accumulate* into the
//! unique row — also the sparse-gradient-accumulation primitive of §5.2),
//! and volume accounting used by the Figure 16 experiment.

use crate::embedding::hash::fmix64;
use crate::embedding::GlobalId;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Single-shot fmix64 hasher for u64 keys — bypasses SipHash on the
/// dedup hot path (§Perf: ~1.7x faster deduplication; IDs are already
/// well-mixed by Eq. 8 packing so DoS-resistance is irrelevant here).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Only u64 keys are ever hashed; fall back defensively.
        let mut buf = [0u8; 8];
        buf[..bytes.len().min(8)].copy_from_slice(&bytes[..bytes.len().min(8)]);
        self.0 = fmix64(u64::from_le_bytes(buf));
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.0 = fmix64(x);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

/// `HashMap` keyed by ids with the fast hasher.
pub type IdMap<V> = HashMap<GlobalId, V, BuildHasherDefault<IdHasher>>;

/// Result of deduplicating an ID list: the unique IDs plus, for every
/// original position, the index of its unique representative.
#[derive(Clone, Debug, PartialEq)]
pub struct Dedup {
    pub unique: Vec<GlobalId>,
    pub inverse: Vec<u32>,
}

impl Dedup {
    /// Deduplicate preserving first-occurrence order (hash-based).
    pub fn of(ids: &[GlobalId]) -> Dedup {
        let mut map: IdMap<u32> =
            IdMap::with_capacity_and_hasher(ids.len(), Default::default());
        let mut unique = Vec::new();
        let mut inverse = Vec::with_capacity(ids.len());
        for &id in ids {
            let next = unique.len() as u32;
            let idx = *map.entry(id).or_insert_with(|| {
                unique.push(id);
                next
            });
            inverse.push(idx);
        }
        Dedup { unique, inverse }
    }

    /// Sort-based deduplication (unique list is sorted ascending).
    /// Kept as an alternative kernel for the perf pass; same contract.
    pub fn of_sorted(ids: &[GlobalId]) -> Dedup {
        let mut order: Vec<u32> = (0..ids.len() as u32).collect();
        order.sort_unstable_by_key(|&i| ids[i as usize]);
        let mut unique = Vec::new();
        let mut inverse = vec![0u32; ids.len()];
        let mut prev: Option<GlobalId> = None;
        for &pos in &order {
            let id = ids[pos as usize];
            if prev != Some(id) {
                unique.push(id);
                prev = Some(id);
            }
            inverse[pos as usize] = (unique.len() - 1) as u32;
        }
        Dedup { unique, inverse }
    }

    pub fn num_duplicates(&self) -> usize {
        self.inverse.len() - self.unique.len()
    }

    /// Fraction of the original list that was redundant.
    pub fn dup_ratio(&self) -> f64 {
        if self.inverse.is_empty() {
            0.0
        } else {
            self.num_duplicates() as f64 / self.inverse.len() as f64
        }
    }

    /// Reconstruct the original list (round-trip check/debugging).
    pub fn reconstruct(&self) -> Vec<GlobalId> {
        self.inverse
            .iter()
            .map(|&i| self.unique[i as usize])
            .collect()
    }
}

/// Expand unique embedding rows back to occurrence order:
/// `out[i] = rows[inverse[i]]`. (The forward scatter after lookup.)
pub fn gather_rows(rows: &[f32], dim: usize, inverse: &[u32], out: &mut [f32]) {
    assert_eq!(out.len(), inverse.len() * dim);
    assert_eq!(rows.len() % dim, 0);
    for (i, &u) in inverse.iter().enumerate() {
        let src = &rows[u as usize * dim..(u as usize + 1) * dim];
        out[i * dim..(i + 1) * dim].copy_from_slice(src);
    }
}

/// Accumulate occurrence-order gradients into unique rows:
/// `out[inverse[i]] += grads[i]`. (The backward counterpart: duplicate
/// occurrences of an ID sum their gradients — §5.2 sparse accumulation.)
pub fn scatter_accumulate(grads: &[f32], dim: usize, inverse: &[u32], out: &mut [f32]) {
    assert_eq!(grads.len(), inverse.len() * dim);
    assert_eq!(out.len() % dim, 0);
    for (i, &u) in inverse.iter().enumerate() {
        let dst = u as usize * dim;
        for d in 0..dim {
            out[dst + d] += grads[i * dim + d];
        }
    }
}

/// Communication-volume accounting for one lookup round — drives the
/// Figure 16 reproduction. All byte counts assume f32 embeddings.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DedupVolume {
    /// IDs sent before / after stage-1 dedup.
    pub ids_raw: usize,
    pub ids_sent: usize,
    /// Embedding rows returned before / after stage-1 dedup (peers answer
    /// once per received ID).
    pub emb_rows_raw: usize,
    pub emb_rows_sent: usize,
    /// Table lookups before / after stage-2 dedup.
    pub lookups_raw: usize,
    pub lookups_done: usize,
}

impl DedupVolume {
    pub fn id_bytes_saved(&self) -> usize {
        (self.ids_raw - self.ids_sent) * 8
    }

    pub fn emb_bytes_saved(&self, dim: usize) -> usize {
        (self.emb_rows_raw - self.emb_rows_sent) * dim * 4
    }
}

/// Deduplication strategy toggles for the Figure 16 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DedupStrategy {
    /// (a) no deduplication at all.
    None,
    /// (b) stage-1 only: dedup before the ID all-to-all.
    CommUnique,
    /// (c) stage-2 only: dedup received IDs before table lookup.
    LookupUnique,
    /// (d) both stages (the MTGRBoost default).
    TwoStage,
}

impl DedupStrategy {
    pub fn stage1(&self) -> bool {
        matches!(self, DedupStrategy::CommUnique | DedupStrategy::TwoStage)
    }

    pub fn stage2(&self) -> bool {
        matches!(self, DedupStrategy::LookupUnique | DedupStrategy::TwoStage)
    }

    pub fn label(&self) -> &'static str {
        match self {
            DedupStrategy::None => "w/o unique",
            DedupStrategy::CommUnique => "Comm. unique",
            DedupStrategy::LookupUnique => "Lookup unique",
            DedupStrategy::TwoStage => "Two-stage unique",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Xoshiro256, Zipf};

    #[test]
    fn dedup_basic_and_roundtrip() {
        let ids = vec![5, 3, 5, 5, 9, 3];
        let d = Dedup::of(&ids);
        assert_eq!(d.unique, vec![5, 3, 9]);
        assert_eq!(d.inverse, vec![0, 1, 0, 0, 2, 1]);
        assert_eq!(d.num_duplicates(), 3);
        assert_eq!(d.reconstruct(), ids);
    }

    #[test]
    fn sorted_variant_equivalent() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..50 {
            let n = rng.range_usize(0, 200);
            let ids: Vec<u64> = (0..n).map(|_| rng.gen_range(40)).collect();
            let a = Dedup::of(&ids);
            let b = Dedup::of_sorted(&ids);
            assert_eq!(a.reconstruct(), ids);
            assert_eq!(b.reconstruct(), ids);
            let mut ua = a.unique.clone();
            ua.sort_unstable();
            assert_eq!(ua, b.unique, "same unique set");
        }
    }

    #[test]
    fn empty_input() {
        let d = Dedup::of(&[]);
        assert!(d.unique.is_empty() && d.inverse.is_empty());
        assert_eq!(d.dup_ratio(), 0.0);
    }

    #[test]
    fn gather_scatter_are_adjoint() {
        // <gather(rows), grads> == <rows, scatter(grads)> — the defining
        // property that makes backward correct.
        let mut rng = Xoshiro256::new(9);
        let dim = 3;
        let ids: Vec<u64> = (0..40).map(|_| rng.gen_range(10)).collect();
        let d = Dedup::of(&ids);
        let rows: Vec<f32> = (0..d.unique.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let grads: Vec<f32> = (0..ids.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();

        let mut expanded = vec![0.0f32; ids.len() * dim];
        gather_rows(&rows, dim, &d.inverse, &mut expanded);
        let mut accum = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate(&grads, dim, &d.inverse, &mut accum);

        let lhs: f64 = expanded
            .iter()
            .zip(&grads)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let rhs: f64 = rows
            .iter()
            .zip(&accum)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        assert!((lhs - rhs).abs() < 1e-4, "adjoint mismatch {lhs} vs {rhs}");
    }

    #[test]
    fn gather_places_correct_rows() {
        let d = Dedup::of(&[7, 8, 7]);
        let rows = vec![1.0, 1.0, 2.0, 2.0]; // dim 2: row0 = [1,1], row1 = [2,2]
        let mut out = vec![0.0; 6];
        gather_rows(&rows, 2, &d.inverse, &mut out);
        assert_eq!(out, vec![1.0, 1.0, 2.0, 2.0, 1.0, 1.0]);
    }

    #[test]
    fn zipf_batches_have_high_dup_ratio() {
        // The premise of §4.3: realistic skewed batches are highly
        // redundant, so dedup saves most of the embedding traffic.
        let z = Zipf::new(100_000, 1.2);
        let mut rng = Xoshiro256::new(3);
        let ids: Vec<u64> = (0..50_000).map(|_| z.sample(&mut rng) as u64).collect();
        let d = Dedup::of(&ids);
        assert!(
            d.dup_ratio() > 0.5,
            "expected >50% duplicates, got {:.2}",
            d.dup_ratio()
        );
    }

    #[test]
    fn volume_accounting() {
        let v = DedupVolume {
            ids_raw: 1000,
            ids_sent: 400,
            emb_rows_raw: 1000,
            emb_rows_sent: 400,
            lookups_raw: 400,
            lookups_done: 300,
        };
        assert_eq!(v.id_bytes_saved(), 600 * 8);
        assert_eq!(v.emb_bytes_saved(64), 600 * 64 * 4);
    }

    #[test]
    fn strategy_stage_flags() {
        assert!(!DedupStrategy::None.stage1() && !DedupStrategy::None.stage2());
        assert!(DedupStrategy::CommUnique.stage1() && !DedupStrategy::CommUnique.stage2());
        assert!(!DedupStrategy::LookupUnique.stage1() && DedupStrategy::LookupUnique.stage2());
        assert!(DedupStrategy::TwoStage.stage1() && DedupStrategy::TwoStage.stage2());
    }
}
