//! Managed Collision Handling (MCH) — TorchRec's mechanism for
//! changeable feature IDs, reproduced as the Table 3 baseline.
//!
//! As described in §6.3: MCH "maintains a fixed-size mapping table to
//! remap original IDs into a continuous space. It employs binary search
//! for efficient ID localization and activates an eviction mechanism to
//! update ID mappings when a threshold is reached."
//!
//! Costs reproduced faithfully (they drive the Table 3 result):
//! - the remap table is **sorted** and searched with **binary search**
//!   (O(log n) per lookup, plus O(n) insertion shifting — this is why the
//!   paper's hash table wins 1.47×–2.22×);
//! - the embedding storage for the remapped continuous space is
//!   **pre-allocated at full capacity** (this is why MCH OOMs at
//!   110G-64D in Table 3 while the dynamic table does not).

use crate::embedding::hash::hash_id;
use crate::embedding::{EmbeddingStore, GlobalId};
use crate::util::rng::Xoshiro256;

/// One entry in the sorted remap table.
#[derive(Clone, Copy, Debug)]
struct MchEntry {
    original_id: u64,
    /// Slot in the pre-allocated embedding array.
    slot: u32,
    /// Access counter driving eviction.
    count: u32,
    last_access: u64,
}

/// TorchRec-style Managed Collision Handling store.
pub struct MchTable {
    dim: usize,
    capacity: usize,
    /// Sorted by `original_id` for binary search.
    entries: Vec<MchEntry>,
    /// Pre-allocated embedding storage for the continuous space.
    values: Vec<f32>,
    free_slots: Vec<u32>,
    /// Eviction triggers when occupancy reaches this fraction.
    evict_threshold: f64,
    /// Fraction of coldest entries dropped per eviction pass.
    evict_fraction: f64,
    default_row: Vec<f32>,
    seed: u64,
    clock: u64,
    pub evictions: u64,
}

impl MchTable {
    pub fn new(dim: usize, capacity: usize, seed: u64) -> Self {
        assert!(dim > 0 && capacity > 0);
        MchTable {
            dim,
            capacity,
            entries: Vec::new(),
            values: vec![0.0; capacity * dim], // full pre-allocation
            free_slots: (0..capacity as u32).rev().collect(),
            evict_threshold: 0.95,
            evict_fraction: 0.2,
            default_row: vec![0.0; dim],
            seed,
            clock: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Binary-search localization of an original ID (the paper's stated
    /// MCH lookup mechanism).
    fn find(&self, id: u64) -> Result<usize, usize> {
        self.entries.binary_search_by(|e| e.original_id.cmp(&id))
    }

    fn init_row(&self, id: u64, out: &mut [f32]) {
        let mut rng = Xoshiro256::new(hash_id(id, self.seed ^ 0xD1CE));
        let scale = 1.0 / (self.dim as f32).sqrt();
        for v in out.iter_mut() {
            *v = rng.gauss() as f32 * scale;
        }
    }

    /// Evict the coldest `evict_fraction` of entries (threshold pass).
    fn evict_pass(&mut self) {
        let n_drop = ((self.entries.len() as f64 * self.evict_fraction) as usize).max(1);
        // Rank by (count, last_access): least frequent, then least recent.
        let mut order: Vec<usize> = (0..self.entries.len()).collect();
        order.sort_by_key(|&i| (self.entries[i].count, self.entries[i].last_access));
        let mut drop: Vec<usize> = order.into_iter().take(n_drop).collect();
        drop.sort_unstable_by(|a, b| b.cmp(a)); // remove back-to-front
        for i in drop {
            let e = self.entries.remove(i);
            self.free_slots.push(e.slot);
            self.evictions += 1;
        }
    }
}

impl EmbeddingStore for MchTable {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn lookup_or_insert(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        assert_eq!(out.len(), self.dim);
        self.clock += 1;
        match self.find(id) {
            Ok(i) => {
                self.entries[i].count += 1;
                self.entries[i].last_access = self.clock;
                let slot = self.entries[i].slot as usize;
                out.copy_from_slice(&self.values[slot * self.dim..(slot + 1) * self.dim]);
                true
            }
            Err(i) => {
                // Threshold-triggered eviction to make room.
                if self.entries.len() as f64 >= self.capacity as f64 * self.evict_threshold
                {
                    self.evict_pass();
                }
                let slot = match self.free_slots.pop() {
                    Some(s) => s,
                    None => {
                        // Fully saturated even after eviction: default row.
                        out.copy_from_slice(&self.default_row);
                        return false;
                    }
                };
                // O(n) shifting insert to keep the table sorted — the cost
                // profile the paper's hash table avoids. Re-locate in case
                // the eviction pass shifted indices.
                let _ = i;
                let i = self.find(id).unwrap_err();
                self.entries.insert(
                    i,
                    MchEntry {
                        original_id: id,
                        slot,
                        count: 1,
                        last_access: self.clock,
                    },
                );
                let mut init = vec![0.0f32; self.dim];
                self.init_row(id, &mut init);
                let s = slot as usize;
                self.values[s * self.dim..(s + 1) * self.dim].copy_from_slice(&init);
                out.copy_from_slice(&init);
                false
            }
        }
    }

    fn lookup(&self, id: GlobalId, out: &mut [f32]) -> bool {
        assert_eq!(out.len(), self.dim);
        match self.find(id) {
            Ok(i) => {
                let slot = self.entries[i].slot as usize;
                out.copy_from_slice(&self.values[slot * self.dim..(slot + 1) * self.dim]);
                true
            }
            Err(_) => {
                out.copy_from_slice(&self.default_row);
                false
            }
        }
    }

    fn apply_delta(&mut self, id: GlobalId, delta: &[f32]) -> bool {
        assert_eq!(delta.len(), self.dim);
        match self.find(id) {
            Ok(i) => {
                let slot = self.entries[i].slot as usize;
                for (v, d) in self.values[slot * self.dim..(slot + 1) * self.dim]
                    .iter_mut()
                    .zip(delta)
                {
                    *v += d;
                }
                true
            }
            Err(_) => false,
        }
    }

    /// Full pre-allocated footprint (the Table 3 OOM driver).
    fn memory_bytes(&self) -> usize {
        self.capacity * self.dim * std::mem::size_of::<f32>()
            + self.entries.capacity() * std::mem::size_of::<MchEntry>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remap_roundtrip() {
        let mut t = MchTable::new(4, 100, 9);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        // Arbitrary huge original IDs remap fine.
        assert!(!t.lookup_or_insert(u64::MAX / 3, &mut a));
        assert!(t.lookup_or_insert(u64::MAX / 3, &mut b));
        assert_eq!(a, b);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn entries_stay_sorted() {
        let mut t = MchTable::new(2, 50, 1);
        let mut r = vec![0.0; 2];
        let mut rng = Xoshiro256::new(4);
        for _ in 0..40 {
            t.lookup_or_insert(rng.next_u64(), &mut r);
        }
        for w in t.entries.windows(2) {
            assert!(w[0].original_id < w[1].original_id);
        }
    }

    #[test]
    fn eviction_triggers_at_threshold_and_keeps_hot() {
        let mut t = MchTable::new(2, 20, 1);
        let mut r = vec![0.0; 2];
        // Make id 5 hot.
        for _ in 0..50 {
            t.lookup_or_insert(5, &mut r);
        }
        for id in 100..200 {
            t.lookup_or_insert(id, &mut r);
        }
        assert!(t.evictions > 0);
        assert!(t.len() <= 20);
        assert!(t.lookup(5, &mut r), "hot id survives threshold eviction");
    }

    #[test]
    fn memory_preallocated_at_capacity() {
        let t0 = MchTable::new(64, 10_000, 1);
        assert!(t0.memory_bytes() >= 10_000 * 64 * 4);
    }

    #[test]
    fn apply_delta_and_default_fallback() {
        let mut t = MchTable::new(3, 10, 1);
        let mut r = vec![0.0; 3];
        t.lookup_or_insert(1, &mut r);
        assert!(t.apply_delta(1, &[0.5; 3]));
        assert!(!t.apply_delta(999, &[0.5; 3]));
        let mut out = vec![1.0; 3];
        assert!(!t.lookup(999, &mut out));
        assert_eq!(out, vec![0.0; 3]);
    }
}
