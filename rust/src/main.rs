//! MTGRBoost CLI — the leader entrypoint.
//!
//! ```text
//! mtgrboost train --model tiny --world 2 --steps 50 [--no-balancing]
//!                 [--dedup none|comm|lookup|two-stage] [--overlap on|off]
//!                 [--cross-step on|off] [--threads N] [--lr 0.001]
//!                 [--schema meituan|meituan-mixed|meituan-tiered]
//!                 [--no-merging] [--no-multiplex]
//!                 [--precision fp32|mixed] [--hot-threshold N]
//!                 [--scenario skew-storm|churn-storm|multi-tenant|soak]
//! mtgrboost train --mode online --sync-interval 50 [--intervals N]
//!                 [--feature-ttl N] [--admit-threshold N] [--admit-prob P]
//!                 [--sync-dir DIR] [--day-every N] ...
//! mtgrboost train-dist --world 2 --mode online --sync-interval 5
//!                 --sync-dir DIR --intervals N [--run-dir DIR]
//!                 [--heartbeat-ms N] [--heartbeat-timeout-ms N]
//!                 [--max-recoveries N] [--fault PLAN] [--report-json F]
//!                 [--gauc on|off] [...train flags...]
//! mtgrboost sim   --model 4g --world 64 --dim-factor 1 --steps 50
//!                 [--no-balancing] [--dedup ...] [--overlap on|off]
//!                 [--cross-step on|off] [--backend hash|mch]
//! mtgrboost data  --out /tmp/shards --sequences 1000 --shards 4
//! mtgrboost serve --sync-dir DIR [--requests N] [--micro-batch N]
//!                 [--refresh-every N] [--compact-every N] [--group K]
//!                 [--qps F] [--users N] [--zipf-alpha F] [--burst F]
//!                 [--day-seconds F] [--ids-per-request N] [--miss-rate F]
//!                 [--cache-slots N] [--seed S] [--artifacts DIR]
//! mtgrboost info  [--artifacts artifacts]
//! ```
//!
//! `--mode online` turns the trainer into a continuously running online
//! learner: an endless day-advancing stream, feature admission in front
//! of sparse insertion, TTL expiry of stale rows, and an incremental
//! delta snapshot to `--sync-dir` every `--sync-interval` steps.
//! Contradictory combinations (`--steps` with online mode, zero
//! `--sync-interval`, TTL below the sync interval, online-only knobs in
//! offline mode) are rejected up front.
//!
//! `train-dist` runs the same online trainer as N real worker
//! *processes* over the Unix-domain-socket transport: the supervisor
//! owns a coordinator (registration, seeded shard assignment, interval
//! barrier, heartbeat failure detection) and recovers from any worker
//! death by gang restart from the newest CRC-durable delta under
//! `--sync-dir`. `--fault kill:rank=R,step=S` (also `drop:`/`delay:`/
//! `torn:`) injects deterministic failures for the recovery drills;
//! `--report-json` writes the merged bit-exact report. Every training
//! flag after the supervisor knobs is forwarded verbatim to the
//! workers. The hidden `dist-worker` subcommand is the per-rank process
//! body the supervisor spawns.
//!
//! `serve` is the consumer end of that sync path: it bootstraps a
//! read-optimized serving replica from the base + delta chain under
//! `--sync-dir` (gapped or torn chains are rejected, never served
//! stale), drives it with deterministic Zipf/diurnal traffic through
//! micro-batched lookup + dense-forward requests, optionally refreshes
//! and compacts while serving, and prints p50/p99 latency, achieved
//! QPS and cache hit rates.
//!
//! `--schema meituan-mixed` switches the trainer onto the
//! heterogeneous-dim feature schema (8D context features, model-dim
//! token features, an exposure-item `shared_table` alias): automatic
//! table merging folds it into one physical table per dim group and the
//! whole distributed path runs per group. `--no-merging` runs the
//! unmerged ablation in the real trainer — one physical table and one
//! exchange per logical table — so the fusion win of §4.2 is measured
//! in wall-clock seconds, not just sim op counts. `--no-multiplex`
//! posts one exchange per merge group instead of packing every group
//! into one message per comm lane (the multiplexed default; payload
//! bytes are identical either way, only message counts and header
//! bytes differ). Unknown preset names
//! and contradictory combos (`--schema` under `sim`) are rejected up
//! front; online knobs apply uniformly to every group.
//!
//! `--scenario <name>` trains under a named adversarial / long-run
//! workload preset: `skew-storm` (heavy-tailed sequence lengths that
//! stress the dynamic batcher), `churn-storm` (flash-sale ID churn with
//! admission day decay + re-admission hysteresis; requires `--mode
//! online`), `multi-tenant` (the three-tier `meituan-tiered` schema
//! with per-group row budgets; offline only) and `soak` (multi-day
//! bounded-memory soak; requires `--mode online`). A scenario only
//! reshapes the generator and tunes admission/TTL defaults — seeds and
//! the training hot path are untouched, so runs stay bit-identical
//! across `--threads`/`--overlap`/`--cross-step`. Scenario telemetry
//! (peak resident rows, evictions, batcher carry-over and fill) is
//! printed after training and included in `--report-json`. Unknown
//! names, mode mismatches, a conflicting `--schema`, and `--scenario`
//! under `sim` or `train-dist` are rejected up front.
//!
//! `--precision mixed` keeps hot embedding rows (post-bump access count
//! >= `--hot-threshold`, default 8) in FP32 and stores cold rows on the
//! binary16 grid (§5.2), compressing cold reply rows and cold gradient
//! pushes to packed FP16 on the wire with per-row precision tags. Runs
//! stay bit-identical across `--threads`/`--overlap`/`--cross-step`/
//! `--no-multiplex`; `fp32` (the default) is byte-identical to a build
//! without the policy. The hot/cold census, per-precision wire bytes
//! and quantization telemetry are printed after training and included
//! in `--report-json`; checkpoints and deltas record the per-group
//! policy so serving replicas and `train-dist` recovery round-trip cold
//! rows on the exact f16 grid.

use anyhow::{bail, Context, Result};

use mtgrboost::config::ModelConfig;
use mtgrboost::data::generator::{GeneratorConfig, WorkloadGenerator};
use mtgrboost::data::schema::Schema;
use mtgrboost::data::shards::write_sharded_dataset;
use mtgrboost::dist::{
    dist_report_to_json, report_to_json, run_dist, run_worker, DistOptions, FaultPlan,
    WorkerOptions,
};
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::embedding::precision::PrecisionMode;
use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::runtime::Engine;
use mtgrboost::scenario::Scenario;
use mtgrboost::serve::{run_serve, ServeOptions};
use mtgrboost::sim::{simulate, SimOptions, TableBackend};
use mtgrboost::train::{Trainer, TrainerOptions};
use mtgrboost::util::cli::Args;

fn parse_switch(flag: &str, s: &str) -> Result<bool> {
    Ok(match s {
        "on" => true,
        "off" => false,
        other => bail!("--{flag} expects on|off, got `{other}`"),
    })
}

fn parse_dedup(s: &str) -> Result<DedupStrategy> {
    Ok(match s {
        "none" => DedupStrategy::None,
        "comm" => DedupStrategy::CommUnique,
        "lookup" => DedupStrategy::LookupUnique,
        "two-stage" | "twostage" => DedupStrategy::TwoStage,
        other => bail!("unknown dedup strategy `{other}`"),
    })
}

/// Parse + validate `--schema`, rejecting unknown presets (mirrors the
/// `--mode` validation style: fail at the flag layer with flag-named
/// errors; `TrainerOptions::validate` re-checks the preset name).
fn parse_schema(args: &Args) -> Result<String> {
    let name = args.get_or("schema", "meituan");
    if !Schema::is_preset(&name) {
        bail!(
            "unknown --schema `{name}` (expected one of {:?})",
            Schema::preset_names()
        );
    }
    Ok(name)
}

/// Parse + validate `--scenario` at the flag layer (unknown presets,
/// mode mismatches, a conflicting explicit `--schema`) so the errors
/// name flags; `TrainerOptions::validate` re-checks all of it.
fn parse_scenario(args: &Args, online: bool) -> Result<Option<Scenario>> {
    let Some(name) = args.get("scenario") else {
        return Ok(None);
    };
    let sc = Scenario::by_name(name)?;
    sc.validate(online)?;
    if let Some(forced) = sc.schema_override {
        let schema = args.get_or("schema", forced);
        if schema != forced && schema != "meituan" {
            bail!(
                "--scenario {name} forces --schema {forced} (got --schema {schema}); \
                 drop --schema or pass the forced preset"
            );
        }
    }
    Ok(Some(sc))
}

/// Parse + validate `--precision` / `--hot-threshold` at the flag
/// layer (same discipline as [`parse_online_mode`]: contradictory
/// combinations fail with flag-named errors; `TrainerOptions::validate`
/// re-checks the threshold under mixed).
fn parse_precision(args: &Args) -> Result<(PrecisionMode, u32)> {
    let mode = PrecisionMode::parse(&args.get_or("precision", "fp32"))
        .map_err(|e| anyhow::anyhow!("--precision: {e}"))?;
    if mode == PrecisionMode::Fp32 && args.get("hot-threshold").is_some() {
        bail!(
            "--hot-threshold requires --precision mixed (fp32 keeps every \
             row in full precision, so there is no hot/cold split to tune)"
        );
    }
    let threshold = args.get_usize("hot-threshold", 8);
    if mode == PrecisionMode::Mixed && threshold == 0 {
        bail!(
            "--hot-threshold must be >= 1 under --precision mixed \
             (0 would classify every row hot and never compress)"
        );
    }
    Ok((mode, threshold as u32))
}

/// Parse and validate `--mode` plus the online-only knobs, rejecting
/// contradictory flag combinations up front with actionable errors.
fn parse_online_mode(args: &Args) -> Result<Option<OnlineOptions>> {
    const ONLINE_ONLY: &[&str] = &[
        "intervals",
        "sync-interval",
        "feature-ttl",
        "admit-threshold",
        "admit-prob",
        "sync-dir",
        "day-every",
    ];
    match args.get_or("mode", "offline").as_str() {
        "offline" => {
            for key in ONLINE_ONLY {
                if args.get(key).is_some() {
                    bail!("--{key} requires --mode online");
                }
            }
            Ok(None)
        }
        "online" => {
            if args.get("steps").is_some() {
                bail!(
                    "--mode online runs are bounded by --intervals × --sync-interval \
                     (--intervals 0 = run until interrupted); --steps only applies \
                     to --mode offline"
                );
            }
            let mut o = OnlineOptions::new(args.get_usize("sync-interval", 50));
            o.intervals = args.get_usize("intervals", 0);
            o.feature_ttl = args.get_u64("feature-ttl", 0);
            o.day_every = args.get_usize("day-every", 8);
            // Admission: distinguish "flag omitted" from explicit values
            // so `--admit-threshold 0` cannot silently mean something
            // else, and an out-of-range probability errors instead of
            // disabling the filter.
            let threshold_given = args.get("admit-threshold").is_some();
            let threshold = args.get_usize("admit-threshold", 0);
            let prob = args.get_f64("admit-prob", 0.0);
            if args.get("admit-prob").is_some() && !(0.0..=1.0).contains(&prob) {
                bail!("--admit-prob must be in [0, 1], got {prob}");
            }
            if threshold_given && threshold == 0 {
                bail!(
                    "--admit-threshold 0 is ambiguous: omit the flag to disable \
                     admission, or use 1 to admit on first sight"
                );
            }
            o.admission = if threshold_given {
                Some(AdmissionConfig::new(threshold as u32, prob))
            } else if prob > 0.0 {
                // Lottery-only filtering: never admit by count alone.
                Some(AdmissionConfig::new(u32::MAX, prob))
            } else {
                None
            };
            o.sync_dir = args.get("sync-dir").map(std::path::PathBuf::from);
            // Trainer::new re-validates; failing here keeps the error at
            // the flag-parsing layer where the wording can name flags.
            o.validate()?;
            Ok(Some(o))
        }
        other => bail!("--mode expects offline|online, got `{other}`"),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "no-balancing",
        "no-merging",
        "no-multiplex",
        "verbose",
        "fixed",
    ]);
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("train-dist") => cmd_train_dist(&args),
        Some("dist-worker") => cmd_dist_worker(&args),
        Some("sim") => cmd_sim(&args),
        Some("data") => cmd_data(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: mtgrboost <train|train-dist|sim|data|serve|info> [--key value ...]\n\
                 see rust/src/main.rs for the full flag list"
            );
            Ok(())
        }
    }
}

/// Build [`TrainerOptions`] from the shared training-flag tail. Used
/// identically by `train`, by `train-dist` (supervisor side, for
/// validation and the coordinator seed) and by `dist-worker` — so one
/// argv means one option set in every process. `dist` flips the
/// GAUC default off (per-process GAUC state cannot be merged and
/// `TrainerOptions::validate` rejects it under `dist`).
fn parse_train_opts(args: &Args, dist: bool) -> Result<TrainerOptions> {
    let model = args.get_or("model", "tiny");
    let world = args.get_usize("world", 2);
    let steps = args.get_usize("steps", 50);
    let mut opts = TrainerOptions::new(&model, world, steps);
    opts.train.sequence_balancing = !args.has_flag("no-balancing");
    opts.train.dedup = parse_dedup(&args.get_or("dedup", "two-stage"))?;
    opts.overlap = parse_switch("overlap", &args.get_or("overlap", "on"))?;
    // Cross-step pipelining (post step s+1's first ID exchange during
    // step s's dense sync); only meaningful with overlap on. Numerics
    // are bit-identical on or off.
    opts.cross_step = parse_switch("cross-step", &args.get_or("cross-step", "on"))?;
    // Size of the process-global worker pool shared by all trainer
    // workers (each gets a deterministic fair share); 0 = size to the
    // machine. Numerics are bit-identical for every value.
    opts.threads = args.get_usize("threads", 1);
    opts.train.lr = args.get_f64("lr", 1e-3) as f32;
    opts.train.target_tokens = args.get_usize("target-tokens", 2048);
    opts.train.fixed_batch = args.get_usize("batch", 16);
    opts.train.grad_accum = args.get_usize("grad-accum", 1);
    opts.generator.seed = args.get_u64("seed", 2026);
    opts.generator.len_mu = args.get_f64("len-mu", 3.8);
    opts.generator.max_len = args.get_usize("max-len", 256);
    opts.log_every = args.get_usize("log-every", 10);
    opts.prefetch_depth = args.get_usize("prefetch-depth", opts.prefetch_depth);
    // Feature schema preset: `meituan` (homogeneous, one merge group)
    // or `meituan-mixed` (8D context + model-dim token features — the
    // multi-group table-merging path). Online knobs apply uniformly to
    // every group.
    opts.schema = parse_schema(args)?;
    // Mixed-precision embedding storage (§5.2): FP32 hot rows, FP16
    // cold rows, plus FP16 wire compression for cold replies and cold
    // gradient pushes. `fp32` (the default) is byte-identical to a
    // build without the policy.
    let (precision, hot_threshold) = parse_precision(args)?;
    opts.precision = precision;
    opts.hot_threshold = hot_threshold;
    // Unmerged ablation: one physical table + one exchange per logical
    // table instead of one per dim group, so the §4.2 fusion win shows
    // up as measured wall-clock, not just op counts.
    opts.table_merging = !args.has_flag("no-merging");
    // Exchange multiplexing ablation: post one exchange per merge
    // group instead of one packed message per comm lane. Payload bytes
    // and numerics are bit-identical either way.
    opts.multiplex_exchange = !args.has_flag("no-multiplex");
    opts.collect_gauc = parse_switch(
        "gauc",
        &args.get_or("gauc", if dist { "off" } else { "on" }),
    )?;
    opts.online = parse_online_mode(args)?;
    // Named workload scenario: reshapes the generator and may force a
    // schema / install admission defaults (`Trainer::new` applies the
    // online defaults and re-validates). Dist runs are refused here —
    // scenarios are a single-process harness feature.
    opts.scenario = parse_scenario(args, opts.online.is_some())?;
    if dist && opts.scenario.is_some() {
        bail!("--scenario only applies to single-process `train`, not train-dist");
    }
    let default_warmup = match &opts.online {
        Some(o) => o.sync_interval,
        None => steps / 4,
    };
    opts.gauc_warmup = args.get_usize("gauc-warmup", default_warmup);
    Ok(opts)
}

/// The engine every trainer-shaped command shares: a PJRT artifacts dir
/// when one is given, the deterministic reference backend otherwise
/// (seeded identically to the data generator, so reference runs are
/// reproducible end to end).
fn engine_from_args(args: &Args) -> Result<Engine> {
    match args.get("artifacts") {
        Some(dir) => Engine::start(std::path::Path::new(dir)).context("start PJRT engine"),
        None => Engine::reference(args.get_u64("seed", 2026)),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let opts = parse_train_opts(args, false)?;
    let engine = engine_from_args(args)?;

    let world = opts.cluster.world;
    let overlap = opts.overlap;
    let online = opts.online.is_some();
    let prefetch_depth = opts.prefetch_depth;
    let report = Trainer::new(opts, engine)?.run()?;
    if let Some(path) = args.get("report-json") {
        // The single-process reference report for the dist drills: the
        // same bit-exact JSON shape the dist workers emit.
        std::fs::write(path, report_to_json(&report, 0, world).pretty())
            .with_context(|| format!("write {path}"))?;
    }
    let (lc, lv) = report.final_losses();
    println!("steps                : {}", report.steps.len());
    println!(
        "comm exposed/hidden  : {:.3} / {:.3} ms per step (overlap {})",
        report.mean_exposed_comm_s() * 1e3,
        report.mean_hidden_comm_s() * 1e3,
        if overlap { "on" } else { "off" },
    );
    println!(
        "hidden reply/grad    : {:.3} / {:.3} ms per step",
        report.mean_hidden_reply_s() * 1e3,
        report.mean_hidden_grad_s() * 1e3,
    );
    println!(
        "hidden boundary      : {:.3} id / {:.3} grad ms per step (cross-step)",
        report.mean_hidden_boundary_s() * 1e3,
        report.mean_hidden_boundary_grad_s() * 1e3,
    );
    println!(
        "prefetch occupancy   : {:.2} of depth {}",
        report.prefetch_occupancy, prefetch_depth
    );
    println!("final loss ctr/ctcvr : {lc:.4} / {lv:.4}");
    println!(
        "GAUC ctr/ctcvr       : {} / {}",
        report
            .gauc_ctr
            .map(|g| format!("{g:.4}"))
            .unwrap_or_else(|| "n/a".into()),
        report
            .gauc_ctcvr
            .map(|g| format!("{g:.4}"))
            .unwrap_or_else(|| "n/a".into()),
    );
    println!(
        "throughput wall      : {:.1} samples/s ({:.0} tokens/s)",
        report.wall.samples_per_sec(),
        report.wall.tokens_per_sec()
    );
    println!(
        "throughput simulated : {:.1} samples/s ({:.0} tokens/s)",
        report.sim_samples_per_sec, report.sim_tokens_per_sec
    );
    println!(
        "sparse rows          : {} ({:.1} MB)",
        report.table_rows,
        report.table_memory_bytes as f64 / 1e6
    );
    println!(
        "table evict/expand   : {} / {} (inserts {})",
        report.table_stats.evictions, report.table_stats.expansions, report.table_stats.inserts
    );
    if report.precision == "mixed" {
        println!(
            "precision            : mixed ({} hot / {} cold rows, {} quantize ops)",
            report.hot_rows, report.cold_rows, report.quantize_ops
        );
        println!(
            "precision wire bytes : {:.3} MB fp32 rows + {:.3} MB fp16 rows + {:.3} MB tags",
            report.wire_fp32_row_bytes as f64 / 1e6,
            report.wire_fp16_row_bytes as f64 / 1e6,
            report.wire_tag_bytes as f64 / 1e6
        );
        let all_fp32: f64 = report
            .group_rows
            .iter()
            .zip(&report.group_dims)
            .map(|(&rows, &dim)| rows as f64 * dim as f64 * 4.0)
            .sum();
        println!(
            "effective value bytes: {:.3} MB stored (vs {:.3} MB all-fp32)",
            report.effective_value_bytes as f64 / 1e6,
            all_fp32 / 1e6
        );
    }
    if online {
        println!(
            "online admit/reject  : {} / {}",
            report.online_admitted, report.online_rejected
        );
        println!(
            "online expired/sync  : {} rows expired, {} rows synced ({:.2} MB of deltas)",
            report.online_expired,
            report.online_synced_rows,
            report.online_sync_bytes as f64 / 1e6
        );
    }
    if let Some(name) = &report.scenario {
        println!("scenario             : {name}");
        println!(
            "peak resident rows   : {} ({} row-budget evictions)",
            report.peak_resident_rows, report.total_evictions
        );
        println!(
            "batcher carry/fill   : {:.0} tokens carried, {:.2} fill",
            report.batcher_carryover_mean, report.batcher_fill_mean
        );
    }
    println!(
        "dedup                : ids {} -> {}, lookups {} -> {}",
        report.dedup_volume.ids_raw,
        report.dedup_volume.ids_sent,
        report.dedup_volume.lookups_raw,
        report.dedup_volume.lookups_done
    );
    println!(
        "lookup ops           : {} merged vs {} unmerged ({} merge group{})",
        report.lookup_ops_merged,
        report.lookup_ops_unmerged,
        report.group_dims.len(),
        if report.group_dims.len() == 1 { "" } else { "s" }
    );
    if report.group_dims.len() > 1 {
        for (g, dim) in report.group_dims.iter().enumerate() {
            let v = &report.group_volumes[g];
            println!(
                "  group {g} ({dim:>3}D)     : {} rows, ids {} -> {}, lookups {} -> {}",
                report.group_rows[g], v.ids_raw, v.ids_sent, v.lookups_raw, v.lookups_done
            );
        }
    }
    println!("\nphase decomposition (wall):\n{}", report.phases.report());
    Ok(())
}

/// Supervisor-only keys that must NOT be forwarded to workers: the
/// worker either gets its own value appended per rank (`rank`,
/// `run-dir`, `heartbeat-ms`, `incarnation`, `fault`) or the key is
/// meaningless in a worker (`report-json`, the timeout/recovery knobs).
const SUPERVISOR_ONLY: &[&str] = &[
    "report-json",
    "run-dir",
    "heartbeat-ms",
    "heartbeat-timeout-ms",
    "max-recoveries",
    "fault",
    "rank",
    "incarnation",
];

/// Reconstruct the training-flag tail to forward to every worker from
/// the supervisor's own parsed argv. Per-rank flags are appended after
/// this tail by the supervisor and win on conflict (the parser keeps
/// the last occurrence of a key).
fn worker_args_from(args: &Args) -> Vec<String> {
    let mut out = Vec::new();
    for (k, v) in &args.options {
        if !SUPERVISOR_ONLY.contains(&k.as_str()) {
            out.push(format!("--{k}"));
            out.push(v.clone());
        }
    }
    for f in &args.flags {
        out.push(format!("--{f}"));
    }
    out
}

fn parse_fault_flag(args: &Args) -> Result<Option<FaultPlan>> {
    match args.get("fault") {
        Some(s) => {
            let plan = FaultPlan::parse(s)?;
            Ok((!plan.is_empty()).then_some(plan))
        }
        None => Ok(None),
    }
}

fn cmd_train_dist(args: &Args) -> Result<()> {
    let topts = parse_train_opts(args, true)?;
    let run_dir = match args.get("run-dir") {
        Some(d) => std::path::PathBuf::from(d),
        // Keep the default short: Unix socket paths cap at ~108 bytes.
        None => std::env::temp_dir().join(format!("mtgr_dist_{}", std::process::id())),
    };
    let dopts = DistOptions {
        run_dir,
        heartbeat_ms: args.get_u64("heartbeat-ms", 25),
        heartbeat_timeout_ms: args.get_u64("heartbeat-timeout-ms", 2000),
        max_recoveries: args.get_usize("max-recoveries", 3),
        fault: parse_fault_flag(args)?,
        worker_bin: std::env::current_exe().context("resolve worker binary")?,
        worker_args: worker_args_from(args),
    };
    let report = run_dist(&topts, &dopts)?;
    let (lc, lv) = (
        f64::from_bits(report.final_loss_ctr_bits),
        f64::from_bits(report.final_loss_ctcvr_bits),
    );
    println!("world                : {} processes", report.world);
    println!("steps (rank 0, last incarnation): {}", report.steps.len());
    println!("final loss ctr/ctcvr : {lc:.4} / {lv:.4}");
    println!(
        "sparse rows          : {} across {} group{}",
        report.table_rows,
        report.group_rows.len(),
        if report.group_rows.len() == 1 { "" } else { "s" }
    );
    println!("rows synced          : {}", report.online_synced_rows);
    println!(
        "recoveries           : {} ({} steps replayed)",
        report.dist.recoveries, report.dist.replayed_steps
    );
    println!(
        "heartbeat misses     : {} (transport retries {})",
        report.dist.heartbeat_misses, report.dist.transport_retries
    );
    for (g, c) in report.group_checksums.iter().enumerate() {
        println!("group {g} checksum     : {c:#018x}");
    }
    if let Some(path) = args.get("report-json") {
        std::fs::write(path, dist_report_to_json(&report).pretty())
            .with_context(|| format!("write {path}"))?;
    }
    Ok(())
}

/// The hidden per-rank process body `train-dist` spawns. Parses the
/// same training tail as the supervisor plus its appended per-rank
/// flags.
fn cmd_dist_worker(args: &Args) -> Result<()> {
    let topts = parse_train_opts(args, true)?;
    let Some(rank) = args.get("rank") else {
        bail!("dist-worker requires --rank (spawned by train-dist, not by hand)");
    };
    let rank: usize = rank
        .parse()
        .with_context(|| format!("--rank expects an integer, got `{rank}`"))?;
    let Some(run_dir) = args.get("run-dir") else {
        bail!("dist-worker requires --run-dir");
    };
    let w = WorkerOptions {
        rank,
        run_dir: std::path::PathBuf::from(run_dir),
        heartbeat_ms: args.get_u64("heartbeat-ms", 25),
        incarnation: args.get_u64("incarnation", 0) as u32,
        fault: parse_fault_flag(args)?,
        artifacts: args.get("artifacts").map(std::path::PathBuf::from),
    };
    run_worker(topts, &w)
}

fn cmd_sim(args: &Args) -> Result<()> {
    if args.get("schema").is_some() {
        bail!(
            "--schema only applies to `train`; the simulator models the \
             schema analytically (use --merge-groups for the fused-op count)"
        );
    }
    if args.get("scenario").is_some() {
        bail!(
            "--scenario only applies to `train`; the simulator has no data \
             stream or admission machinery to reshape"
        );
    }
    if args.get("precision").is_some() || args.get("hot-threshold").is_some() {
        bail!(
            "--precision/--hot-threshold only apply to `train`; the simulator \
             models embedding storage analytically at full precision"
        );
    }
    let model = args.get_or("model", "4g");
    let world = args.get_usize("world", 8);
    let dim_factor = args.get_usize("dim-factor", 1);
    let cfg = ModelConfig::by_name(&model)
        .with_context(|| format!("unknown model `{model}`"))?
        .with_dim_factor(dim_factor);
    let mut opts = SimOptions::new(cfg, world);
    opts.steps = args.get_usize("steps", 50);
    opts.sequence_balancing = !args.has_flag("no-balancing");
    opts.table_merging = !args.has_flag("no-merging");
    opts.dedup = parse_dedup(&args.get_or("dedup", "two-stage"))?;
    // Sim default mirrors SimOptions::new (off): figure baselines keep
    // the paper's serial-exchange semantics unless the ablation asks.
    opts.overlap = parse_switch("overlap", &args.get_or("overlap", "off"))?;
    opts.cross_step = parse_switch("cross-step", &args.get_or("cross-step", "off"))?;
    opts.backend = match args.get_or("backend", "hash").as_str() {
        "hash" => TableBackend::DynamicHash,
        "mch" => TableBackend::Mch,
        other => bail!("unknown backend `{other}`"),
    };
    opts.fixed_batch = args.get_usize("batch", 32);
    opts.target_tokens = args.get_usize("target-tokens", 600 * 32);
    // Fused lookup ops per exchange with merging on: one per merge
    // group (heterogeneous dims cannot fuse below one op per dim, nor
    // above one op per logical table). Validated here so the CLI errors
    // like every other flag instead of panicking inside simulate().
    opts.merge_groups = args.get_usize("merge-groups", 1);
    let logical_tables = opts.token_features + opts.context_features;
    if opts.merge_groups < 1 || opts.merge_groups > logical_tables {
        bail!(
            "--merge-groups must be in 1..={logical_tables} (one fused lookup op \
             per dim group, at most one per logical table)"
        );
    }

    let r = simulate(&opts);
    println!("world                : {world} GPUs");
    println!("throughput           : {:.0} sequences/s", r.throughput);
    println!("tokens/s             : {:.3e}", r.tokens_per_sec);
    println!(
        "mean step            : {:.2} ms",
        mtgrboost::sim::mean_step_s(&r) * 1e3
    );
    println!("idle fraction        : {:.1}%", r.idle_fraction * 100.0);
    println!(
        "per-GPU memory       : {:.1} GB ({:.1}% of A100)",
        r.memory_bytes / 1e9,
        r.memory_utilization * 100.0
    );
    println!(
        "tokens per device    : min {:.0} / max {:.0} (means across steps)",
        r.token_min_mean, r.token_max_mean
    );
    Ok(())
}

fn cmd_data(args: &Args) -> Result<()> {
    let out = args.get_or("out", "/tmp/mtgr_shards");
    let n = args.get_usize("sequences", 1000);
    let shards = args.get_usize("shards", 4);
    let cfg = GeneratorConfig {
        seed: args.get_u64("seed", 2026),
        ..Default::default()
    };
    let schema = Schema::meituan_like(args.get_usize("dim", 32), 1);
    let mut gen = WorkloadGenerator::new(cfg);
    let seqs = gen.batch(&schema, n);
    let paths = write_sharded_dataset(std::path::Path::new(&out), &schema, &seqs, shards)?;
    println!(
        "wrote {} sequences into {} shards under {}",
        n,
        paths.len(),
        out
    );
    Ok(())
}

/// Parse + validate the `serve` flags (same discipline as
/// [`parse_online_mode`]: fail at the flag layer with flag-named
/// errors; `run_serve` and `TrafficConfig::validate` re-check).
/// Returns the sync dir and the assembled serve options.
fn parse_serve(args: &Args) -> Result<(String, ServeOptions)> {
    if args.get("mode").is_some() {
        bail!("--mode applies to `train`; `serve` always consumes a sync dir");
    }
    let Some(sync_dir) = args.get("sync-dir") else {
        bail!(
            "serve requires --sync-dir DIR (the base + delta_<seq> snapshots \
             an online trainer published with --sync-dir)"
        );
    };
    let d = ServeOptions::default();
    let requests = args.get_usize("requests", d.requests);
    if requests == 0 {
        bail!("--requests must be positive");
    }
    let micro_batch = args.get_usize("micro-batch", d.micro_batch);
    if micro_batch == 0 {
        bail!("--micro-batch must be positive (requests batched per forward)");
    }
    let qps = args.get_f64("qps", d.traffic.qps);
    if !qps.is_finite() || qps <= 0.0 {
        bail!("--qps must be positive, got {qps}");
    }
    let burst = args.get_f64("burst", d.traffic.burst_amplitude);
    if !(0.0..1.0).contains(&burst) {
        bail!("--burst must be in [0, 1) (relative diurnal amplitude), got {burst}");
    }
    let miss_rate = args.get_f64("miss-rate", d.traffic.miss_rate);
    if !(0.0..=1.0).contains(&miss_rate) {
        bail!("--miss-rate must be in [0, 1], got {miss_rate}");
    }
    let opts = ServeOptions {
        requests,
        micro_batch,
        refresh_every: args.get_usize("refresh-every", d.refresh_every),
        compact_every: args.get_usize("compact-every", d.compact_every),
        group: args.get_usize("group", 0),
        traffic: mtgrboost::serve::TrafficConfig {
            users: args.get_usize("users", d.traffic.users),
            alpha: args.get_f64("zipf-alpha", d.traffic.alpha),
            qps,
            burst_amplitude: burst,
            day_seconds: args.get_f64("day-seconds", d.traffic.day_seconds),
            ids_per_request: args.get_usize("ids-per-request", d.traffic.ids_per_request),
            miss_rate,
            seed: args.get_u64("seed", d.traffic.seed),
        },
        replica: mtgrboost::serve::ReplicaOptions {
            cache_slots: args.get_usize("cache-slots", d.replica.cache_slots),
            ..d.replica
        },
    };
    opts.traffic.validate()?;
    Ok((sync_dir.to_string(), opts))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (sync_dir, opts) = parse_serve(args)?;
    // Serving reuses the training engine contract: a PJRT artifacts dir
    // when one is given, the deterministic reference backend otherwise.
    let engine = match args.get("artifacts") {
        Some(dir) => Engine::start(std::path::Path::new(dir)).context("start PJRT engine")?,
        None => Engine::reference(args.get_u64("seed", 2026))?,
    };
    let r = run_serve(std::path::Path::new(&sync_dir), &engine, &opts)?;
    println!("requests             : {} ({} micro-batches)", r.requests, r.micro_batches);
    println!(
        "latency p50/p99      : {:.3} / {:.3} ms (mean {:.3})",
        r.latency_ms.p50, r.latency_ms.p99, r.latency_ms.mean
    );
    println!(
        "qps achieved/offered : {:.0} / {:.0}",
        r.achieved_qps, r.offered_qps
    );
    println!(
        "cache hit rate       : {:.1}% ({} invalidations)",
        r.cache_hit_rate * 100.0,
        r.stats.cache_invalidations
    );
    println!(
        "lookups              : {} ({} resident, {} missing)",
        r.stats.lookups, r.stats.resident, r.stats.missing
    );
    println!(
        "sync state           : seq {} step {} ({} deltas refreshed, {} compactions)",
        r.applied_seq, r.applied_step, r.deltas_refreshed, r.compactions
    );
    println!("embedding checksum   : {:#018x}", r.embedding_checksum);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_of(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string()), &[])
    }

    #[test]
    fn offline_mode_rejects_online_only_flags() {
        let a = args_of(&["train", "--sync-interval", "10"]);
        let err = parse_online_mode(&a).unwrap_err().to_string();
        assert!(err.contains("--sync-interval requires --mode online"), "{err}");
        let a = args_of(&["train", "--mode", "offline", "--feature-ttl", "5"]);
        assert!(parse_online_mode(&a).is_err());
        let a = args_of(&["train", "--steps", "10"]);
        assert!(parse_online_mode(&a).unwrap().is_none());
    }

    #[test]
    fn online_mode_rejects_steps_and_bad_intervals() {
        let a = args_of(&["train", "--mode", "online", "--steps", "10"]);
        let err = parse_online_mode(&a).unwrap_err().to_string();
        assert!(err.contains("--steps"), "{err}");

        let a = args_of(&["train", "--mode", "online", "--sync-interval", "0"]);
        assert!(parse_online_mode(&a).is_err(), "zero sync interval");

        let a = args_of(&[
            "train", "--mode", "online", "--sync-interval", "20", "--feature-ttl", "5",
        ]);
        let err = parse_online_mode(&a).unwrap_err().to_string();
        assert!(err.contains("--feature-ttl"), "{err}");

        let a = args_of(&["train", "--mode", "bogus"]);
        assert!(parse_online_mode(&a).is_err());
    }

    #[test]
    fn online_mode_parses_admission_variants() {
        let a = args_of(&["train", "--mode", "online", "--sync-interval", "10"]);
        let o = parse_online_mode(&a).unwrap().unwrap();
        assert!(o.admission.is_none(), "no knobs → admission off");
        assert_eq!(o.total_steps(), None, "endless by default");

        let a = args_of(&[
            "train", "--mode", "online", "--sync-interval", "10", "--intervals", "3",
            "--admit-threshold", "2", "--admit-prob", "0.1", "--feature-ttl", "20",
        ]);
        let o = parse_online_mode(&a).unwrap().unwrap();
        assert_eq!(o.total_steps(), Some(30));
        let adm = o.admission.unwrap();
        assert_eq!(adm.threshold, 2);
        assert!((adm.admit_prob - 0.1).abs() < 1e-12);

        // Lottery-only filtering: threshold omitted, prob set.
        let a = args_of(&[
            "train", "--mode", "online", "--sync-interval", "10", "--admit-prob", "0.2",
        ]);
        let o = parse_online_mode(&a).unwrap().unwrap();
        assert_eq!(o.admission.unwrap().threshold, u32::MAX);
    }

    #[test]
    fn schema_flag_validation() {
        // Unknown preset names are rejected with the candidate list.
        let a = args_of(&["train", "--schema", "bogus"]);
        let err = parse_schema(&a).unwrap_err().to_string();
        assert!(err.contains("unknown --schema"), "{err}");
        assert!(err.contains("meituan-mixed"), "candidates listed: {err}");

        // Known presets parse; omission defaults to the homogeneous one.
        let a = args_of(&["train", "--schema", "meituan-mixed"]);
        assert_eq!(parse_schema(&a).unwrap(), "meituan-mixed");
        let a = args_of(&["train"]);
        assert_eq!(parse_schema(&a).unwrap(), "meituan");
    }

    #[test]
    fn precision_flag_validation() {
        // Unknown modes rejected with the candidate list.
        let a = args_of(&["train", "--precision", "fp64"]);
        let err = parse_precision(&a).unwrap_err().to_string();
        assert!(err.contains("fp32|mixed"), "{err}");

        // Defaults: fp32 with the untouched threshold default.
        let a = args_of(&["train"]);
        assert_eq!(parse_precision(&a).unwrap(), (PrecisionMode::Fp32, 8));

        // --hot-threshold is meaningless without the hot/cold split.
        let a = args_of(&["train", "--hot-threshold", "4"]);
        let err = parse_precision(&a).unwrap_err().to_string();
        assert!(err.contains("--precision mixed"), "{err}");

        // Mixed parses with the default or an explicit threshold;
        // 0 would disable compression entirely and is rejected.
        let a = args_of(&["train", "--precision", "mixed"]);
        assert_eq!(parse_precision(&a).unwrap(), (PrecisionMode::Mixed, 8));
        let a = args_of(&["train", "--precision", "mixed", "--hot-threshold", "4"]);
        assert_eq!(parse_precision(&a).unwrap(), (PrecisionMode::Mixed, 4));
        let a = args_of(&["train", "--precision", "mixed", "--hot-threshold", "0"]);
        assert!(parse_precision(&a).is_err(), "zero threshold");
    }

    #[test]
    fn precision_wires_into_train_opts_and_is_refused_by_sim() {
        let a = args_of(&["train", "--precision", "mixed", "--hot-threshold", "3"]);
        let o = parse_train_opts(&a, false).unwrap();
        assert_eq!(o.precision, PrecisionMode::Mixed);
        assert_eq!(o.hot_threshold, 3);
        let p = o.precision_policy();
        assert!(p.enabled);
        assert_eq!(p.hot_threshold, 3);

        // train-dist shares the same flag tail, so workers inherit the
        // policy from the forwarded argv.
        let o = parse_train_opts(&a, true).unwrap();
        assert_eq!(o.precision, PrecisionMode::Mixed);

        // Default stays fp32 with a disabled policy.
        let o = parse_train_opts(&args_of(&["train"]), false).unwrap();
        assert_eq!(o.precision, PrecisionMode::Fp32);
        assert!(!o.precision_policy().enabled);

        // The simulator refuses both flags like it refuses --schema.
        let err = cmd_sim(&args_of(&["sim", "--precision", "mixed"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--precision"), "{err}");
        let err = cmd_sim(&args_of(&["sim", "--hot-threshold", "4"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--hot-threshold"), "{err}");
    }

    #[test]
    fn scenario_flag_validation() {
        // Unknown names rejected with the candidate list.
        let a = args_of(&["train", "--scenario", "bogus"]);
        let err = parse_scenario(&a, false).unwrap_err().to_string();
        assert!(err.contains("unknown scenario"), "{err}");
        assert!(err.contains("skew-storm"), "candidates listed: {err}");
        // Omitted flag → no scenario.
        assert!(parse_scenario(&args_of(&["train"]), false).unwrap().is_none());

        // Online-only presets need --mode online; the offline-only one
        // rejects it.
        for name in ["churn-storm", "soak"] {
            let a = args_of(&["train", "--scenario", name]);
            let err = parse_scenario(&a, false).unwrap_err().to_string();
            assert!(err.contains("--mode online"), "{err}");
            assert!(parse_scenario(&a, true).unwrap().is_some());
        }
        let a = args_of(&["train", "--scenario", "multi-tenant"]);
        assert!(parse_scenario(&a, true).is_err(), "offline-only");
        assert!(parse_scenario(&a, false).unwrap().is_some());
        let a = args_of(&["train", "--scenario", "skew-storm"]);
        assert!(parse_scenario(&a, false).unwrap().is_some(), "either mode");
        assert!(parse_scenario(&a, true).unwrap().is_some());

        // A conflicting explicit --schema is rejected; the forced
        // preset (or the untouched default) passes.
        let a = args_of(&[
            "train", "--scenario", "multi-tenant", "--schema", "meituan-mixed",
        ]);
        let err = parse_scenario(&a, false).unwrap_err().to_string();
        assert!(err.contains("meituan-tiered"), "{err}");
        let a = args_of(&[
            "train", "--scenario", "multi-tenant", "--schema", "meituan-tiered",
        ]);
        assert!(parse_scenario(&a, false).unwrap().is_some());
    }

    #[test]
    fn scenario_wires_into_train_opts_and_is_refused_elsewhere() {
        let a = args_of(&["train", "--scenario", "skew-storm", "--steps", "4"]);
        let o = parse_train_opts(&a, false).unwrap();
        assert_eq!(o.scenario.as_ref().unwrap().name, "skew-storm");

        // train-dist refuses scenarios at the flag layer.
        let err = parse_train_opts(&a, true).unwrap_err().to_string();
        assert!(err.contains("--scenario"), "{err}");

        // The simulator refuses the flag like it refuses --schema.
        let err = cmd_sim(&args_of(&["sim", "--scenario", "soak"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--scenario"), "{err}");

        // An online-only preset parses with the full online tail and
        // lands in the options.
        let a = args_of(&[
            "train", "--scenario", "soak", "--mode", "online",
            "--sync-interval", "5", "--intervals", "2",
        ]);
        let o = parse_train_opts(&a, false).unwrap();
        assert_eq!(o.scenario.as_ref().unwrap().name, "soak");
        assert!(o.online.is_some());
    }

    #[test]
    fn train_accepts_no_merging() {
        // The trainer now has a real unmerged path (one physical table
        // per logical table), so `--no-merging` parses with any schema
        // and simply disables grouping in TrainerOptions.
        for argv in [
            &["train", "--schema", "meituan-mixed", "--no-merging"][..],
            &["train", "--no-merging"][..],
        ] {
            let a = Args::parse(argv.iter().map(|s| s.to_string()), &["no-merging"]);
            assert!(parse_schema(&a).is_ok());
            assert!(a.has_flag("no-merging"));
        }
        // The multiplexing ablation parses alongside either plan.
        let a = Args::parse(
            ["train", "--schema", "meituan-mixed", "--no-multiplex"]
                .iter()
                .map(|s| s.to_string()),
            &["no-multiplex"],
        );
        assert!(parse_schema(&a).is_ok());
        assert!(a.has_flag("no-multiplex"));
        // Without the flag both schemas still parse.
        let a = args_of(&["train", "--schema", "meituan-mixed"]);
        assert!(parse_schema(&a).is_ok());
    }

    #[test]
    fn online_knobs_apply_uniformly_across_schema_groups() {
        // `--schema meituan-mixed --mode online` parses to ONE
        // OnlineOptions — there is deliberately no per-group TTL or
        // sync-interval syntax, so the knobs cannot diverge per group.
        let a = args_of(&[
            "train", "--schema", "meituan-mixed", "--mode", "online",
            "--sync-interval", "10", "--feature-ttl", "20", "--intervals", "2",
        ]);
        assert_eq!(parse_schema(&a).unwrap(), "meituan-mixed");
        let o = parse_online_mode(&a).unwrap().unwrap();
        assert_eq!(o.sync_interval, 10);
        assert_eq!(o.feature_ttl, 20);
        // Contradictions within the uniform knobs still fail fast.
        let a = args_of(&[
            "train", "--schema", "meituan-mixed", "--mode", "online",
            "--sync-interval", "20", "--feature-ttl", "5",
        ]);
        assert!(parse_online_mode(&a).is_err(), "ttl below interval");
    }

    #[test]
    fn serve_requires_sync_dir_and_rejects_mode() {
        let a = args_of(&["serve"]);
        let err = parse_serve(&a).unwrap_err().to_string();
        assert!(err.contains("--sync-dir"), "{err}");

        let a = args_of(&["serve", "--sync-dir", "/tmp/x", "--mode", "online"]);
        let err = parse_serve(&a).unwrap_err().to_string();
        assert!(err.contains("--mode"), "{err}");
    }

    #[test]
    fn serve_validates_traffic_knobs_at_the_flag_layer() {
        let base = ["serve", "--sync-dir", "/tmp/x"];
        let bad = [
            (vec!["--qps", "0"], "--qps"),
            (vec!["--qps", "-5"], "--qps"),
            (vec!["--burst", "1.0"], "--burst"),
            (vec!["--miss-rate", "1.5"], "--miss-rate"),
            (vec!["--micro-batch", "0"], "--micro-batch"),
            (vec!["--requests", "0"], "--requests"),
        ];
        for (extra, flag) in bad {
            let mut argv: Vec<&str> = base.to_vec();
            argv.extend(extra.iter());
            let err = parse_serve(&args_of(&argv)).unwrap_err().to_string();
            assert!(err.contains(flag), "`{flag}` named in: {err}");
        }
        // Remaining invalid combos fall through to TrafficConfig checks.
        let a = args_of(&["serve", "--sync-dir", "/tmp/x", "--users", "0"]);
        assert!(parse_serve(&a).is_err());
    }

    #[test]
    fn serve_defaults_and_overrides_parse() {
        let a = args_of(&["serve", "--sync-dir", "/tmp/x"]);
        let (dir, o) = parse_serve(&a).unwrap();
        assert_eq!(dir, "/tmp/x");
        assert!(o.requests > 0 && o.micro_batch > 0);
        assert_eq!(o.group, 0);

        let a = args_of(&[
            "serve", "--sync-dir", "/tmp/x", "--requests", "100", "--micro-batch", "4",
            "--qps", "500", "--burst", "0.3", "--miss-rate", "0.1", "--group", "1",
            "--cache-slots", "64", "--refresh-every", "10", "--compact-every", "50",
        ]);
        let (_, o) = parse_serve(&a).unwrap();
        assert_eq!((o.requests, o.micro_batch, o.group), (100, 4, 1));
        assert_eq!((o.refresh_every, o.compact_every), (10, 50));
        assert_eq!(o.replica.cache_slots, 64);
        assert!((o.traffic.qps - 500.0).abs() < 1e-12);
        assert!((o.traffic.burst_amplitude - 0.3).abs() < 1e-12);
        assert!((o.traffic.miss_rate - 0.1).abs() < 1e-12);
    }

    #[test]
    fn online_mode_rejects_ambiguous_admission_flags() {
        let a = args_of(&[
            "train", "--mode", "online", "--sync-interval", "10",
            "--admit-threshold", "0", "--admit-prob", "0.9",
        ]);
        let err = parse_online_mode(&a).unwrap_err().to_string();
        assert!(err.contains("--admit-threshold 0"), "{err}");

        let a = args_of(&[
            "train", "--mode", "online", "--sync-interval", "10", "--admit-prob", "-0.5",
        ]);
        let err = parse_online_mode(&a).unwrap_err().to_string();
        assert!(err.contains("--admit-prob"), "{err}");

        let a = args_of(&[
            "train", "--mode", "online", "--sync-interval", "10", "--admit-prob", "1.5",
        ]);
        assert!(parse_online_mode(&a).is_err());
    }

    #[test]
    fn train_opts_parse_with_gauc_defaults_per_mode() {
        let a = args_of(&["train", "--model", "tiny", "--world", "2", "--steps", "4"]);
        let o = parse_train_opts(&a, false).unwrap();
        assert!(o.collect_gauc, "single-process default: gauc on");
        assert_eq!((o.cluster.world, o.steps), (2, 4));

        // Dist parsing flips the default off (validate rejects it on).
        let o = parse_train_opts(&a, true).unwrap();
        assert!(!o.collect_gauc, "dist default: gauc off");

        // Explicit values win over either default, and junk is loud.
        let a = args_of(&["train", "--gauc", "off"]);
        assert!(!parse_train_opts(&a, false).unwrap().collect_gauc);
        let a = args_of(&["train", "--gauc", "sometimes"]);
        let err = parse_train_opts(&a, false).unwrap_err().to_string();
        assert!(err.contains("--gauc"), "{err}");
    }

    #[test]
    fn worker_args_strip_supervisor_keys_and_keep_training_tail() {
        let a = Args::parse(
            [
                "train-dist", "--mode", "online", "--sync-interval", "5",
                "--sync-dir", "/tmp/sync", "--world", "2", "--seed", "7",
                "--run-dir", "/tmp/run", "--heartbeat-ms", "10",
                "--heartbeat-timeout-ms", "500", "--max-recoveries", "2",
                "--fault", "kill:rank=1,step=3", "--report-json", "/tmp/r.json",
                "--no-balancing",
            ]
            .iter()
            .map(|s| s.to_string()),
            &["no-balancing"],
        );
        let tail = worker_args_from(&a);
        for kept in ["--mode", "--sync-interval", "--sync-dir", "--world", "--seed"] {
            assert!(tail.contains(&kept.to_string()), "{kept} forwarded: {tail:?}");
        }
        for stripped in SUPERVISOR_ONLY {
            assert!(
                !tail.contains(&format!("--{stripped}")),
                "--{stripped} must not be forwarded: {tail:?}"
            );
        }
        assert!(tail.contains(&"--no-balancing".to_string()), "flags forwarded");
        // Values travel right after their keys (argv pairing intact).
        let i = tail.iter().position(|t| t == "--sync-dir").unwrap();
        assert_eq!(tail[i + 1], "/tmp/sync");
    }

    #[test]
    fn fault_flag_parses_and_rejects_junk() {
        let a = args_of(&["train-dist", "--fault", "kill:rank=1,step=3"]);
        let plan = parse_fault_flag(&a).unwrap().unwrap();
        assert_eq!(plan.kill.unwrap().rank, 1);
        assert_eq!(plan.kill.unwrap().step, 3);

        let a = args_of(&["train-dist"]);
        assert!(parse_fault_flag(&a).unwrap().is_none(), "no flag → no plan");

        let a = args_of(&["train-dist", "--fault", "explode:rank=1"]);
        assert!(parse_fault_flag(&a).is_err(), "unknown fault kind is loud");
    }

    #[test]
    fn dist_worker_requires_rank_and_run_dir() {
        let base = [
            "dist-worker", "--mode", "online", "--sync-interval", "5",
            "--sync-dir", "/tmp/s", "--intervals", "1",
        ];
        let err = cmd_dist_worker(&args_of(&base)).unwrap_err().to_string();
        assert!(err.contains("--rank"), "{err}");

        let mut argv = base.to_vec();
        argv.extend(["--rank", "0"]);
        let err = cmd_dist_worker(&args_of(&argv)).unwrap_err().to_string();
        assert!(err.contains("--run-dir"), "{err}");
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = mtgrboost::runtime::Manifest::load(std::path::Path::new(&dir))?;
    println!("artifacts dir : {dir}");
    println!("seed          : {}", manifest.seed);
    for (name, m) in &manifest.models {
        println!(
            "model {name:<8} d={} blocks={} heads={} tasks={} params={}",
            m.emb_dim, m.blocks, m.heads, m.tasks, m.param_count
        );
        for b in &m.buckets {
            println!(
                "  bucket {}x{}  train={} fwd={}",
                b.batch, b.len, b.train, b.forward
            );
        }
    }
    Ok(())
}
