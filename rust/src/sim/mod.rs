//! Analytic multi-node scale simulator (DESIGN.md substitution #1).
//!
//! The paper's §6 experiments run on up to 16 nodes × 8 A100s. This
//! simulator reproduces their *shape* on one host by combining:
//!
//! - the **real** batching machinery ([`crate::balance`]) fed with real
//!   sampled sequence lengths (the long-tail workload), so per-device
//!   token counts are faithful;
//! - an analytic **Zipf dedup model** (expected-unique curves) for the
//!   ID/embedding communication volumes under each [`DedupStrategy`];
//! - the [`DeviceModel`] (A100 compute/lookup rates) and [`NetModel`]
//!   (NVLink/IB) cost models;
//! - per-table-backend lookup cost multipliers (dynamic hash vs MCH) and
//!   memory footprints for Table 3.
//!
//! Each simulated step: every device draws/bins its batch, costs are
//! computed per device, and the synchronous step time is the slowest
//! device plus the dense all-reduce — the same gating the real trainer
//! measures.

use crate::balance::{Batcher, DynamicBatcher, FixedBatcher};
use crate::collective::netmodel::NetModel;
use crate::config::{ClusterConfig, ModelConfig};
use crate::data::generator::GeneratorConfig;
use crate::data::schema::Sequence;
use crate::embedding::dedup::DedupStrategy;
use crate::metrics::DeviceModel;
use crate::util::rng::Xoshiro256;
use crate::util::stats::Summary;

/// Expected-unique curve for Zipf(α) draws over a vocabulary:
/// `E[unique(n)] = Σ_k 1 − (1 − p_k)^n`, precomputed on a log-grid and
/// interpolated (evaluating the exact sum per query would be O(vocab)).
#[derive(Clone, Debug)]
pub struct ZipfUniqueModel {
    grid_n: Vec<f64>,
    grid_u: Vec<f64>,
    pub vocab: usize,
}

impl ZipfUniqueModel {
    pub fn new(vocab: usize, alpha: f64) -> Self {
        assert!(vocab > 0);
        // Zipf pmf.
        let mut p: Vec<f64> = (1..=vocab).map(|k| 1.0 / (k as f64).powf(alpha)).collect();
        let z: f64 = p.iter().sum();
        for x in p.iter_mut() {
            *x /= z;
        }
        // Log-spaced n grid from 1 to 10^8.
        let mut grid_n = Vec::new();
        let mut n = 1.0f64;
        while n <= 1.0e8 {
            grid_n.push(n);
            n *= 1.6;
        }
        let grid_u: Vec<f64> = grid_n
            .iter()
            .map(|&n| {
                p.iter()
                    .map(|&pk| {
                        // 1-(1-p)^n via expm1 for numerical stability.
                        -(n * (-pk).ln_1p()).exp_m1()
                    })
                    .sum()
            })
            .collect();
        ZipfUniqueModel {
            grid_n,
            grid_u,
            vocab,
        }
    }

    /// Expected number of unique ids among `n` draws.
    pub fn expected_unique(&self, n: f64) -> f64 {
        if n <= 0.0 {
            return 0.0;
        }
        if n <= self.grid_n[0] {
            return n.min(self.grid_u[0]);
        }
        let last = self.grid_n.len() - 1;
        if n >= self.grid_n[last] {
            return self.grid_u[last];
        }
        let i = self.grid_n.partition_point(|&g| g < n) - 1;
        let (n0, n1) = (self.grid_n[i], self.grid_n[i + 1]);
        let (u0, u1) = (self.grid_u[i], self.grid_u[i + 1]);
        // Log-linear interpolation.
        let t = (n.ln() - n0.ln()) / (n1.ln() - n0.ln());
        (u0.ln() * (1.0 - t) + u1.ln() * t).exp()
    }
}

/// Embedding-table backend being simulated (Table 3 axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableBackend {
    /// MTGRBoost dynamic hash table (grouped parallel probing).
    DynamicHash,
    /// TorchRec Managed Collision Handling (binary search + sorted
    /// inserts + full pre-allocation).
    Mch,
}

impl TableBackend {
    /// Relative per-lookup cost vs the dynamic hash table. MCH pays a
    /// binary search (O(log n) dependent probes ≈ ~8× the cost of a
    /// hashed probe at production table sizes) — this reproduces the
    /// 1.47×–2.22× Table 3 gap at the measured lookup volumes.
    fn lookup_cost_multiplier(&self, rows: usize) -> f64 {
        match self {
            TableBackend::DynamicHash => 1.0,
            TableBackend::Mch => (rows.max(2) as f64).log2() / 3.0,
        }
    }
}

/// Simulation options for one configuration point.
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub model: ModelConfig,
    pub cluster: ClusterConfig,
    pub device: DeviceModel,
    pub net: NetModel,
    pub generator: GeneratorConfig,
    pub steps: usize,
    pub seed: u64,
    // ---- feature toggles -------------------------------------------
    pub sequence_balancing: bool,
    pub dedup: DedupStrategy,
    /// Overlap the ID all-to-all with compute (two-phase pipelined
    /// lookup); only the excess beyond the compute window is exposed.
    /// Defaults to **off** so existing figure baselines keep the
    /// paper's serial-exchange semantics; the overlap ablation
    /// (fig12, `--overlap`) enables it explicitly.
    pub overlap: bool,
    /// Extend the double buffer across *step boundaries*, both ways:
    /// step s+1's first ID all-to-all posts during step s's dense
    /// all-reduce + optimizer apply, and step s's last gradient push
    /// stays in flight across the same window — so the ID lane and the
    /// gradient lane additionally hide behind the boundary
    /// ([`DeviceStep::hidden_boundary_s`] and
    /// [`DeviceStep::hidden_boundary_grad_s`], IDs first). Only
    /// meaningful with `overlap` on; defaults to off like `overlap`.
    pub cross_step: bool,
    /// Merged lookup ops (true) vs one op per logical table (false);
    /// per-op fixed launch overhead models the §4.2 fusion win.
    pub table_merging: bool,
    /// Merge groups the schema's dims fold into: with merging on, one
    /// fused lookup op per *group* (a heterogeneous-dim schema cannot
    /// fuse below one op per distinct dim). 1 = homogeneous (the
    /// historical default, byte-identical); must be ≤ the logical table
    /// count.
    pub merge_groups: usize,
    pub backend: TableBackend,
    // ---- batching --------------------------------------------------
    /// Per-device batch size when balancing is off.
    pub fixed_batch: usize,
    /// Target tokens per device when balancing is on.
    pub target_tokens: usize,
    // ---- sparse-side shape -----------------------------------------
    /// Token features per token (schema F) and context features (C).
    pub token_features: usize,
    pub context_features: usize,
    /// Rows resident per table shard (drives lookup cost / memory).
    pub resident_rows: usize,
}

impl SimOptions {
    pub fn new(model: ModelConfig, world: usize) -> Self {
        let avg_len = 600usize;
        let batch = 32usize;
        SimOptions {
            model,
            cluster: ClusterConfig::new(world),
            device: DeviceModel::default(),
            net: NetModel::default(),
            generator: GeneratorConfig::default(),
            steps: 50,
            seed: 2026,
            sequence_balancing: true,
            dedup: DedupStrategy::TwoStage,
            overlap: false,
            cross_step: false,
            table_merging: true,
            merge_groups: 1,
            backend: TableBackend::DynamicHash,
            fixed_batch: batch,
            target_tokens: avg_len * batch,
            // Meituan-scale feature schema: industrial GRMs carry tens
            // of sparse features per token and per user (the real-run
            // schema uses 7 for CPU tractability; the simulator models
            // the production fan-out that makes table merging and dedup
            // matter as much as the paper reports).
            token_features: 16,
            context_features: 24,
            resident_rows: 10_000_000,
        }
    }
}

/// Per-step, per-device cost breakdown.
#[derive(Clone, Debug, Default)]
pub struct DeviceStep {
    pub sequences: usize,
    pub tokens: usize,
    pub compute_s: f64,
    pub lookup_s: f64,
    /// Exposed communication (un-hidden shares of all three lanes).
    pub comm_s: f64,
    /// ID-exchange seconds hidden behind compute (0 with overlap off).
    pub hidden_comm_s: f64,
    /// Embedding-reply seconds hidden by the double-buffered round
    /// (0 with overlap off).
    pub hidden_reply_s: f64,
    /// Backward-gradient seconds hidden behind the next micro-batch's
    /// forward (0 with overlap off).
    pub hidden_grad_s: f64,
    /// ID-exchange seconds hidden behind the *previous* step's dense
    /// all-reduce (cross-step pipelining; 0 unless `cross_step` and
    /// `overlap` are both on).
    pub hidden_boundary_s: f64,
    /// Last-round gradient-push seconds hidden behind the dense
    /// all-reduce (the cross-step gradient lane; 0 unless `cross_step`
    /// and `overlap` are both on).
    pub hidden_boundary_grad_s: f64,
}

/// One simulated step.
#[derive(Clone, Debug)]
pub struct SimStep {
    pub devices: Vec<DeviceStep>,
    /// max(compute+lookup+comm) + dense all-reduce.
    pub step_s: f64,
    pub allreduce_s: f64,
}

/// Aggregated results for one configuration point.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub steps: Vec<SimStep>,
    pub samples: u64,
    pub tokens: u64,
    /// Simulated sequences/second (the paper's throughput metric).
    pub throughput: f64,
    pub tokens_per_sec: f64,
    /// Mean fraction of the step the average device idles (Fig. 9).
    pub idle_fraction: f64,
    /// Per-GPU memory estimate (bytes) and utilization vs 80 GB.
    pub memory_bytes: f64,
    pub memory_utilization: f64,
    /// Mean per-device token summary across steps (Fig. 15 boxes).
    pub token_min_mean: f64,
    pub token_max_mean: f64,
}

const A100_MEM: f64 = 80.0e9;

/// Run the simulator for one configuration.
pub fn simulate(opts: &SimOptions) -> SimResult {
    let world = opts.cluster.world;
    let mut rng = Xoshiro256::new(opts.seed);
    // Per-device length streams (lengths only — ids are modeled
    // analytically via the Zipf unique curves).
    let mut batchers: Vec<Box<dyn Batcher>> = (0..world)
        .map(|_| -> Box<dyn Batcher> {
            if opts.sequence_balancing {
                Box::new(DynamicBatcher::new(opts.target_tokens))
            } else {
                Box::new(FixedBatcher::new(opts.fixed_batch))
            }
        })
        .collect();
    let mut dev_rngs: Vec<Xoshiro256> = (0..world).map(|r| rng.fork(r as u64)).collect();

    // Zipf dedup model over the item vocabulary (the dominant feature);
    // secondary features have smaller vocabularies and dedup even
    // harder, so using the item curve is conservative.
    let zipf = ZipfUniqueModel::new(
        (opts.generator.num_items as usize).min(200_000),
        opts.generator.item_zipf,
    );

    let dim = opts.model.emb_dim * opts.model.dim_factor;
    let f = opts.token_features;
    let params_bytes = opts.model.dense_params() * 4;
    let allreduce_s = opts.net.all_reduce_time(world, params_bytes);
    // Lookup-op launch overhead: merged = one fused op per merge group
    // (1 for a homogeneous schema), unmerged = one op per logical table
    // (F + C tables). Each op costs a kernel launch + collective setup
    // (~60 µs on GPU+NCCL) on each of the three exchange rounds (id
    // a2a, emb a2a, grad a2a).
    let logical_tables = opts.token_features + opts.context_features;
    assert!(
        opts.merge_groups >= 1 && opts.merge_groups <= logical_tables,
        "merge_groups must be in 1..=logical tables ({logical_tables})"
    );
    let ops = if opts.table_merging {
        opts.merge_groups
    } else {
        logical_tables
    };
    let op_overhead = 6.0e-5 * ops as f64 * 3.0;

    let mut steps = Vec::with_capacity(opts.steps);
    let mut total_samples = 0u64;
    let mut total_tokens = 0u64;
    let mut idle_acc = 0.0;
    let mut tmin_acc = 0.0;
    let mut tmax_acc = 0.0;

    for _ in 0..opts.steps {
        let mut devices = Vec::with_capacity(world);
        for g in 0..world {
            // Draw this device's batch of real lengths.
            let batch = loop {
                if let Some(b) = batchers[g].next_batch() {
                    break b;
                }
                let chunk: Vec<Sequence> = (0..64)
                    .map(|_| {
                        let l = dev_rngs[g]
                            .lognormal(opts.generator.len_mu, opts.generator.len_sigma)
                            as usize;
                        let l = l.clamp(opts.generator.min_len, opts.generator.max_len);
                        synth_seq(l)
                    })
                    .collect();
                batchers[g].push_chunk(chunk);
            };
            let tokens: usize = batch.tokens;
            let seqs = batch.batch_size();
            let flops: f64 = batch
                .sequences
                .iter()
                .map(|s| opts.model.forward_flops(s.len()))
                .sum();

            // ---- sparse communication volumes (per device) -----------
            let occurrences = (tokens * f + seqs * opts.context_features) as f64;
            // Stage 1: per-destination dedup of n/W draws over the
            // shard's sub-vocabulary.
            let per_dest = occurrences / world as f64;
            let sub_vocab_scale = 1.0 / world as f64;
            let sent_per_dest = if opts.dedup.stage1() {
                // Expected unique of per_dest draws over vocab/W ids —
                // approximate by scaling the curve's argument.
                zipf.expected_unique(per_dest / sub_vocab_scale) * sub_vocab_scale
            } else {
                per_dest
            };
            let rows_sent = sent_per_dest * world as f64; // total rows on the wire
            // Stage 2: server-side unique across all sources.
            let received_per_shard = rows_sent; // symmetric devices
            let lookups = if opts.dedup.stage2() {
                zipf.expected_unique(received_per_shard * world as f64 / world as f64)
            } else {
                received_per_shard
            };

            let id_bytes_pp = (sent_per_dest * 8.0) as usize;
            let emb_bytes_pp = (sent_per_dest * dim as f64 * 4.0) as usize;
            // Forward: ID all-to-all + embedding-reply all-to-all.
            // Backward (§3 "Backward Update"): gradient all-to-all of
            // the same embedding volume back to the owning shards. With
            // overlap on, all three lanes ride the double-buffered
            // pipeline and hide behind compute in priority order (IDs,
            // then the reply, then gradients).
            let id_comm = opts.net.all_to_all_uniform_time(world, id_bytes_pp.max(1));
            let reply_comm = opts.net.all_to_all_uniform_time(world, emb_bytes_pp.max(1));
            let grad_comm = reply_comm;

            // Cross-step pipelining: the step's *first* micro-round ID
            // exchange was posted during the previous step's dense
            // all-reduce, and the step's *last* gradient push stays in
            // flight across its own all-reduce (the cross-step gradient
            // lane) — both shares hide behind the boundary window, IDs
            // first (they are on the wire before this step's compute
            // even starts); the later rounds' shares still compete for
            // the compute window. The sim models the minimum pipelined
            // configuration of R = 2 micro-rounds, so each boundary
            // share is half its lane.
            let bshares = crate::metrics::overlap_exposure_lanes(
                allreduce_s,
                &[id_comm * 0.5, grad_comm * 0.5],
                opts.overlap && opts.cross_step,
            );
            let boundary_hidden = bshares[0].1;
            let boundary_grad_hidden = bshares[1].1;

            let mult = opts.backend.lookup_cost_multiplier(opts.resident_rows);
            // Forward lookups + backward sparse update: the optimizer
            // reads/writes row + Adam m/v (≈ 3× row traffic) for every
            // unique id it owns.
            let update_hbm =
                lookups * dim as f64 * 4.0 * 3.0 * 2.0 / opts.device.hbm_bytes_per_sec;
            let lookup_s = opts.device.lookup_time(
                (lookups * mult * 2.0) as usize, // fwd probe + bwd locate
                rows_sent as usize,
                dim,
            ) + update_hbm;
            let compute_s = opts.device.compute_time(flops);
            let shares = crate::metrics::overlap_exposure_lanes(
                compute_s,
                &[
                    id_comm - boundary_hidden,
                    reply_comm,
                    grad_comm - boundary_grad_hidden,
                ],
                opts.overlap,
            );
            let comm_s = shares[0].0 + shares[1].0 + shares[2].0 + op_overhead;

            total_samples += seqs as u64;
            total_tokens += tokens as u64;
            devices.push(DeviceStep {
                sequences: seqs,
                tokens,
                compute_s,
                lookup_s,
                comm_s,
                hidden_comm_s: shares[0].1,
                hidden_reply_s: shares[1].1,
                hidden_grad_s: shares[2].1,
                hidden_boundary_s: boundary_hidden,
                hidden_boundary_grad_s: boundary_grad_hidden,
            });
        }
        let busy: Vec<f64> = devices
            .iter()
            .map(|d| d.compute_s + d.lookup_s + d.comm_s)
            .collect();
        let max_busy = busy.iter().cloned().fold(0.0, f64::max);
        let mean_busy = busy.iter().sum::<f64>() / world as f64;
        idle_acc += (max_busy - mean_busy) / max_busy.max(1e-12);
        let toks: Vec<f64> = devices.iter().map(|d| d.tokens as f64).collect();
        tmin_acc += toks.iter().cloned().fold(f64::INFINITY, f64::min);
        tmax_acc += toks.iter().cloned().fold(0.0, f64::max);
        steps.push(SimStep {
            step_s: max_busy + allreduce_s,
            allreduce_s,
            devices,
        });
    }

    let sim_total: f64 = steps.iter().map(|s| s.step_s).sum();
    let n = opts.steps as f64;

    // ---- memory model (Table 2 / Table 3) ----------------------------
    // Activations ∝ peak tokens per device × d × blocks × ~40 bytes
    // (fwd + bwd live tensors incl. 4d UQKV); embeddings + optimizer.
    let peak_tokens = if opts.sequence_balancing {
        // Dynamic batching caps tokens near the target.
        opts.target_tokens as f64 * 1.05
    } else {
        // Fixed batching must survive the worst observed batch.
        steps
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.tokens as f64))
            .fold(0.0, f64::max)
            * 1.15
    };
    let act_bytes =
        peak_tokens * (opts.model.emb_dim * opts.model.hstu_blocks) as f64 * 40.0;
    let table_bytes = match opts.backend {
        // Dynamic: resident rows (values+meta+keys ≈ dim·4 + 32 B) ×3
        // for Adam m/v.
        TableBackend::DynamicHash => {
            opts.resident_rows as f64 * (dim as f64 * 4.0 * 3.0 + 32.0)
        }
        // MCH pre-allocates remap capacity ×2 (paper: over-provisioned)
        // plus the same optimizer state.
        TableBackend::Mch => {
            opts.resident_rows as f64 * 2.0 * (dim as f64 * 4.0)
                + opts.resident_rows as f64 * (dim as f64 * 4.0 * 2.0 + 32.0)
        }
    };
    let memory = act_bytes + table_bytes + params_bytes as f64 * 4.0;

    SimResult {
        samples: total_samples,
        tokens: total_tokens,
        throughput: total_samples as f64 / sim_total.max(1e-12),
        tokens_per_sec: total_tokens as f64 / sim_total.max(1e-12),
        idle_fraction: idle_acc / n,
        memory_bytes: memory,
        memory_utilization: (memory / A100_MEM).min(1.2),
        token_min_mean: tmin_acc / n,
        token_max_mean: tmax_acc / n,
        steps,
    }
}

/// Whether this configuration would OOM on an 80 GB A100 (Table 3's
/// "OOM" cells).
pub fn would_oom(r: &SimResult) -> bool {
    r.memory_bytes > A100_MEM
}

fn synth_seq(len: usize) -> Sequence {
    Sequence {
        user_id: 0,
        context: vec![0, 0, 0],
        tokens: vec![vec![0, 0, 0, 0]; len],
        labels: [0.0, 0.0],
    }
}

/// Convenience: mean step time.
pub fn mean_step_s(r: &SimResult) -> f64 {
    let n = r.steps.len().max(1) as f64;
    r.steps.iter().map(|s| s.step_s).sum::<f64>() / n
}

/// Token summaries across devices and steps (Fig. 15).
pub fn token_summary(r: &SimResult) -> Summary {
    let toks: Vec<f64> = r
        .steps
        .iter()
        .flat_map(|s| s.devices.iter().map(|d| d.tokens as f64))
        .collect();
    Summary::of(&toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts(world: usize) -> SimOptions {
        let mut o = SimOptions::new(ModelConfig::grm_4g(), world);
        o.steps = 10;
        o
    }

    #[test]
    fn zipf_unique_monotone_and_bounded() {
        let m = ZipfUniqueModel::new(10_000, 1.05);
        let mut prev = 0.0;
        for &n in &[1.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6] {
            let u = m.expected_unique(n);
            assert!(u >= prev, "monotone");
            assert!(u <= 10_000.0 + 1e-6, "bounded by vocab");
            assert!(u <= n + 1e-6, "bounded by draws");
            prev = u;
        }
        // Heavy skew → strong dedup at large n.
        assert!(m.expected_unique(1e6) < 10_000.0 + 1e-6);
        assert!(m.expected_unique(1e5) / 1e5 < 0.2, "dup ratio > 80%");
    }

    #[test]
    fn zipf_unique_matches_sampling() {
        // Cross-check the analytic curve against an empirical sample.
        let vocab = 2000;
        let alpha = 1.1;
        let m = ZipfUniqueModel::new(vocab, alpha);
        let z = crate::util::rng::Zipf::new(vocab, alpha);
        let mut rng = Xoshiro256::new(3);
        for &n in &[100usize, 1000, 10_000] {
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n {
                seen.insert(z.sample(&mut rng));
            }
            let got = m.expected_unique(n as f64);
            let emp = seen.len() as f64;
            let rel = (got - emp).abs() / emp;
            assert!(rel < 0.15, "n={n}: analytic {got:.0} vs empirical {emp}");
        }
    }

    #[test]
    fn balancing_reduces_idle_fraction() {
        let mut on = quick_opts(8);
        on.sequence_balancing = true;
        let mut off = quick_opts(8);
        off.sequence_balancing = false;
        let r_on = simulate(&on);
        let r_off = simulate(&off);
        assert!(
            r_on.idle_fraction < r_off.idle_fraction,
            "balanced idle {:.3} vs fixed {:.3}",
            r_on.idle_fraction,
            r_off.idle_fraction
        );
        assert!(r_on.throughput > r_off.throughput);
    }

    #[test]
    fn dedup_improves_throughput_more_at_higher_dims() {
        let gain = |dim_factor: usize| {
            let model = ModelConfig::grm_4g().with_dim_factor(dim_factor);
            let mut none = SimOptions::new(model.clone(), 16);
            none.steps = 8;
            none.dedup = DedupStrategy::None;
            let mut two = none.clone();
            two.dedup = DedupStrategy::TwoStage;
            simulate(&two).throughput / simulate(&none).throughput
        };
        let g1 = gain(1);
        let g64 = gain(64);
        assert!(g1 > 1.0, "dedup must help at 1D: {g1:.2}");
        assert!(
            g64 > g1,
            "dedup gain grows with dim factor: {g1:.2} vs {g64:.2}"
        );
    }

    #[test]
    fn scaling_is_sublinear_but_positive() {
        let thr = |world: usize| {
            let mut o = quick_opts(world);
            o.steps = 6;
            simulate(&o).throughput
        };
        let t8 = thr(8);
        let t64 = thr(64);
        let speedup = t64 / t8;
        assert!(speedup > 3.0, "64 GPUs ≥ 3x of 8: {speedup:.2}");
        assert!(speedup < 8.5, "but sublinear: {speedup:.2}");
    }

    #[test]
    fn overlap_hides_id_communication() {
        let mut on = quick_opts(16);
        on.overlap = true;
        let mut off = on.clone();
        off.overlap = false;
        let r_on = simulate(&on);
        let r_off = simulate(&off);
        let exposed = |r: &SimResult| {
            r.steps
                .iter()
                .flat_map(|s| s.devices.iter().map(|d| d.comm_s))
                .sum::<f64>()
        };
        let hidden = |r: &SimResult| {
            r.steps
                .iter()
                .flat_map(|s| s.devices.iter().map(|d| d.hidden_comm_s))
                .sum::<f64>()
        };
        assert!(
            exposed(&r_on) < exposed(&r_off),
            "overlap must reduce exposed communication: {} vs {}",
            exposed(&r_on),
            exposed(&r_off)
        );
        assert!(hidden(&r_on) > 0.0, "hidden share must be reported");
        assert_eq!(hidden(&r_off), 0.0, "no hiding without overlap");
        assert!(r_on.throughput >= r_off.throughput);
    }

    #[test]
    fn overlap_hides_reply_and_gradient_lanes() {
        let mut on = quick_opts(8);
        on.overlap = true;
        let r_on = simulate(&on);
        let sum_reply: f64 = r_on
            .steps
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.hidden_reply_s))
            .sum();
        let sum_grad: f64 = r_on
            .steps
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.hidden_grad_s))
            .sum();
        assert!(sum_reply > 0.0, "reply lane must report hidden time");
        assert!(sum_grad > 0.0, "gradient lane must report hidden time");
        let mut off = quick_opts(8);
        off.overlap = false;
        let r_off = simulate(&off);
        let sum_off: f64 = r_off
            .steps
            .iter()
            .flat_map(|s| {
                s.devices
                    .iter()
                    .map(|d| d.hidden_reply_s + d.hidden_grad_s)
            })
            .sum();
        assert_eq!(sum_off, 0.0, "no hiding without overlap");
    }

    #[test]
    fn cross_step_hides_boundary_time() {
        let mut on = quick_opts(8);
        on.overlap = true;
        on.cross_step = true;
        let mut off = on.clone();
        off.cross_step = false;
        let r_on = simulate(&on);
        let r_off = simulate(&off);
        let boundary = |r: &SimResult| {
            r.steps
                .iter()
                .flat_map(|s| s.devices.iter().map(|d| d.hidden_boundary_s))
                .sum::<f64>()
        };
        let boundary_grad = |r: &SimResult| {
            r.steps
                .iter()
                .flat_map(|s| s.devices.iter().map(|d| d.hidden_boundary_grad_s))
                .sum::<f64>()
        };
        let exposed = |r: &SimResult| {
            r.steps
                .iter()
                .flat_map(|s| s.devices.iter().map(|d| d.comm_s))
                .sum::<f64>()
        };
        assert!(boundary(&r_on) > 0.0, "boundary lane must report hidden time");
        assert_eq!(boundary(&r_off), 0.0, "no boundary hiding without cross-step");
        assert_eq!(
            boundary_grad(&r_off),
            0.0,
            "no gradient-lane boundary hiding without cross-step"
        );
        assert!(
            exposed(&r_on) <= exposed(&r_off) + 1e-12,
            "cross-step cannot increase exposed comm"
        );
        // The boundary window hides the ID lane first; the gradient
        // lane only gets the remainder, so the two shares together
        // never exceed the window.
        for s in &r_on.steps {
            for d in &s.devices {
                assert!(
                    d.hidden_boundary_s + d.hidden_boundary_grad_s <= s.allreduce_s + 1e-12,
                    "boundary lanes overflow the all-reduce window"
                );
            }
        }
        // Conservation on the ID lane: boundary + compute-hidden +
        // exposed shares never exceed the lane totals, and overlap-off
        // reports zero on every hidden lane.
        let mut plain = quick_opts(8);
        plain.overlap = false;
        plain.cross_step = true; // ignored without overlap
        let r_plain = simulate(&plain);
        assert_eq!(boundary(&r_plain), 0.0, "cross-step requires overlap");
        assert_eq!(boundary_grad(&r_plain), 0.0, "cross-step requires overlap");
    }

    #[test]
    fn mch_slower_and_heavier_than_dynamic() {
        let mut dynamic = quick_opts(8);
        dynamic.backend = TableBackend::DynamicHash;
        let mut mch = dynamic.clone();
        mch.backend = TableBackend::Mch;
        let rd = simulate(&dynamic);
        let rm = simulate(&mch);
        assert!(rd.throughput > rm.throughput, "hash beats binary search");
        assert!(rm.memory_bytes > rd.memory_bytes, "MCH pre-allocates");
    }

    #[test]
    fn merged_tables_cut_op_overhead() {
        let mut merged = quick_opts(8);
        merged.table_merging = true;
        let mut unmerged = merged.clone();
        unmerged.table_merging = false;
        assert!(simulate(&merged).throughput > simulate(&unmerged).throughput);
    }

    #[test]
    fn heterogeneous_groups_sit_between_fused_and_unmerged() {
        // A mixed-dim schema fuses to one op per dim group: more groups
        // ⇒ more launch overhead than full fusion, still far below one
        // op per logical table.
        let mut one = quick_opts(8);
        one.merge_groups = 1;
        let mut four = one.clone();
        four.merge_groups = 4;
        let mut unmerged = one.clone();
        unmerged.table_merging = false;
        let t1 = simulate(&one).throughput;
        let t4 = simulate(&four).throughput;
        let tu = simulate(&unmerged).throughput;
        assert!(t1 >= t4, "fewer groups cannot be slower: {t1} vs {t4}");
        assert!(t4 > tu, "4 fused groups still beat 40 per-table ops");
    }

    #[test]
    #[should_panic(expected = "merge_groups")]
    fn merge_groups_out_of_range_rejected() {
        let mut o = quick_opts(4);
        o.merge_groups = o.token_features + o.context_features + 1;
        let _ = simulate(&o);
    }

    #[test]
    fn memory_utilization_higher_with_balancing_at_same_throughput_envelope() {
        // Table 2's effect: fixed batching must be provisioned for the
        // worst case, so its *peak* activation memory exceeds dynamic
        // batching's at equal average load.
        let mut on = quick_opts(8);
        on.sequence_balancing = true;
        let mut off = quick_opts(8);
        off.sequence_balancing = false;
        // Fixed batch sized to the same average token count.
        off.fixed_batch = on.target_tokens / 600;
        let r_on = simulate(&on);
        let r_off = simulate(&off);
        assert!(r_off.memory_bytes > r_on.memory_bytes);
    }
}
