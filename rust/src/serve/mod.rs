//! Serving subsystem: the consumer end of the train→sync→serve loop.
//!
//! The paper's deployment handles hundreds of millions of daily
//! requests against models the trainer refreshes every few minutes via
//! base + delta parameter sync. This module is that consumer side:
//!
//! * [`replica`] — a read-optimized [`ServingReplica`] that folds all
//!   trainer rank shards into one striped table per merge group,
//!   bootstraps from the newest `base_<seq>` + validated delta chain,
//!   and [`ServingReplica::refresh`]es as the trainer publishes syncs.
//! * [`compact`] — log-structured compaction: fold base + deltas into a
//!   fresh `base_<seq>` (crash-safe stage + rename) so cold-start
//!   replay cost stays bounded and folded deltas can be pruned.
//! * [`cache`] — a direct-mapped [`HotIdCache`] in front of the tables,
//!   invalidated per delta-touched id, with hit-rate counters.
//! * [`traffic`] — a deterministic closed-loop [`TrafficGenerator`]:
//!   Zipf user popularity, diurnal burst curve, configurable QPS and
//!   miss rate.
//!
//! [`run_serve`] wires them together: it drives generated traffic
//! through micro-batched embedding-lookup + dense-forward requests,
//! periodically refreshing from and compacting the sync dir, and
//! reports p50/p99 service latency, achieved QPS and cache hit rates
//! ([`ServeReport`]) — the numbers `bench_serving` sweeps against
//! `--sync-interval`.

pub mod cache;
pub mod compact;
pub mod replica;
pub mod traffic;

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

pub use cache::HotIdCache;
pub use compact::{compact_chain, CompactOptions, CompactionReport};
pub use replica::{ReplicaOptions, ReplicaStats, ServingReplica};
pub use traffic::{Request, TrafficConfig, TrafficGenerator};

use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Knobs for one closed-loop serving run.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Total requests to serve.
    pub requests: usize,
    /// Requests batched into one dense forward.
    pub micro_batch: usize,
    /// Poll the sync dir for new deltas every N requests (0 = never).
    pub refresh_every: usize,
    /// Compact the delta chain every N requests (0 = never).
    pub compact_every: usize,
    /// Merge group the request ids address (must match the model's
    /// embedding dim; group 0 for homogeneous schemas).
    pub group: usize,
    pub traffic: TrafficConfig,
    pub replica: ReplicaOptions,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            requests: 2_000,
            micro_batch: 8,
            refresh_every: 256,
            compact_every: 0,
            group: 0,
            traffic: TrafficConfig::default(),
            replica: ReplicaOptions::default(),
        }
    }
}

/// What a [`run_serve`] pass measured.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub micro_batches: usize,
    /// Real wall time spent serving.
    pub wall_s: f64,
    /// Requests per real second actually served (closed loop).
    pub achieved_qps: f64,
    /// Mean offered rate of the modeled traffic (requests / modeled
    /// seconds) — what an open-loop client would have sent.
    pub offered_qps: f64,
    /// Per-request service latency, milliseconds.
    pub latency_ms: Summary,
    pub stats: ReplicaStats,
    pub cache_hit_rate: f64,
    pub deltas_refreshed: usize,
    pub compactions: usize,
    pub applied_seq: u64,
    pub applied_step: u64,
    /// Replica embedding checksum after the run — comparable to the
    /// trainer report's `embedding_checksum`.
    pub embedding_checksum: u64,
    /// Order-stable sum of all served logits: a cheap end-to-end
    /// witness that two runs served identical predictions.
    pub logits_sum: f64,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("requests", self.requests.into());
        j.set("micro_batches", self.micro_batches.into());
        j.set("wall_s", self.wall_s.into());
        j.set("achieved_qps", self.achieved_qps.into());
        j.set("offered_qps", self.offered_qps.into());
        j.set("latency_p50_ms", self.latency_ms.p50.into());
        j.set("latency_p90_ms", self.latency_ms.p90.into());
        j.set("latency_p99_ms", self.latency_ms.p99.into());
        j.set("latency_mean_ms", self.latency_ms.mean.into());
        j.set("lookups", (self.stats.lookups as usize).into());
        j.set("resident", (self.stats.resident as usize).into());
        j.set("missing", (self.stats.missing as usize).into());
        j.set("cache_hit_rate", self.cache_hit_rate.into());
        j.set(
            "cache_invalidations",
            (self.stats.cache_invalidations as usize).into(),
        );
        j.set("deltas_refreshed", self.deltas_refreshed.into());
        j.set("compactions", self.compactions.into());
        j.set("applied_seq", (self.applied_seq as usize).into());
        j.set("applied_step", (self.applied_step as usize).into());
        j.set("embedding_checksum", self.embedding_checksum.into());
        j.set("logits_sum", self.logits_sum.into());
        j
    }
}

/// Serve `opts.requests` generated requests against the sync dir at
/// `dir`: bootstrap the replica, then loop micro-batches of
/// lookup+forward, interleaving delta refreshes and compaction passes.
/// Closed loop — the next micro-batch starts when the previous one
/// finishes, so achieved QPS is what this host can actually sustain.
pub fn run_serve(dir: &Path, engine: &Engine, opts: &ServeOptions) -> Result<ServeReport> {
    anyhow::ensure!(opts.requests > 0, "must serve at least one request");
    anyhow::ensure!(opts.micro_batch > 0, "micro-batch must be positive");
    let mut replica = ServingReplica::open(dir, opts.replica.clone())?;
    let catalog = replica.live_ids(opts.group);
    let mut gen = TrafficGenerator::new(opts.traffic.clone(), catalog)?;

    let mut latencies_ms = Vec::with_capacity(opts.requests);
    let mut logits_sum = 0.0f64;
    let mut served = 0usize;
    let mut micro_batches = 0usize;
    let mut refreshed = 0usize;
    let mut compactions = 0usize;
    let compact_opts = CompactOptions::default();

    let wall_start = Instant::now();
    while served < opts.requests {
        let n = opts.micro_batch.min(opts.requests - served);
        let requests: Vec<Request> = (0..n).map(|_| gen.next_request()).collect();
        let ids: Vec<&[u64]> = requests.iter().map(|r| r.ids.as_slice()).collect();

        let t0 = Instant::now();
        let logits = replica.forward(engine, opts.group, &ids)?;
        let batch_ms = t0.elapsed().as_secs_f64() * 1e3;
        // Closed loop: every request in the micro-batch waits for the
        // whole batch, so each one experiences the batch service time.
        for _ in 0..n {
            latencies_ms.push(batch_ms);
        }
        logits_sum += logits.iter().map(|&x| x as f64).sum::<f64>();
        served += n;
        micro_batches += 1;

        if opts.refresh_every > 0 && served % opts.refresh_every < n {
            refreshed += replica.refresh()?;
        }
        if opts.compact_every > 0 && served % opts.compact_every < n {
            // The replica has already applied everything the pass
            // folds, so pruning under it is safe.
            if compact_chain(dir, &compact_opts)?.is_some() {
                compactions += 1;
            }
        }
    }
    // Final refresh so the report reflects the newest published state.
    if opts.refresh_every > 0 {
        refreshed += replica.refresh()?;
    }
    let wall_s = wall_start.elapsed().as_secs_f64().max(1e-9);

    let stats = replica.stats();
    let cache_total = stats.cache_hits + stats.cache_misses;
    Ok(ServeReport {
        requests: served,
        micro_batches,
        wall_s,
        achieved_qps: served as f64 / wall_s,
        offered_qps: gen.issued() as f64 / gen.clock_s().max(1e-9),
        latency_ms: Summary::of(&latencies_ms),
        cache_hit_rate: if cache_total == 0 {
            0.0
        } else {
            stats.cache_hits as f64 / cache_total as f64
        },
        stats,
        deltas_refreshed: refreshed,
        compactions,
        applied_seq: replica.applied_seq(),
        applied_step: replica.applied_step(),
        embedding_checksum: replica.content_checksum(),
        logits_sum,
    })
}
