//! Log-structured compaction of delta chains into fresh bases.
//!
//! A replica that replays `base + delta_1 ... delta_n` from scratch pays
//! O(chain length) on every cold start, and the sync dir grows without
//! bound. Compaction folds the validated chain into a new full base
//! `base_<seq:05>` (seq = newest folded delta), after which bootstrap
//! cost resets to one base read and the folded deltas can be pruned.
//!
//! The compacted base is a **full checkpoint** in the standard layout
//! (`meta.json` + `dense.bin` + per-rank/per-group sparse files, rows
//! sorted by id), so `checkpoint::load_*` reads it unchanged and its
//! bytes are independent of the trainer's `--threads` or the order
//! deltas were applied in. Compaction preserves Adam `m`/`v`/`t` bits —
//! a base it writes is byte-identical to a full checkpoint taken at the
//! same step.
//!
//! Crash safety: the new base is staged at `base_<seq:05>.tmp` and
//! published with a single `rename`; [`recover_leftovers`] sweeps any
//! `.tmp` stage a crash left behind before the replica trusts the dir.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::checkpoint::delta::{
    apply_delta, delta_dir, install_rows_concurrent, load_delta_group_dims,
    load_delta_precision_policy, load_delta_shard_group, parse_canonical_seq, snapshot_rows,
    validate_chain,
};
use crate::checkpoint::{
    load_group_dims, load_meta, load_precision_policy, load_sparse_shard_group,
    push_row_bytes, rows_block_bytes, sparse_group_path, write_sealed, CheckpointMeta,
};
use crate::embedding::concurrent::ConcurrentDynamicTable;
use crate::embedding::dynamic_table::DynamicTableConfig;
use crate::optim::adam::{AdamParams, SparseAdam};
use crate::util::json::Json;

/// Directory of compacted base `seq` under the sync root.
pub fn base_dir(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("base_{seq:05}"))
}

/// Knobs for one compaction pass.
#[derive(Clone, Debug)]
pub struct CompactOptions {
    /// Initial capacity of the per-(rank, group) fold tables.
    pub capacity: usize,
    /// Remove the folded deltas and superseded bases after publishing
    /// the new base. Off when an auditor wants the full history kept.
    pub prune: bool,
}

impl Default for CompactOptions {
    fn default() -> Self {
        CompactOptions {
            capacity: 1 << 14,
            prune: true,
        }
    }
}

/// What one compaction pass did.
#[derive(Clone, Debug)]
pub struct CompactionReport {
    /// Seq of the base that was folded into (0 = empty state).
    pub prev_base_seq: u64,
    /// Seq of the freshly published base.
    pub base_seq: u64,
    /// Step the new base captures.
    pub step: u64,
    /// Deltas folded by this pass.
    pub folded_deltas: usize,
    /// Live rows written into the new base (all ranks, all groups).
    pub rows: usize,
    /// Snapshot dirs removed by pruning (0 when `prune` is off).
    pub pruned_dirs: usize,
    /// Wrapping sum of the fold tables' content checksums — comparable
    /// to the trainer's `embedding_checksum` at the same step.
    pub checksum: u64,
}

/// Newest valid compacted base under `dir`, if any: `(seq, meta)`.
/// Non-canonical `base_*` names are rejected like delta aliases;
/// `.tmp` stages (crash leftovers) are ignored — run
/// [`recover_leftovers`] to clear them.
pub fn latest_base(dir: &Path) -> Result<Option<(u64, CheckpointMeta)>> {
    let mut newest: Option<u64> = None;
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("read sync dir {}", dir.display()))?
    {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(".tmp") {
            continue;
        }
        if let Some(seq) = parse_canonical_seq("base_", &name)? {
            newest = Some(newest.map_or(seq, |n: u64| n.max(seq)));
        }
    }
    match newest {
        None => Ok(None),
        Some(seq) => {
            let meta = load_meta(&base_dir(dir, seq))
                .with_context(|| format!("base_{seq:05} is unreadable"))?;
            Ok(Some((seq, meta)))
        }
    }
}

/// Remove crash leftovers: `base_*.tmp` stages whose publishing rename
/// never happened. Returns how many were swept.
pub fn recover_leftovers(dir: &Path) -> Result<usize> {
    let mut swept = 0;
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("read sync dir {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        if name.starts_with("base_") && name.ends_with(".tmp") {
            std::fs::remove_dir_all(entry.path())
                .with_context(|| format!("sweep stale stage {name}"))?;
            swept += 1;
        }
    }
    Ok(swept)
}

/// Fold the current valid delta chain into a fresh base. Returns
/// `Ok(None)` when there is nothing to fold (no deltas past the newest
/// base). Errors on gapped/malformed chains ([`validate_chain`]) and on
/// base/chain disagreements — compaction must never bake stale or
/// mixed-lineage state into a base.
pub fn compact_chain(dir: &Path, opts: &CompactOptions) -> Result<Option<CompactionReport>> {
    recover_leftovers(dir)?;
    let base = latest_base(dir)?;
    let (base_seq, base_step) = base
        .as_ref()
        .map_or((0, 0), |(seq, m)| (*seq, m.step));
    let chain = validate_chain(dir, base_seq, base_step)?;
    let Some(newest) = chain.last().cloned() else {
        return Ok(None);
    };

    if let Some((seq, bm)) = &base {
        anyhow::ensure!(
            bm.world == newest.world,
            "base_{seq:05} was written for world {} but the chain is world {}",
            bm.world,
            newest.world
        );
        anyhow::ensure!(
            bm.param_count == newest.param_count && bm.model == newest.model,
            "base_{seq:05} model/{} params disagree with the chain",
            bm.model
        );
    }

    let group_dims = load_delta_group_dims(dir, &newest)?;
    // The precision policy rides the chain like group_dims does: a base
    // folded from a mixed chain records the policy so replicas (and
    // audits of what grid cold rows live on) survive pruning of the
    // deltas that originally carried it.
    let precision = load_delta_precision_policy(dir, newest.seq)?;
    if let Some((seq, bm)) = &base {
        let bdims = load_group_dims(&base_dir(dir, *seq), bm)?;
        anyhow::ensure!(
            bdims == group_dims,
            "base_{seq:05} group dims {bdims:?} disagree with the chain's {group_dims:?}"
        );
        let bprec = load_precision_policy(&base_dir(dir, *seq))?;
        anyhow::ensure!(
            bprec == precision,
            "base_{seq:05} precision policy {bprec:?} disagrees with the \
             chain's {precision:?}; refusing to fold mixed-lineage state"
        );
    }

    let world = newest.world;
    let stage = dir.join(format!("base_{:05}.tmp", newest.seq));
    std::fs::remove_dir_all(&stage).ok();
    std::fs::create_dir_all(&stage)?;

    let mut rows_written = 0usize;
    let mut checksum = 0u64;
    for rank in 0..world {
        for (g, &gdim) in group_dims.iter().enumerate() {
            // Fold with full Adam state so the published base is
            // byte-identical to a real checkpoint at the same step.
            // The policy is inert here (installs copy stored bits
            // verbatim and mixed chains carry cold rows already on the
            // f16 grid) but keeps the fold tables' self-description —
            // census, effective bytes — truthful.
            let table = ConcurrentDynamicTable::new(
                DynamicTableConfig::new(gdim)
                    .with_capacity(opts.capacity)
                    .with_seed(0),
                1,
            )
            .with_precision(precision);
            let mut opt = SparseAdam::new(gdim, AdamParams::default());
            if let Some((seq, bm)) = &base {
                let rows =
                    load_sparse_shard_group(&base_dir(dir, *seq), bm, world, rank, g)?;
                install_rows_concurrent(rows, &table, &mut opt);
            }
            for m in &chain {
                let (rows, removed) = load_delta_shard_group(dir, m, rank, g)?;
                apply_delta(&table, &mut opt, rows, &removed);
            }
            let rows = snapshot_rows(&table, &opt);
            let mut body = Vec::new();
            for r in &rows {
                push_row_bytes(&mut body, r.id, &r.row, &r.m, &r.v, r.t);
            }
            write_sealed(
                &sparse_group_path(&stage, rank, world, g),
                rows_block_bytes(rows.len() as u64, gdim, &body),
            )?;
            rows_written += rows.len();
            checksum = checksum.wrapping_add(table.content_checksum());
        }
    }

    // Dense state ships whole in every delta; the newest one is the
    // fold result by construction. Copy its bytes verbatim.
    std::fs::copy(
        delta_dir(dir, newest.seq).join("dense.bin"),
        stage.join("dense.bin"),
    )
    .context("copy dense.bin into the staged base")?;

    // Same key order as a trainer-written full checkpoint.
    let mut j = Json::obj();
    j.set("world", world.into());
    j.set("step", (newest.step as usize).into());
    j.set("model", newest.model.as_str().into());
    j.set("dim", newest.dim.into());
    j.set("param_count", newest.param_count.into());
    if group_dims.len() > 1 {
        j.set(
            "group_dims",
            Json::Arr(group_dims.iter().map(|&d| d.into()).collect()),
        );
    }
    crate::checkpoint::set_precision_keys(&mut j, precision);
    std::fs::write(stage.join("meta.json"), j.pretty())?;

    let published = base_dir(dir, newest.seq);
    if published.exists() {
        bail!("base_{:05} already exists; refusing to overwrite", newest.seq);
    }
    std::fs::rename(&stage, &published).context("publish compacted base")?;

    let mut pruned = 0usize;
    if opts.prune {
        for m in &chain {
            std::fs::remove_dir_all(delta_dir(dir, m.seq))?;
            pruned += 1;
        }
        if let Some((seq, _)) = &base {
            std::fs::remove_dir_all(base_dir(dir, *seq))?;
            pruned += 1;
        }
    }

    Ok(Some(CompactionReport {
        prev_base_seq: base_seq,
        base_seq: newest.seq,
        step: newest.step,
        folded_deltas: chain.len(),
        rows: rows_written,
        pruned_dirs: pruned,
        checksum,
    }))
}
