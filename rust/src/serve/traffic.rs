//! Closed-loop serving traffic generator.
//!
//! Models the request stream a Meituan-scale replica sees: millions of
//! users whose activity follows a Zipf power law (a hot head of heavy
//! users dominates), a diurnal load curve (lunch/dinner bursts, late
//! night troughs), and a configurable offered QPS. Requests are a pure
//! function of `(config, seed, index)` — the generator never consults a
//! wall clock, so benches replay identical traffic across runs and
//! machines.
//!
//! Each [`Request`] carries the ids the user's recent behavior sequence
//! resolves to. Ids are drawn from a catalog of *live* ids snapshotted
//! from the replica (so resident lookups hit real rows), plus a
//! configurable fraction of fabricated never-trained ids that model
//! cold items and exercise the miss path.

use anyhow::{bail, Result};

use crate::embedding::GlobalId;
use crate::util::rng::{Xoshiro256, Zipf};

/// Knobs for the synthetic request stream.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    /// Modeled user population (Zipf support size).
    pub users: usize,
    /// Zipf exponent for user activity; production logs are ~1.0–1.2.
    pub alpha: f64,
    /// Mean offered load in requests per second.
    pub qps: f64,
    /// Relative amplitude of the diurnal sine (0 = flat, 0.6 = strong
    /// lunch/dinner swing). Must stay < 1 so the rate never hits zero.
    pub burst_amplitude: f64,
    /// Modeled seconds per diurnal cycle ("day length"); compressed in
    /// benches so a short run sweeps trough and peak.
    pub day_seconds: f64,
    /// Ids per request (the user's behavior-sequence length).
    pub ids_per_request: usize,
    /// Fraction of ids fabricated as never-trained (cache/table misses).
    pub miss_rate: f64,
    /// RNG seed; the whole stream is a pure function of it.
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            users: 1_000_000,
            alpha: 1.1,
            qps: 2000.0,
            burst_amplitude: 0.5,
            day_seconds: 60.0,
            ids_per_request: 32,
            miss_rate: 0.02,
            seed: 0x7EA77FE,
        }
    }
}

impl TrafficConfig {
    pub fn validate(&self) -> Result<()> {
        if self.users == 0 {
            bail!("traffic users must be positive");
        }
        if !self.alpha.is_finite() || self.alpha <= 0.0 {
            bail!("traffic alpha must be positive, got {}", self.alpha);
        }
        if !self.qps.is_finite() || self.qps <= 0.0 {
            bail!("traffic qps must be positive, got {}", self.qps);
        }
        if !(0.0..1.0).contains(&self.burst_amplitude) {
            bail!(
                "traffic burst amplitude must be in [0, 1), got {}",
                self.burst_amplitude
            );
        }
        if !self.day_seconds.is_finite() || self.day_seconds <= 0.0 {
            bail!("traffic day length must be positive seconds");
        }
        if self.ids_per_request == 0 {
            bail!("traffic ids-per-request must be positive");
        }
        if !(0.0..=1.0).contains(&self.miss_rate) {
            bail!("traffic miss rate must be in [0, 1], got {}", self.miss_rate);
        }
        Ok(())
    }
}

/// One serving request: a user and the embedding ids their sequence
/// needs, stamped with the modeled arrival time.
#[derive(Clone, Debug)]
pub struct Request {
    /// Zipf rank of the issuing user (0 = heaviest user).
    pub user: u64,
    /// Modeled arrival time in seconds since stream start.
    pub arrival_s: f64,
    /// Embedding ids to look up (may contain duplicates, like a real
    /// behavior sequence).
    pub ids: Vec<GlobalId>,
}

/// Deterministic closed-loop request stream over a live-id catalog.
pub struct TrafficGenerator {
    cfg: TrafficConfig,
    zipf: Zipf,
    rng: Xoshiro256,
    catalog: Vec<GlobalId>,
    clock_s: f64,
    issued: u64,
}

/// Fabricated ids live at the top of the id space, far above anything
/// the trainer's `GlobalIdCodec` hands out.
const MISS_ID_BASE: GlobalId = GlobalId::MAX - (1 << 20);

impl TrafficGenerator {
    /// `catalog` is the replica's live-id snapshot; resident lookups are
    /// drawn from it, so it must be non-empty.
    pub fn new(cfg: TrafficConfig, catalog: Vec<GlobalId>) -> Result<Self> {
        cfg.validate()?;
        if catalog.is_empty() {
            bail!("traffic generator needs a non-empty live-id catalog");
        }
        let zipf = Zipf::new(cfg.users, cfg.alpha);
        let rng = Xoshiro256::new(cfg.seed);
        Ok(TrafficGenerator {
            cfg,
            zipf,
            rng,
            catalog,
            clock_s: 0.0,
            issued: 0,
        })
    }

    /// Instantaneous offered rate at modeled time `t_s`:
    /// `qps * (1 + A * sin(2πt/day))`.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t_s / self.cfg.day_seconds;
        self.cfg.qps * (1.0 + self.cfg.burst_amplitude * phase.sin())
    }

    /// Modeled clock after the last issued request.
    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Draw the next request. Inter-arrival gaps follow the diurnal
    /// rate deterministically (gap = 1/λ(t)), so a fixed request count
    /// sweeps a known span of modeled time.
    pub fn next_request(&mut self) -> Request {
        let arrival_s = self.clock_s;
        self.clock_s += 1.0 / self.rate_at(arrival_s);
        self.issued += 1;

        let user = self.zipf.sample(&mut self.rng) as u64;
        // The user's id mix is a stable function of the user, so hot
        // users re-request the same hot ids — what makes a hot-ID cache
        // pay off — while the per-request sample still varies.
        let mut ids = Vec::with_capacity(self.cfg.ids_per_request);
        for _ in 0..self.cfg.ids_per_request {
            if self.rng.bernoulli(self.cfg.miss_rate) {
                ids.push(MISS_ID_BASE + self.rng.gen_range(1 << 20));
            } else {
                let span = (self.catalog.len() as u64 / 8).max(1);
                let base = user.wrapping_mul(0x9E37_79B9_7F4A_7C15) % self.catalog.len() as u64;
                let off = self.rng.gen_range(span);
                ids.push(self.catalog[((base + off) % self.catalog.len() as u64) as usize]);
            }
        }
        Request {
            user,
            arrival_s,
            ids,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn catalog(n: u64) -> Vec<GlobalId> {
        (0..n).map(|i| i * 7 + 3).collect()
    }

    fn gen(cfg: TrafficConfig) -> TrafficGenerator {
        TrafficGenerator::new(cfg, catalog(512)).unwrap()
    }

    #[test]
    fn stream_is_deterministic_in_the_seed() {
        let cfg = TrafficConfig {
            users: 10_000,
            ..TrafficConfig::default()
        };
        let mut a = gen(cfg.clone());
        let mut b = gen(cfg.clone());
        let mut c = gen(TrafficConfig { seed: 1, ..cfg });
        let ra: Vec<Request> = (0..64).map(|_| a.next_request()).collect();
        let rb: Vec<Request> = (0..64).map(|_| b.next_request()).collect();
        let rc: Vec<Request> = (0..64).map(|_| c.next_request()).collect();
        for (x, y) in ra.iter().zip(rb.iter()) {
            assert_eq!(x.user, y.user);
            assert_eq!(x.ids, y.ids);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        }
        assert!(
            ra.iter().zip(rc.iter()).any(|(x, y)| x.ids != y.ids),
            "different seeds should diverge"
        );
    }

    #[test]
    fn user_popularity_is_zipf_skewed() {
        let mut g = gen(TrafficConfig {
            users: 1000,
            alpha: 1.2,
            miss_rate: 0.0,
            ..TrafficConfig::default()
        });
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            counts[g.next_request().user as usize] += 1;
        }
        assert!(
            counts[0] > 20 * counts[500].max(1),
            "head user {} vs mid user {}",
            counts[0],
            counts[500]
        );
    }

    #[test]
    fn diurnal_rate_swings_and_arrivals_follow_it() {
        let cfg = TrafficConfig {
            qps: 100.0,
            burst_amplitude: 0.5,
            day_seconds: 40.0,
            ..TrafficConfig::default()
        };
        let g = gen(cfg);
        // Peak at quarter-day, trough at three-quarter-day.
        let peak = g.rate_at(10.0);
        let trough = g.rate_at(30.0);
        assert!((peak - 150.0).abs() < 1e-9, "peak {peak}");
        assert!((trough - 50.0).abs() < 1e-9, "trough {trough}");
        // Arrival gaps shrink at the peak: issue through a quarter day
        // and check the local gap tracks 1/rate.
        let mut g = gen(TrafficConfig {
            qps: 100.0,
            burst_amplitude: 0.5,
            day_seconds: 40.0,
            ..TrafficConfig::default()
        });
        let mut prev = g.next_request().arrival_s;
        let mut min_gap = f64::MAX;
        let mut max_gap: f64 = 0.0;
        for _ in 0..4000 {
            let t = g.next_request().arrival_s;
            let gap = t - prev;
            min_gap = min_gap.min(gap);
            max_gap = max_gap.max(gap);
            prev = t;
        }
        assert!(min_gap > 0.0);
        assert!(
            max_gap > 2.5 * min_gap,
            "diurnal swing should separate gaps: min {min_gap} max {max_gap}"
        );
    }

    #[test]
    fn miss_rate_controls_fabricated_ids() {
        let mut g = gen(TrafficConfig {
            miss_rate: 0.25,
            ids_per_request: 16,
            ..TrafficConfig::default()
        });
        let cat: std::collections::HashSet<GlobalId> = catalog(512).into_iter().collect();
        let mut total = 0usize;
        let mut missing = 0usize;
        for _ in 0..2000 {
            for id in g.next_request().ids {
                total += 1;
                if !cat.contains(&id) {
                    missing += 1;
                    assert!(id >= MISS_ID_BASE, "fabricated ids live at the top");
                }
            }
        }
        let frac = missing as f64 / total as f64;
        assert!((frac - 0.25).abs() < 0.02, "miss fraction {frac}");
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        let ok = TrafficConfig::default();
        assert!(ok.validate().is_ok());
        assert!(TrafficConfig { users: 0, ..ok.clone() }.validate().is_err());
        assert!(TrafficConfig { qps: 0.0, ..ok.clone() }.validate().is_err());
        assert!(TrafficConfig { burst_amplitude: 1.0, ..ok.clone() }
            .validate()
            .is_err());
        assert!(TrafficConfig { miss_rate: 1.5, ..ok.clone() }.validate().is_err());
        assert!(TrafficConfig { ids_per_request: 0, ..ok }.validate().is_err());
        assert!(TrafficGenerator::new(TrafficConfig::default(), vec![]).is_err());
    }
}
