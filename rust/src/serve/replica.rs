//! Read-optimized serving replica over the base + delta sync dir.
//!
//! The trainer shards its embedding state across `world` ranks because
//! training is write-heavy; serving is read-heavy and single-host here,
//! so the replica **folds all rank shards into one striped table per
//! merge group** — a lookup is one hash, no shard routing. Optimizer
//! state is deliberately dropped on the serving side (Adam `m`/`v`/`t`
//! never influence inference); the row-content checksum still matches
//! the trainer's report bit-for-bit, which is the witness the tests
//! pin. Compaction (`super::compact`), which must preserve Adam bits,
//! keeps per-rank tables instead.
//!
//! Bootstrap = newest valid `base_<seq:05>` + the validated delta chain
//! on top ([`validate_chain`] — gapped or torn chains are a hard error,
//! never a silently stale replica). [`ServingReplica::refresh`] picks
//! up deltas the trainer published since, invalidating the hot-ID
//! cache for every id a delta touches before the rows become servable.
//! A refresh that trips on a gapped or torn chain degrades gracefully:
//! every load is staged before any install, so the replica keeps
//! serving its last good state, counts the failure in
//! [`ReplicaStats::refresh_failures`] and surfaces the message in
//! [`ReplicaStats::last_refresh_error`] — only bootstrap is hard-fail.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::checkpoint::delta::{
    delta_dir, load_delta_group_dims, load_delta_precision_policy, load_delta_shard_group,
    validate_chain, DeltaMeta,
};
use crate::checkpoint::{
    load_dense, load_group_dims, load_precision_policy, load_sparse_shard_group, SparseRow,
};
use crate::embedding::concurrent::ConcurrentDynamicTable;
use crate::embedding::dynamic_table::DynamicTableConfig;
use crate::embedding::precision::PrecisionPolicy;
use crate::embedding::GlobalId;
use crate::runtime::{Engine, Tensor};
use crate::serve::cache::HotIdCache;
use crate::serve::compact::{base_dir, latest_base, recover_leftovers};

/// Sizing knobs for the replica's tables and cache.
#[derive(Clone, Debug)]
pub struct ReplicaOptions {
    /// Initial capacity of each merge group's folded table.
    pub capacity: usize,
    /// Lock stripes per table (reads are shared; stripes only matter
    /// while a refresh is applying a delta).
    pub stripes: usize,
    /// Hot-ID cache slots per merge group (rounded to a power of two).
    pub cache_slots: usize,
}

impl Default for ReplicaOptions {
    fn default() -> Self {
        ReplicaOptions {
            capacity: 1 << 14,
            stripes: 8,
            cache_slots: 1 << 12,
        }
    }
}

/// Serving-side counters, reported alongside bench latencies.
#[derive(Clone, Debug, Default)]
pub struct ReplicaStats {
    pub lookups: u64,
    /// Lookups answered from table or cache.
    pub resident: u64,
    /// Lookups for ids the trainer never shipped (served as zeros).
    pub missing: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_inserts: u64,
    pub cache_invalidations: u64,
    pub deltas_applied: u64,
    /// Refreshes that failed (gapped or torn chain) with the replica
    /// kept serving its last good state.
    pub refresh_failures: u64,
    /// The most recent refresh failure, for operators polling stats.
    pub last_refresh_error: Option<String>,
}

/// One folded, continuously-refreshed copy of the trainer's state.
pub struct ServingReplica {
    dir: PathBuf,
    opts: ReplicaOptions,
    model: String,
    world: usize,
    param_count: usize,
    group_dims: Vec<usize>,
    /// Precision policy recorded by the snapshots being served (the
    /// disabled fp32 policy for chains that never wrote the keys).
    /// Mixed chains carry cold rows already on the f16 grid; installs
    /// copy bits verbatim, so serving needs no dequantization step.
    precision: PrecisionPolicy,
    /// One table per merge group, all ranks folded in.
    tables: Vec<ConcurrentDynamicTable>,
    caches: Vec<HotIdCache>,
    /// Replicated dense params from the newest applied snapshot.
    dense: Vec<f32>,
    applied_seq: u64,
    applied_step: u64,
    lookups: u64,
    resident: u64,
    missing: u64,
    deltas_applied: u64,
    refresh_failures: u64,
    last_refresh_error: Option<String>,
    scratch: Vec<f32>,
}

/// One delta fully loaded (and CRC-checked) into memory, not yet
/// installed — the staging half of the refresh path's all-or-nothing
/// apply.
struct StagedDelta {
    meta: DeltaMeta,
    /// `(group, upserts, removed)` in (rank, group)-major order — the
    /// same order the bootstrap apply uses.
    shards: Vec<(usize, Vec<SparseRow>, Vec<GlobalId>)>,
}

impl ServingReplica {
    /// Bootstrap from `dir`: sweep crash leftovers, install the newest
    /// base (if any), then replay the validated delta chain. Errors when
    /// the dir holds nothing servable or the chain is gapped/malformed.
    pub fn open(dir: &Path, opts: ReplicaOptions) -> Result<ServingReplica> {
        recover_leftovers(dir)?;
        let base = latest_base(dir)?;
        let (base_seq, base_step) = base.as_ref().map_or((0, 0), |(s, m)| (*s, m.step));
        let chain = validate_chain(dir, base_seq, base_step)?;

        // Snapshot-format facts come from the newest state present.
        let (model, world, param_count, group_dims, precision, dense_from) =
            match (&base, chain.last()) {
                (_, Some(m)) => {
                    if let Some((bseq, bm)) = &base {
                        anyhow::ensure!(
                            bm.world == m.world && bm.param_count == m.param_count,
                            "base_{bseq:05} and the delta chain disagree on world/params"
                        );
                    }
                    (
                        m.model.clone(),
                        m.world,
                        m.param_count,
                        load_delta_group_dims(dir, m)?,
                        load_delta_precision_policy(dir, m.seq)?,
                        delta_dir(dir, m.seq),
                    )
                }
                (Some((seq, bm)), None) => (
                    bm.model.clone(),
                    bm.world,
                    bm.param_count,
                    load_group_dims(&base_dir(dir, *seq), bm)?,
                    load_precision_policy(&base_dir(dir, *seq))?,
                    base_dir(dir, *seq),
                ),
                (None, None) => bail!(
                    "nothing to serve under {}: no base and no delta snapshots",
                    dir.display()
                ),
            };

        let tables: Vec<ConcurrentDynamicTable> = group_dims
            .iter()
            .map(|&d| {
                ConcurrentDynamicTable::new(
                    DynamicTableConfig::new(d)
                        .with_capacity(opts.capacity)
                        .with_seed(0),
                    opts.stripes,
                )
                .with_precision(precision)
            })
            .collect();
        let caches: Vec<HotIdCache> = group_dims
            .iter()
            .map(|&d| HotIdCache::new(opts.cache_slots, d))
            .collect();

        let mut replica = ServingReplica {
            dir: dir.to_path_buf(),
            opts,
            model,
            world,
            param_count,
            group_dims,
            precision,
            tables,
            caches,
            dense: Vec::new(),
            applied_seq: base_seq,
            applied_step: base_step,
            lookups: 0,
            resident: 0,
            missing: 0,
            deltas_applied: 0,
            refresh_failures: 0,
            last_refresh_error: None,
            scratch: Vec::new(),
        };

        if let Some((seq, bm)) = &base {
            let bdims = load_group_dims(&base_dir(dir, *seq), bm)?;
            anyhow::ensure!(
                bdims == replica.group_dims,
                "base_{seq:05} group dims {bdims:?} disagree with the chain's {:?}",
                replica.group_dims
            );
            let bprec = load_precision_policy(&base_dir(dir, *seq))?;
            anyhow::ensure!(
                bprec == replica.precision,
                "base_{seq:05} precision policy {bprec:?} disagrees with the \
                 chain's {:?}",
                replica.precision
            );
            for rank in 0..bm.world {
                for g in 0..replica.group_dims.len() {
                    let rows =
                        load_sparse_shard_group(&base_dir(dir, *seq), bm, bm.world, rank, g)?;
                    for r in rows {
                        replica.tables[g].set_row_scratch(r.id, &r.row, &mut replica.scratch);
                    }
                }
            }
        }
        for m in &chain {
            replica.apply_one(m)?;
        }
        let (dense, _) = load_dense(&dense_from, replica.param_count)
            .context("load dense params for serving")?;
        replica.dense = dense;
        Ok(replica)
    }

    /// Load one delta's every shard into memory, CRC-checked, without
    /// touching the tables — the failure-safe half of an apply.
    fn stage_one(&self, m: &DeltaMeta) -> Result<StagedDelta> {
        let dims = load_delta_group_dims(&self.dir, m)?;
        anyhow::ensure!(
            dims == self.group_dims,
            "delta_{:05} group dims {dims:?} disagree with the replica's {:?}",
            m.seq,
            self.group_dims
        );
        // A trainer restarted with different --precision flags mid-chain
        // must not silently reach serving: the stored grids would mix.
        let prec = load_delta_precision_policy(&self.dir, m.seq)?;
        anyhow::ensure!(
            prec == self.precision,
            "delta_{:05} precision policy {prec:?} disagrees with the replica's {:?}",
            m.seq,
            self.precision
        );
        let mut shards = Vec::with_capacity(m.world * self.group_dims.len());
        for rank in 0..m.world {
            for g in 0..self.group_dims.len() {
                let (rows, removed) = load_delta_shard_group(&self.dir, m, rank, g)?;
                shards.push((g, rows, removed));
            }
        }
        Ok(StagedDelta {
            meta: m.clone(),
            shards,
        })
    }

    /// Install a staged delta, invalidating every touched id in the hot
    /// cache *before* its new state becomes servable. Infallible: every
    /// load already happened in [`Self::stage_one`].
    fn install_one(&mut self, d: StagedDelta) {
        for (g, rows, removed) in d.shards {
            for id in removed {
                self.caches[g].invalidate(id);
                self.tables[g].remove(id);
            }
            for r in rows {
                self.caches[g].invalidate(r.id);
                self.tables[g].set_row_scratch(r.id, &r.row, &mut self.scratch);
            }
        }
        self.applied_seq = d.meta.seq;
        self.applied_step = d.meta.step;
        self.deltas_applied += 1;
    }

    /// Fold one delta into the tables (bootstrap path — errors here are
    /// hard failures in [`Self::open`]).
    fn apply_one(&mut self, m: &DeltaMeta) -> Result<()> {
        let staged = self.stage_one(m)?;
        self.install_one(staged);
        Ok(())
    }

    /// Consume any deltas published since the last apply; returns how
    /// many were folded in. A gapped or torn chain is an error, but a
    /// **serving-safe** one: every load is staged before any install,
    /// so the replica keeps serving its last good state untouched, the
    /// failure is counted in [`ReplicaStats::refresh_failures`], and
    /// the message lands in [`ReplicaStats::last_refresh_error`] for
    /// operators who only poll stats. (Bootstrap via [`Self::open`]
    /// stays hard-fail: there is no good state to fall back to.)
    pub fn refresh(&mut self) -> Result<usize> {
        match self.try_refresh() {
            Ok(n) => Ok(n),
            Err(e) => {
                self.refresh_failures += 1;
                self.last_refresh_error = Some(format!("{e:#}"));
                Err(e)
            }
        }
    }

    fn try_refresh(&mut self) -> Result<usize> {
        let chain = validate_chain(&self.dir, self.applied_seq, self.applied_step)?;
        // Stage everything — every delta's shards and the newest dense
        // params — before mutating anything, so a torn file surfacing
        // mid-chain can never leave the replica half-refreshed.
        let staged: Vec<StagedDelta> = chain
            .iter()
            .map(|m| self.stage_one(m))
            .collect::<Result<_>>()?;
        let dense = match chain.last() {
            Some(m) => Some(load_dense(&delta_dir(&self.dir, m.seq), self.param_count)?.0),
            None => None,
        };
        let n = staged.len();
        for d in staged {
            self.install_one(d);
        }
        if let Some(d) = dense {
            self.dense = d;
        }
        Ok(n)
    }

    /// Embedding lookup through the hot-ID cache. Returns `true` when
    /// `id` is resident; unknown ids zero-fill `out` (cold items serve
    /// the zero embedding, they don't fail the request).
    pub fn lookup(&mut self, group: usize, id: GlobalId, out: &mut [f32]) -> bool {
        self.lookups += 1;
        if self.caches[group].get(id, out) {
            self.resident += 1;
            return true;
        }
        if self.tables[group].lookup(id, out) {
            self.caches[group].insert(id, out);
            self.resident += 1;
            true
        } else {
            out.fill(0.0);
            self.missing += 1;
            false
        }
    }

    /// Dense forward over one micro-batch of id sequences (all from
    /// merge group `group`). The batch is padded up to the engine's
    /// smallest fitting shape bucket — padding rows get length 0, which
    /// the kernels treat as an empty sequence. Returns `tasks` logits
    /// per real request (padding logits are sliced off).
    pub fn forward(
        &mut self,
        engine: &Engine,
        group: usize,
        batch: &[&[GlobalId]],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(!batch.is_empty(), "empty micro-batch");
        let arts = engine.manifest().model(&self.model)?.clone();
        let d = self.group_dims[group];
        anyhow::ensure!(
            d == arts.emb_dim,
            "merge group {group} has dim {d} but model `{}` consumes {}-dim embeddings",
            self.model,
            arts.emb_dim
        );
        let max_len = batch.iter().map(|ids| ids.len()).max().unwrap_or(0);
        let bucket = match arts.pick_bucket(batch.len(), max_len) {
            Some(b) => b,
            None => {
                let b = arts.largest_bucket();
                anyhow::ensure!(
                    batch.len() <= b.batch && max_len <= b.len,
                    "micro-batch {}x{max_len} exceeds the largest shape bucket {}x{}",
                    batch.len(),
                    b.batch,
                    b.len
                );
                b
            }
        };
        let (bb, bl) = (bucket.batch, bucket.len);
        let mut emb = vec![0.0f32; bb * bl * d];
        let mut lengths = vec![0i32; bb];
        for (i, ids) in batch.iter().enumerate() {
            lengths[i] = ids.len() as i32;
            for (j, &id) in ids.iter().enumerate() {
                let off = (i * bl + j) * d;
                self.lookup(group, id, &mut emb[off..off + d]);
            }
        }
        let dense = self.dense.clone();
        let logits = engine.forward(
            &self.model,
            (bb, bl),
            &dense,
            Tensor::f32(&[bb, bl, d], emb),
            lengths,
        )?;
        Ok(logits[..batch.len() * arts.tasks].to_vec())
    }

    /// Live ids of merge group `group` — the traffic generator's
    /// resident-id catalog.
    pub fn live_ids(&self, group: usize) -> Vec<GlobalId> {
        let mut ids = self.tables[group].live_ids();
        ids.sort_unstable();
        ids
    }

    /// Wrapping sum of the group tables' content checksums — directly
    /// comparable to the trainer report's `embedding_checksum` at the
    /// replica's applied step.
    pub fn content_checksum(&self) -> u64 {
        self.tables
            .iter()
            .fold(0u64, |acc, t| acc.wrapping_add(t.content_checksum()))
    }

    pub fn resident_rows(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }

    pub fn groups(&self) -> usize {
        self.group_dims.len()
    }

    pub fn group_dim(&self, group: usize) -> usize {
        self.group_dims[group]
    }

    /// Precision policy recorded by the snapshots being served.
    pub fn precision(&self) -> PrecisionPolicy {
        self.precision
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn applied_seq(&self) -> u64 {
        self.applied_seq
    }

    pub fn applied_step(&self) -> u64 {
        self.applied_step
    }

    pub fn cache_slots(&self) -> usize {
        self.opts.cache_slots
    }

    pub fn stats(&self) -> ReplicaStats {
        let mut s = ReplicaStats {
            lookups: self.lookups,
            resident: self.resident,
            missing: self.missing,
            deltas_applied: self.deltas_applied,
            refresh_failures: self.refresh_failures,
            last_refresh_error: self.last_refresh_error.clone(),
            ..ReplicaStats::default()
        };
        for c in &self.caches {
            let (h, m, i, inv) = c.counters();
            s.cache_hits += h;
            s.cache_misses += m;
            s.cache_inserts += i;
            s.cache_invalidations += inv;
        }
        s
    }
}
