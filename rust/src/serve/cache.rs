//! Hot-ID lookup cache for the serving replica.
//!
//! Production feature-ID popularity is heavily Zipf-skewed: a small hot
//! head of ids absorbs most of the lookup traffic. [`HotIdCache`] is a
//! direct-mapped, power-of-two-slot cache in front of the replica's
//! striped group tables — one hash, one tag compare, one row copy on a
//! hit; no locks (the replica serves lookups from one thread per cache)
//! and no steady-state allocation. Collisions simply overwrite: the
//! Zipf head keeps its slots warm while the long tail churns through
//! the rest, which is exactly the behavior a bounded serving cache
//! wants.
//!
//! Freshness contract: the replica **invalidates** every id a consumed
//! delta upserts or removes ([`HotIdCache::invalidate`]) before the
//! table mutation becomes visible to lookups, so the cache can never
//! serve bits older than the applied sync state. Hit/miss/invalidation
//! counters feed the serve report.

use crate::embedding::hash::hash_id;
use crate::embedding::GlobalId;

const SLOT_SEED: u64 = 0x5EED_CAC4E;

/// Direct-mapped id → row cache with hit-rate counters.
pub struct HotIdCache {
    dim: usize,
    mask: u64,
    /// `id + 1` per slot; 0 = empty (GlobalId::MAX is never cached).
    tags: Vec<u64>,
    rows: Vec<f32>,
    hits: u64,
    misses: u64,
    inserts: u64,
    invalidations: u64,
}

impl HotIdCache {
    /// `slots` is rounded up to the next power of two (min 1).
    pub fn new(slots: usize, dim: usize) -> Self {
        assert!(dim > 0, "cache dim must be positive");
        let slots = slots.max(1).next_power_of_two();
        HotIdCache {
            dim,
            mask: (slots - 1) as u64,
            tags: vec![0; slots],
            rows: vec![0.0; slots * dim],
            hits: 0,
            misses: 0,
            inserts: 0,
            invalidations: 0,
        }
    }

    pub fn slots(&self) -> usize {
        self.tags.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn slot(&self, id: GlobalId) -> usize {
        (hash_id(id, SLOT_SEED) & self.mask) as usize
    }

    /// Copy the cached row for `id` into `out` (a hit); `false` counts
    /// a miss and leaves `out` untouched.
    pub fn get(&mut self, id: GlobalId, out: &mut [f32]) -> bool {
        debug_assert_eq!(out.len(), self.dim);
        let s = self.slot(id);
        if self.tags[s] == id.wrapping_add(1) {
            out.copy_from_slice(&self.rows[s * self.dim..(s + 1) * self.dim]);
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Install `row` for `id` (read-through fill after a table hit).
    pub fn insert(&mut self, id: GlobalId, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        let s = self.slot(id);
        self.tags[s] = id.wrapping_add(1);
        self.rows[s * self.dim..(s + 1) * self.dim].copy_from_slice(row);
        self.inserts += 1;
    }

    /// Drop `id`'s slot if it holds `id` — called for every id a delta
    /// upserts or removes, so a consumed sync can never leave stale
    /// bits servable.
    pub fn invalidate(&mut self, id: GlobalId) {
        let s = self.slot(id);
        if self.tags[s] == id.wrapping_add(1) {
            self.tags[s] = 0;
            self.invalidations += 1;
        }
    }

    /// `(hits, misses, inserts, invalidations)` since construction.
    pub fn counters(&self) -> (u64, u64, u64, u64) {
        (self.hits, self.misses, self.inserts, self.invalidations)
    }

    /// Hit fraction of all `get` calls; 0 when nothing was asked.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_through_hit_after_insert() {
        let mut c = HotIdCache::new(64, 4);
        let mut out = vec![0.0f32; 4];
        assert!(!c.get(7, &mut out), "cold cache misses");
        c.insert(7, &[1.0, 2.0, 3.0, 4.0]);
        assert!(c.get(7, &mut out));
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.counters(), (1, 1, 1, 0));
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn invalidate_drops_only_the_matching_id() {
        let mut c = HotIdCache::new(64, 2);
        c.insert(3, &[0.5, 0.5]);
        // Invalidate an id that is not resident in slot terms: no-op.
        c.invalidate(999_999);
        let mut out = vec![0.0f32; 2];
        // (unless 999_999 collides with 3's slot AND holds the tag —
        // tags are exact, so id 3 survives either way)
        assert!(c.get(3, &mut out));
        c.invalidate(3);
        assert!(!c.get(3, &mut out), "stale bits are not servable");
        assert_eq!(c.counters().3, 1);
    }

    #[test]
    fn collisions_overwrite_instead_of_growing() {
        let mut c = HotIdCache::new(1, 2); // one slot: everything collides
        c.insert(1, &[1.0, 1.0]);
        c.insert(2, &[2.0, 2.0]);
        let mut out = vec![0.0f32; 2];
        assert!(!c.get(1, &mut out), "evicted by the collision");
        assert!(c.get(2, &mut out));
        assert_eq!(out, vec![2.0, 2.0]);
        assert_eq!(c.slots(), 1);
    }

    #[test]
    fn slots_round_up_to_power_of_two() {
        assert_eq!(HotIdCache::new(0, 1).slots(), 1);
        assert_eq!(HotIdCache::new(3, 1).slots(), 4);
        assert_eq!(HotIdCache::new(1024, 1).slots(), 1024);
    }
}
