//! Unix-domain-socket mesh implementing [`RemoteTransport`].
//!
//! Topology: every rank binds `peer_<rank>.sock` in the shared socket
//! dir and runs an acceptor; for each ordered pair `(src, dst)` the
//! *source* connects to the destination's socket, so a full mesh is
//! `world * (world - 1)` streams, each carrying all lanes (the frame
//! header demultiplexes). Connections open with the
//! [`wire::write_hello`] handshake so the acceptor knows the source
//! rank and can refuse strays from a previous incarnation.
//!
//! Deadlock freedom: [`RemoteTransport::send`] must never wait on the
//! peer (the collectives post all sends before any receive, but two
//! ranks writing large frames head-on would still deadlock on raw
//! sockets). Each destination therefore gets a dedicated writer thread
//! fed by an unbounded channel — `send` enqueues and returns. Each
//! source gets a dedicated reader thread that demultiplexes frames into
//! per-`(lane, src)` FIFO queues under one mutex + condvar.
//!
//! Failure semantics: a reader hitting EOF or a corrupt frame poisons
//! *every* lane of its source, so any blocked `recv` fails loudly
//! ("peer disconnected") instead of hanging; the communicator panics,
//! the worker dies nonzero, and the supervisor's recovery path takes
//! over. A `recv` that sees neither data nor poison for 120 s bails —
//! a wedged-but-alive peer must not hang CI forever.
//!
//! Fault injection ([`FaultPlan`]) hooks the send path: `drop` makes
//! one frame fail transiently (recovered by [`retry`] and counted in
//! `retries()`), `delay` sleeps before one frame. Neither changes the
//! bytes that ultimately flow, so drills stay bit-identical.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::collective::comm::{Message, RemoteTransport, LANES};
use crate::util::retry::{retry, RetryPolicy};

use super::fault::FaultPlan;
use super::wire;

/// Lanes provisioned per ordered pair: the posted lanes plus the
/// pseudo-lane the blocking reduce/broadcast collectives use.
pub const TRANSPORT_LANES: usize = LANES + 1;

/// How long a `recv` waits before declaring the run wedged.
const RECV_STALL: Duration = Duration::from_secs(120);

/// Socket path for `rank`'s acceptor. Callers should keep `dir` short:
/// `sockaddr_un` caps UDS paths at ~108 bytes.
pub fn sock_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("peer_{rank}.sock"))
}

/// Inbound demultiplexer: one FIFO per `(lane, src)`, poisoned wholesale
/// when the source's stream dies.
struct Inbox {
    world: usize,
    /// Flattened `[lane][src]`; `Err(())` is the poison marker.
    slots: Mutex<Vec<VecDeque<Result<Message, ()>>>>,
    cv: Condvar,
}

impl Inbox {
    fn new(world: usize) -> Self {
        Inbox {
            world,
            slots: Mutex::new(
                (0..TRANSPORT_LANES * world)
                    .map(|_| VecDeque::new())
                    .collect(),
            ),
            cv: Condvar::new(),
        }
    }

    fn push(&self, lane: usize, src: usize, msg: Message) {
        let mut slots = self.slots.lock().unwrap();
        slots[lane * self.world + src].push_back(Ok(msg));
        self.cv.notify_all();
    }

    /// Mark `src` lost on every lane so all pending and future receives
    /// from it fail fast.
    fn poison(&self, src: usize) {
        let mut slots = self.slots.lock().unwrap();
        for lane in 0..TRANSPORT_LANES {
            slots[lane * self.world + src].push_back(Err(()));
        }
        self.cv.notify_all();
    }

    fn recv(&self, lane: usize, src: usize) -> Result<Message> {
        let mut slots = self.slots.lock().unwrap();
        loop {
            match slots[lane * self.world + src].pop_front() {
                Some(Ok(msg)) => return Ok(msg),
                Some(Err(())) => {
                    // Keep the queue poisoned for any later receive.
                    slots[lane * self.world + src].push_front(Err(()));
                    anyhow::bail!("peer rank {src} disconnected mid-run (lane {lane})");
                }
                None => {
                    let (guard, wait) = self.cv.wait_timeout(slots, RECV_STALL).unwrap();
                    slots = guard;
                    if wait.timed_out() && slots[lane * self.world + src].is_empty() {
                        anyhow::bail!(
                            "recv from rank {src} on lane {lane} stalled for {}s — \
                             peer wedged or collective schedule mismatch",
                            RECV_STALL.as_secs()
                        );
                    }
                }
            }
        }
    }
}

/// The UDS mesh transport for one rank. Construct with [`connect`],
/// then hand to [`crate::collective::CommHandle::from_remote`].
///
/// [`connect`]: SocketTransport::connect
pub struct SocketTransport {
    rank: usize,
    world: usize,
    inbox: Arc<Inbox>,
    /// Per-destination writer-thread feeds (`None` at `self.rank`).
    senders: Vec<Option<Sender<(u8, Message)>>>,
    /// Loopback queues per lane: self-sends never touch the wire.
    self_q: Vec<VecDeque<Message>>,
    /// Outbound remote frames sent so far (fault frame indices).
    frames: u64,
    /// Frame index that must fail transiently once (from the plan).
    drop_at: Option<u64>,
    /// `(frame index, ms)` to sleep before sending (from the plan).
    delay_at: Option<(u64, u64)>,
    retries: u64,
}

impl SocketTransport {
    /// Join the mesh: bind our socket, accept `world - 1` valid inbound
    /// streams in the background, and connect (with deterministic
    /// retry/backoff — peers may still be binding) to every other rank.
    /// `fault` is this rank's slice of the drill plan; clauses aimed at
    /// other ranks are ignored here.
    pub fn connect(
        dir: &Path,
        rank: usize,
        world: usize,
        incarnation: u32,
        fault: Option<&FaultPlan>,
    ) -> Result<SocketTransport> {
        anyhow::ensure!(world >= 1 && rank < world, "bad rank {rank} of {world}");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create socket dir {}", dir.display()))?;
        let my_path = sock_path(dir, rank);
        // Unlink any stale socket from a previous incarnation before
        // binding, or bind fails with AddrInUse.
        let _ = std::fs::remove_file(&my_path);
        let listener = UnixListener::bind(&my_path)
            .with_context(|| format!("bind {}", my_path.display()))?;

        let inbox = Arc::new(Inbox::new(world));
        if world > 1 {
            let acceptor_inbox = Arc::clone(&inbox);
            let expected = world - 1;
            std::thread::spawn(move || {
                let mut accepted = 0usize;
                while accepted < expected {
                    let Ok((mut stream, _)) = listener.accept() else {
                        return;
                    };
                    // A peer from a previous incarnation (or garbage)
                    // is dropped; keep accepting until the real mesh
                    // is complete.
                    let Ok((src, inc)) = wire::read_hello(&mut stream) else {
                        continue;
                    };
                    if inc != incarnation || src as usize >= world {
                        continue;
                    }
                    accepted += 1;
                    let reader_inbox = Arc::clone(&acceptor_inbox);
                    let src = src as usize;
                    std::thread::spawn(move || reader_main(stream, src, reader_inbox));
                }
            });
        }

        let mut senders: Vec<Option<Sender<(u8, Message)>>> = vec![None; world];
        let mut retries = 0u64;
        for dst in 0..world {
            if dst == rank {
                continue;
            }
            let path = sock_path(dir, dst);
            // Generous budget: peers start concurrently and may take a
            // while to bind under load. Seed mixes the pair so retriers
            // desynchronize deterministically.
            let policy = RetryPolicy {
                max_attempts: 400,
                base_delay_ms: 5,
                max_delay_ms: 100,
                seed: 0x5EED ^ ((rank as u64) << 16) ^ dst as u64,
            };
            let (mut stream, r) = retry(
                &policy,
                &format!("rank {rank} connect to rank {dst}"),
                |_| UnixStream::connect(&path),
            )?;
            retries += r;
            wire::write_hello(&mut stream, rank as u32, incarnation)?;
            let (tx, rx) = std::sync::mpsc::channel::<(u8, Message)>();
            std::thread::spawn(move || {
                let mut w = BufWriter::new(stream);
                for (lane, msg) in rx {
                    if wire::write_frame(&mut w, lane, &msg).is_err() || w.flush().is_err() {
                        return; // peer gone; its supervisor handles it
                    }
                }
            });
            senders[dst] = Some(tx);
        }

        let mine = |r: usize| r == rank;
        let (drop_at, delay_at) = match fault {
            Some(plan) => (
                plan.drop_frame.filter(|d| mine(d.rank)).map(|d| d.frame),
                plan.delay.filter(|d| mine(d.rank)).map(|d| (d.frame, d.ms)),
            ),
            None => (None, None),
        };

        Ok(SocketTransport {
            rank,
            world,
            inbox,
            senders,
            self_q: (0..TRANSPORT_LANES).map(|_| VecDeque::new()).collect(),
            frames: 0,
            drop_at,
            delay_at,
            retries,
        })
    }
}

fn reader_main(stream: UnixStream, src: usize, inbox: Arc<Inbox>) {
    let mut r = BufReader::new(stream);
    loop {
        match wire::read_frame(&mut r) {
            Ok((lane, msg)) if (lane as usize) < TRANSPORT_LANES => {
                inbox.push(lane as usize, src, msg);
            }
            _ => {
                inbox.poison(src);
                return;
            }
        }
    }
}

impl RemoteTransport for SocketTransport {
    fn send(&mut self, lane: usize, dst: usize, msg: Message) -> Result<()> {
        anyhow::ensure!(
            lane < TRANSPORT_LANES && dst < self.world,
            "send lane {lane} dst {dst} out of range"
        );
        if dst == self.rank {
            self.self_q[lane].push_back(msg);
            return Ok(());
        }
        let frame = self.frames;
        self.frames += 1;
        if let Some((at, ms)) = self.delay_at {
            if at == frame {
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        let inject_drop = self.drop_at == Some(frame);
        let sender = self.senders[dst]
            .as_ref()
            .expect("sender exists for every remote dst");
        let (_, r) = retry(
            &RetryPolicy::default(),
            &format!("send frame {frame} to rank {dst}"),
            |attempt| {
                if inject_drop && attempt == 0 {
                    return Err(format!("injected transient drop of frame {frame}"));
                }
                sender
                    .send((lane as u8, msg.clone()))
                    .map_err(|_| format!("writer thread for rank {dst} is gone"))
            },
        )?;
        self.retries += r;
        Ok(())
    }

    fn recv(&mut self, lane: usize, src: usize) -> Result<Message> {
        anyhow::ensure!(
            lane < TRANSPORT_LANES && src < self.world,
            "recv lane {lane} src {src} out of range"
        );
        if src == self.rank {
            return self.self_q[lane]
                .pop_front()
                .context("self-recv on an empty lane (collective schedule bug)");
        }
        self.inbox.recv(lane, src)
    }

    fn retries(&self) -> u64 {
        self.retries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::CommHandle;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mtgr_tp_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Run `f` on `world` in-process "ranks", each over its own socket
    /// transport, and return the per-rank results.
    fn run_mesh<T: Send + 'static>(
        dir: &Path,
        world: usize,
        fault: Option<FaultPlan>,
        f: impl Fn(CommHandle) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = dir.to_path_buf();
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    let tp =
                        SocketTransport::connect(&dir, rank, world, 0, fault.as_ref()).unwrap();
                    f(CommHandle::from_remote(rank, world, Box::new(tp)))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn world3_collectives_over_sockets() {
        let dir = tmp_dir("w3");
        let out = run_mesh(&dir, 3, None, |mut comm| {
            let rank = comm.rank;
            // all_gather exercises LANE_DEFAULT all-to-all.
            let gathered = comm.all_gather_u64(100 + rank as u64);
            // all_reduce exercises the REDUCE_LANE pseudo-lane.
            let mut acc = [rank as f32, 1.0];
            comm.all_reduce_sum(&mut acc);
            // Directed all-to-all with distinct payloads per pair.
            let chunks: Vec<Message> = (0..3)
                .map(|dst| Message::Ids(vec![(rank * 10 + dst) as u64]))
                .collect();
            let got = comm.all_to_all(chunks);
            comm.barrier();
            (gathered, acc, got)
        });
        for (rank, (gathered, acc, got)) in out.into_iter().enumerate() {
            assert_eq!(gathered, vec![100, 101, 102]);
            assert_eq!(acc, [3.0, 3.0], "0+1+2 and 1+1+1");
            for src in 0..3 {
                assert_eq!(
                    got[src],
                    Message::Ids(vec![(src * 10 + rank) as u64]),
                    "rank {rank} from {src}"
                );
            }
        }
    }

    #[test]
    fn drop_and_delay_faults_recover_with_identical_bytes() {
        let clean_dir = tmp_dir("clean");
        let clean = run_mesh(&clean_dir, 2, None, |mut comm| {
            let g = comm.all_gather_u64(comm.rank as u64 + 7);
            (g, comm.transport_retries())
        });
        let plan = FaultPlan::parse("drop:rank=0,frame=0;delay:rank=1,frame=0,ms=15").unwrap();
        let faulty_dir = tmp_dir("faulty");
        let faulty = run_mesh(&faulty_dir, 2, Some(plan), |mut comm| {
            let g = comm.all_gather_u64(comm.rank as u64 + 7);
            (g, comm.transport_retries())
        });
        for rank in 0..2 {
            assert_eq!(clean[rank].0, faulty[rank].0, "faults change no bytes");
        }
        assert_eq!(clean[0].1, 0, "clean run retries nothing");
        assert!(
            faulty[0].1 >= 1,
            "rank 0's dropped frame is retried and counted, got {}",
            faulty[0].1
        );
    }

    #[test]
    fn world1_is_pure_loopback() {
        let dir = tmp_dir("w1");
        let out = run_mesh(&dir, 1, None, |mut comm| {
            let mut x = [2.5f32];
            comm.all_reduce_sum(&mut x);
            (comm.all_gather_u64(9), x[0])
        });
        assert_eq!(out[0].0, vec![9]);
        assert_eq!(out[0].1, 2.5);
    }

    #[test]
    fn dead_peer_poisons_receives() {
        let dir = tmp_dir("dead");
        // Rank 1 connects and immediately drops its transport; rank 0's
        // recv must fail loudly instead of hanging.
        let d0 = dir.clone();
        let h0 = std::thread::spawn(move || {
            let mut tp = SocketTransport::connect(&d0, 0, 2, 0, None).unwrap();
            // Wait for the poison (EOF) to land.
            tp.recv(0, 1)
        });
        let d1 = dir.clone();
        let h1 = std::thread::spawn(move || {
            let tp = SocketTransport::connect(&d1, 1, 2, 0, None).unwrap();
            drop(tp); // writer channels close; streams EOF
        });
        h1.join().unwrap();
        let err = h0.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("disconnected"), "{err}");
    }
}
