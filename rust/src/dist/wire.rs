//! Byte-level wire formats for the multi-process runtime.
//!
//! Two tiny codecs share this file because they share discipline:
//! everything is length-prefixed little-endian, readers validate before
//! allocating, and a malformed byte is a loud error — never a silent
//! resync attempt (a desynchronized stream has no recoverable framing).
//!
//! * **Peer frames** carry [`Message`] payloads between worker ranks
//!   over the UDS mesh: `[lane u8][kind u8][len u32 LE][payload LE]`
//!   where `len` counts *elements*, not bytes.
//! * **Coordinator messages** carry the control protocol (register /
//!   welcome / heartbeat / barrier / bye) as `[tag u8][fields LE]`.
//! * The **hello** handshake (`[src u32 LE][incarnation u32 LE]`) opens
//!   every peer connection so the acceptor can demultiplex by source
//!   rank and drop strays from a previous incarnation.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::collective::comm::Message;

/// Frame payload kinds (the [`Message`] variants).
pub const KIND_EMPTY: u8 = 0;
pub const KIND_IDS: u8 = 1;
pub const KIND_FLOATS: u8 = 2;
pub const KIND_COUNTS: u8 = 3;

/// Sanity cap on the element count of a single frame. A corrupt or
/// desynchronized stream must fail fast instead of asking the allocator
/// for terabytes; 2^28 u64s (2 GiB) is far above any real exchange.
pub const MAX_FRAME_ELEMS: usize = 1 << 28;

/// Serialize one peer frame onto `w`. Does not flush — the caller's
/// writer loop flushes once per dequeued frame.
pub fn write_frame(w: &mut impl Write, lane: u8, msg: &Message) -> Result<()> {
    let (kind, len) = match msg {
        Message::Empty => (KIND_EMPTY, 0),
        Message::Ids(v) => (KIND_IDS, v.len()),
        Message::Floats(v) => (KIND_FLOATS, v.len()),
        Message::Counts(v) => (KIND_COUNTS, v.len()),
    };
    anyhow::ensure!(
        len <= MAX_FRAME_ELEMS,
        "frame of {len} elements exceeds the {MAX_FRAME_ELEMS} cap"
    );
    let mut buf = Vec::with_capacity(6 + len * 8);
    buf.push(lane);
    buf.push(kind);
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    match msg {
        Message::Empty => {}
        Message::Ids(v) | Message::Counts(v) => {
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Message::Floats(v) => {
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    w.write_all(&buf).context("write peer frame")
}

fn read_u64s(r: &mut impl Read, len: usize) -> Result<Vec<u64>> {
    let mut bytes = vec![0u8; len * 8];
    r.read_exact(&mut bytes).context("read frame payload")?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Read one peer frame. Returns `(lane, message)`.
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Message)> {
    let mut header = [0u8; 6];
    r.read_exact(&mut header).context("read frame header")?;
    let lane = header[0];
    let kind = header[1];
    let len = u32::from_le_bytes([header[2], header[3], header[4], header[5]]) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME_ELEMS,
        "frame header claims {len} elements (cap {MAX_FRAME_ELEMS}); stream is corrupt"
    );
    let msg = match kind {
        KIND_EMPTY => {
            anyhow::ensure!(len == 0, "Empty frame with {len} elements");
            Message::Empty
        }
        KIND_IDS => Message::Ids(read_u64s(r, len)?),
        KIND_COUNTS => Message::Counts(read_u64s(r, len)?),
        KIND_FLOATS => {
            let mut bytes = vec![0u8; len * 4];
            r.read_exact(&mut bytes).context("read frame payload")?;
            Message::Floats(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        k => bail!("unknown frame kind {k}; stream is corrupt"),
    };
    Ok((lane, msg))
}

/// Open a peer connection: identify ourselves and our incarnation.
pub fn write_hello(w: &mut impl Write, src: u32, incarnation: u32) -> Result<()> {
    let mut buf = [0u8; 8];
    buf[..4].copy_from_slice(&src.to_le_bytes());
    buf[4..].copy_from_slice(&incarnation.to_le_bytes());
    w.write_all(&buf).context("write hello")
}

/// Read the peer handshake: `(src_rank, incarnation)`.
pub fn read_hello(r: &mut impl Read) -> Result<(u32, u32)> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf).context("read hello")?;
    Ok((
        u32::from_le_bytes(buf[..4].try_into().unwrap()),
        u32::from_le_bytes(buf[4..].try_into().unwrap()),
    ))
}

/// Coordinator control protocol. Workers send `Register`, `Heartbeat`,
/// `Ready` and `Bye`; the coordinator replies with `Welcome` (once, to
/// a registration) and `Release` (to a complete, unpaused barrier).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoordMsg {
    /// A worker announces itself for `incarnation` of the run.
    Register {
        rank: u32,
        incarnation: u32,
        pid: u32,
    },
    /// The coordinator's registration reply: where to resume from and
    /// the run's base generator seed (the single source of truth for
    /// seeded shard assignment — ranks derive their shard from it).
    Welcome { resume_seq: u64, seed: u64 },
    /// Liveness beat; `step` is the worker's current training step.
    Heartbeat { rank: u32, step: u64 },
    /// The worker reached interval barrier `seq` with its delta durable.
    Ready { rank: u32, seq: u64 },
    /// All ranks reached barrier `seq`; proceed.
    Release { seq: u64 },
    /// Clean exit notice.
    Bye { rank: u32 },
}

const TAG_REGISTER: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_HEARTBEAT: u8 = 3;
const TAG_READY: u8 = 4;
const TAG_RELEASE: u8 = 5;
const TAG_BYE: u8 = 6;

/// Serialize one coordinator message (writes are small and atomic
/// enough for a mutex-guarded stream; no flushing games needed on UDS).
pub fn write_coord(w: &mut impl Write, msg: &CoordMsg) -> Result<()> {
    let mut buf = Vec::with_capacity(17);
    match *msg {
        CoordMsg::Register {
            rank,
            incarnation,
            pid,
        } => {
            buf.push(TAG_REGISTER);
            buf.extend_from_slice(&rank.to_le_bytes());
            buf.extend_from_slice(&incarnation.to_le_bytes());
            buf.extend_from_slice(&pid.to_le_bytes());
        }
        CoordMsg::Welcome { resume_seq, seed } => {
            buf.push(TAG_WELCOME);
            buf.extend_from_slice(&resume_seq.to_le_bytes());
            buf.extend_from_slice(&seed.to_le_bytes());
        }
        CoordMsg::Heartbeat { rank, step } => {
            buf.push(TAG_HEARTBEAT);
            buf.extend_from_slice(&rank.to_le_bytes());
            buf.extend_from_slice(&step.to_le_bytes());
        }
        CoordMsg::Ready { rank, seq } => {
            buf.push(TAG_READY);
            buf.extend_from_slice(&rank.to_le_bytes());
            buf.extend_from_slice(&seq.to_le_bytes());
        }
        CoordMsg::Release { seq } => {
            buf.push(TAG_RELEASE);
            buf.extend_from_slice(&seq.to_le_bytes());
        }
        CoordMsg::Bye { rank } => {
            buf.push(TAG_BYE);
            buf.extend_from_slice(&rank.to_le_bytes());
        }
    }
    w.write_all(&buf).context("write coordinator message")
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read coordinator field")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("read coordinator field")?;
    Ok(u64::from_le_bytes(b))
}

/// Read one coordinator message (blocking until a full message or EOF).
pub fn read_coord(r: &mut impl Read) -> Result<CoordMsg> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag).context("read coordinator tag")?;
    Ok(match tag[0] {
        TAG_REGISTER => CoordMsg::Register {
            rank: read_u32(r)?,
            incarnation: read_u32(r)?,
            pid: read_u32(r)?,
        },
        TAG_WELCOME => CoordMsg::Welcome {
            resume_seq: read_u64(r)?,
            seed: read_u64(r)?,
        },
        TAG_HEARTBEAT => CoordMsg::Heartbeat {
            rank: read_u32(r)?,
            step: read_u64(r)?,
        },
        TAG_READY => CoordMsg::Ready {
            rank: read_u32(r)?,
            seq: read_u64(r)?,
        },
        TAG_RELEASE => CoordMsg::Release { seq: read_u64(r)? },
        TAG_BYE => CoordMsg::Bye { rank: read_u32(r)? },
        t => bail!("unknown coordinator tag {t}; stream is corrupt"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrips_every_kind() {
        let msgs = vec![
            Message::Empty,
            Message::Ids(vec![0, 1, u64::MAX, 42]),
            Message::Floats(vec![0.0, -1.5, f32::MIN_POSITIVE, 3.25e7]),
            Message::Counts(vec![7]),
            Message::Ids(Vec::new()),
            Message::Floats(Vec::new()),
        ];
        for (lane, msg) in msgs.iter().enumerate() {
            let mut buf = Vec::new();
            write_frame(&mut buf, lane as u8, msg).unwrap();
            let (got_lane, got) = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(got_lane as usize, lane);
            assert_eq!(&got, msg);
        }
    }

    #[test]
    fn frames_concatenate_on_one_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 0, &Message::Ids(vec![9, 8])).unwrap();
        write_frame(&mut buf, 5, &Message::Floats(vec![1.0])).unwrap();
        write_frame(&mut buf, 2, &Message::Empty).unwrap();
        let mut cur = Cursor::new(&buf);
        assert_eq!(read_frame(&mut cur).unwrap(), (0, Message::Ids(vec![9, 8])));
        assert_eq!(
            read_frame(&mut cur).unwrap(),
            (5, Message::Floats(vec![1.0]))
        );
        assert_eq!(read_frame(&mut cur).unwrap(), (2, Message::Empty));
        assert!(read_frame(&mut cur).is_err(), "EOF is an error, not a frame");
    }

    #[test]
    fn truncated_and_corrupt_frames_are_loud() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, &Message::Ids(vec![1, 2, 3])).unwrap();
        // Truncation anywhere inside the frame errors.
        for cut in [1, 5, 6, buf.len() - 1] {
            assert!(
                read_frame(&mut Cursor::new(&buf[..cut])).is_err(),
                "cut at {cut} must error"
            );
        }
        // Unknown kind byte.
        let mut bad = buf.clone();
        bad[1] = 99;
        assert!(read_frame(&mut Cursor::new(&bad)).is_err());
        // Oversize element count fails before allocating.
        let mut huge = vec![0u8, KIND_IDS];
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut Cursor::new(&huge)).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
        // Non-empty Empty frame.
        let mut lying = vec![0u8, KIND_EMPTY];
        lying.extend_from_slice(&3u32.to_le_bytes());
        assert!(read_frame(&mut Cursor::new(&lying)).is_err());
    }

    #[test]
    fn hello_roundtrips() {
        let mut buf = Vec::new();
        write_hello(&mut buf, 3, 17).unwrap();
        assert_eq!(read_hello(&mut Cursor::new(&buf)).unwrap(), (3, 17));
        assert!(read_hello(&mut Cursor::new(&buf[..5])).is_err());
    }

    #[test]
    fn coord_messages_roundtrip() {
        let msgs = [
            CoordMsg::Register {
                rank: 2,
                incarnation: 1,
                pid: 4242,
            },
            CoordMsg::Welcome {
                resume_seq: 7,
                seed: 0xDEAD_BEEF_CAFE_F00D,
            },
            CoordMsg::Heartbeat { rank: 0, step: 123 },
            CoordMsg::Ready { rank: 3, seq: 9 },
            CoordMsg::Release { seq: 9 },
            CoordMsg::Bye { rank: 1 },
        ];
        for msg in msgs {
            let mut buf = Vec::new();
            write_coord(&mut buf, &msg).unwrap();
            assert_eq!(read_coord(&mut Cursor::new(&buf)).unwrap(), msg);
        }
        // Stream of several messages in sequence.
        let mut buf = Vec::new();
        for msg in msgs {
            write_coord(&mut buf, &msg).unwrap();
        }
        let mut cur = Cursor::new(&buf);
        for msg in msgs {
            assert_eq!(read_coord(&mut cur).unwrap(), msg);
        }
        // Unknown tag.
        assert!(read_coord(&mut Cursor::new(&[200u8])).is_err());
    }
}
