//! The worker-process side of the multi-process runtime.
//!
//! `mtgrboost dist-worker` (a hidden subcommand the supervisor spawns)
//! lands in [`run_worker`]: register with the coordinator, take the
//! `Welcome`'s resume point and base seed as gospel, join the UDS mesh,
//! and run one rank of the trainer with [`WorkerHooks`] wired into the
//! step/interval hot points. The hooks send an **inline heartbeat at
//! the top of every step** (so the coordinator's `max_step` is exact
//! and `replayed_steps` accounting is too) on top of a background
//! cadence thread that covers long stalls *within* a step, and carry
//! the rank's slice of the fault plan (kill at step / torn publish).
//!
//! The worker's result is a JSON report (`report_rank<r>.json` in the
//! run dir) whose float and checksum fields are **hex bit strings** —
//! JSON numbers are f64 and would silently round u64 checksums, and the
//! whole point of the drill harness is bit-exact comparison.

use std::io::BufReader;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::checkpoint::delta::sparse_delta_group_path;
use crate::runtime::engine::Engine;
use crate::train::{DistHooks, DistTrainOptions, TrainReport, Trainer, TrainerOptions};
use crate::util::json::Json;
use crate::util::retry::{retry, RetryPolicy};

use super::fault::FaultPlan;
use super::transport::SocketTransport;
use super::wire::{self, CoordMsg};

/// Socket / file layout inside a run dir.
pub fn coord_sock(run_dir: &Path) -> PathBuf {
    run_dir.join("coord.sock")
}
pub fn mesh_dir(run_dir: &Path) -> PathBuf {
    run_dir.join("sock")
}
pub fn report_path(run_dir: &Path, rank: usize) -> PathBuf {
    run_dir.join(format!("report_rank{rank}.json"))
}

/// Per-worker launch parameters (everything *not* in the shared
/// training-option tail).
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    pub rank: usize,
    pub run_dir: PathBuf,
    pub heartbeat_ms: u64,
    pub incarnation: u32,
    /// This run's fault plan (incarnation 0 only; the supervisor never
    /// re-arms faults on a recovered gang).
    pub fault: Option<FaultPlan>,
    /// Real artifacts dir, or `None` for the reference engine.
    pub artifacts: Option<PathBuf>,
}

/// Connection to the coordinator. The write half is shared (mutex)
/// between the training thread and the background heartbeat thread;
/// the read half is only ever used by the training thread (barriers).
pub struct CoordClient {
    write: Mutex<UnixStream>,
    read: Mutex<BufReader<UnixStream>>,
    rank: u32,
    step: AtomicU64,
    resume_seq: u64,
    seed: u64,
}

impl CoordClient {
    /// Connect (with retry — the supervisor binds the socket
    /// concurrently with spawning us), register, and consume `Welcome`.
    pub fn connect(sock: &Path, rank: usize, incarnation: u32) -> Result<CoordClient> {
        let policy = RetryPolicy {
            max_attempts: 400,
            base_delay_ms: 5,
            max_delay_ms: 100,
            seed: 0xC0_0D ^ rank as u64,
        };
        let (stream, _) = retry(&policy, &format!("rank {rank} connect coordinator"), |_| {
            UnixStream::connect(sock)
        })?;
        let mut write_half = stream.try_clone()?;
        wire::write_coord(
            &mut write_half,
            &CoordMsg::Register {
                rank: rank as u32,
                incarnation,
                pid: std::process::id(),
            },
        )?;
        let mut reader = BufReader::new(stream);
        let msg = wire::read_coord(&mut reader).context("await Welcome")?;
        let CoordMsg::Welcome { resume_seq, seed } = msg else {
            bail!("expected Welcome from coordinator, got {msg:?}");
        };
        Ok(CoordClient {
            write: Mutex::new(write_half),
            read: Mutex::new(reader),
            rank: rank as u32,
            step: AtomicU64::new(0),
            resume_seq,
            seed,
        })
    }

    /// `(resume_seq, seed)` from the coordinator's `Welcome`.
    pub fn welcome(&self) -> (u64, u64) {
        (self.resume_seq, self.seed)
    }

    fn send(&self, msg: &CoordMsg) -> Result<()> {
        let mut w = self.write.lock().unwrap();
        wire::write_coord(&mut *w, msg)
    }

    /// Record the current step and beat inline. Failures are swallowed:
    /// a worker that has lost the coordinator keeps training and lets
    /// liveness detection on the other side sort it out.
    pub fn stamp_step(&self, step: u64) {
        self.step.store(step, Ordering::Relaxed);
        let _ = self.send(&CoordMsg::Heartbeat {
            rank: self.rank,
            step,
        });
    }

    /// Background cadence beats, covering stalls within one step.
    pub fn spawn_heartbeats(self: &Arc<Self>, every_ms: u64) {
        let client = Arc::clone(self);
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_millis(every_ms.max(1)));
            let beat = CoordMsg::Heartbeat {
                rank: client.rank,
                step: client.step.load(Ordering::Relaxed),
            };
            if client.send(&beat).is_err() {
                return; // coordinator gone; nothing left to prove
            }
        });
    }

    /// Interval barrier: announce `Ready(seq)`, block until the
    /// coordinator releases it. Blocks indefinitely while the
    /// coordinator pauses for a recovery — the supervisor kills us.
    pub fn barrier(&self, seq: u64) -> Result<()> {
        self.send(&CoordMsg::Ready {
            rank: self.rank,
            seq,
        })?;
        let mut r = self.read.lock().unwrap();
        loop {
            match wire::read_coord(&mut *r).context("await barrier release")? {
                CoordMsg::Release { seq: s } if s == seq => return Ok(()),
                CoordMsg::Release { .. } => continue, // stale release
                other => bail!("unexpected coordinator message at barrier: {other:?}"),
            }
        }
    }

    pub fn bye(&self) {
        let _ = self.send(&CoordMsg::Bye { rank: self.rank });
    }
}

/// [`DistHooks`] implementation: heartbeats, the coordinator barrier,
/// and this rank's fault-plan slice.
struct WorkerHooks {
    coord: Arc<CoordClient>,
    rank: usize,
    world: usize,
    sync_dir: PathBuf,
    kill_at: Option<usize>,
    torn_at: Option<u64>,
}

impl DistHooks for WorkerHooks {
    fn on_step(&self, step: usize) {
        // Runs before the step's first collective, so an injected crash
        // never leaves peers blocked mid-exchange: they see EOF on
        // their next receive and die loudly.
        self.coord.stamp_step(step as u64);
        if self.kill_at == Some(step) {
            eprintln!("[dist] rank {} fault: kill at step {step}", self.rank);
            std::process::abort();
        }
    }

    fn on_interval(&self, seq: u64) -> Result<()> {
        if self.torn_at == Some(seq) {
            // Torn publish: our shard of delta `seq` is durable right
            // now — truncate it mid-file and crash, simulating a
            // machine dying inside the write. Recovery must refuse the
            // whole delta.
            let path = sparse_delta_group_path(&self.sync_dir, seq, self.rank, self.world, 0);
            let len = std::fs::metadata(&path)
                .with_context(|| format!("torn fault: stat {}", path.display()))?
                .len();
            let f = std::fs::OpenOptions::new().write(true).open(&path)?;
            f.set_len(len / 2)?;
            f.sync_all()?;
            eprintln!("[dist] rank {} fault: torn publish of delta {seq}", self.rank);
            std::process::abort();
        }
        self.coord.barrier(seq)
    }
}

/// Entry point for the `dist-worker` subcommand: run one rank to
/// completion and leave `report_rank<r>.json` behind.
pub fn run_worker(mut topts: TrainerOptions, w: &WorkerOptions) -> Result<()> {
    let world = topts.cluster.world;
    anyhow::ensure!(w.rank < world, "rank {} out of world {world}", w.rank);
    let ocfg = topts
        .online
        .as_ref()
        .context("dist workers require --mode online")?;
    let sync_dir = ocfg
        .sync_dir
        .clone()
        .context("dist workers require --sync-dir")?;

    let client = Arc::new(CoordClient::connect(
        &coord_sock(&w.run_dir),
        w.rank,
        w.incarnation,
    )?);
    let (resume_seq, seed) = client.welcome();
    // Seeded shard assignment: the coordinator's seed is authoritative;
    // every rank derives its data shard from it identically.
    topts.generator.seed = seed;
    client.spawn_heartbeats(w.heartbeat_ms);

    let transport = SocketTransport::connect(
        &mesh_dir(&w.run_dir),
        w.rank,
        world,
        w.incarnation,
        w.fault.as_ref(),
    )?;
    let comm = crate::collective::CommHandle::from_remote(w.rank, world, Box::new(transport));

    let plan = w.fault.unwrap_or_default();
    topts.dist = Some(DistTrainOptions {
        resume_seq,
        hooks: Some(Arc::new(WorkerHooks {
            coord: Arc::clone(&client),
            rank: w.rank,
            world,
            sync_dir,
            kill_at: plan.kill.filter(|k| k.rank == w.rank).map(|k| k.step),
            torn_at: plan.torn.filter(|t| t.rank == w.rank).map(|t| t.seq),
        })),
    });

    let engine = match &w.artifacts {
        Some(dir) => Engine::start(dir)?,
        None => Engine::reference(seed)?,
    };
    let report = Trainer::new(topts, engine)?.run_rank(comm)?;

    let json = report_to_json(&report, w.rank, world);
    std::fs::write(report_path(&w.run_dir, w.rank), json.pretty())
        .context("write worker report")?;
    client.bye();
    Ok(())
}

/// `0x`-prefixed, zero-padded 16-digit hex — the bit-exact JSON form
/// for u64 checksums and f64 loss bits.
pub fn hex64(x: u64) -> String {
    format!("{x:#018x}")
}

/// Inverse of [`hex64`] (tolerates unpadded values).
pub fn parse_hex64(s: &str) -> Result<u64> {
    let digits = s
        .strip_prefix("0x")
        .with_context(|| format!("`{s}` is not 0x-prefixed hex"))?;
    u64::from_str_radix(digits, 16).with_context(|| format!("`{s}` is not hex"))
}

/// The drill-comparable slice of a [`TrainReport`] as JSON. Shared by
/// `train --report-json` (the single-process reference) and the dist
/// worker reports, so bit-identity checks compare like with like.
pub fn report_to_json(report: &TrainReport, rank: usize, world: usize) -> Json {
    let mut j = Json::obj();
    j.set("rank", rank.into());
    j.set("world", world.into());
    let steps: Vec<Json> = report
        .steps
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("step", s.step.into());
            o.set("loss_ctr_bits", hex64(s.loss_ctr.to_bits()).into());
            o.set("loss_ctcvr_bits", hex64(s.loss_ctcvr.to_bits()).into());
            o
        })
        .collect();
    j.set("steps", Json::Arr(steps));
    let (ctr, ctcvr) = report.final_losses();
    j.set("final_loss_ctr_bits", hex64(ctr.to_bits()).into());
    j.set("final_loss_ctcvr_bits", hex64(ctcvr.to_bits()).into());
    j.set(
        "group_checksums",
        Json::Arr(report.group_checksums.iter().map(|&c| hex64(c).into()).collect()),
    );
    j.set(
        "group_rows",
        Json::Arr(report.group_rows.iter().map(|&r| r.into()).collect()),
    );
    j.set("table_rows", report.table_rows.into());
    j.set("online_synced_rows", report.online_synced_rows.into());
    j.set("transport_retries", report.dist.transport_retries.into());
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex64_roundtrips_edges() {
        for x in [0u64, 1, 0x8000_0000_0000_0000, u64::MAX, 0xDEAD_BEEF] {
            let s = hex64(x);
            assert_eq!(s.len(), 18, "{s} is zero-padded");
            assert_eq!(parse_hex64(&s).unwrap(), x);
        }
        assert_eq!(parse_hex64("0xff").unwrap(), 255, "unpadded tolerated");
        assert!(parse_hex64("ff").is_err());
        assert!(parse_hex64("0xzz").is_err());
        // f64 bits survive exactly, including negatives and subnormals.
        for f in [0.693_147_180_559_9, -0.0, f64::MIN_POSITIVE, 1e300] {
            assert_eq!(
                f64::from_bits(parse_hex64(&hex64(f.to_bits())).unwrap()).to_bits(),
                f.to_bits()
            );
        }
    }
}
