//! The fault-tolerant multi-process runtime.
//!
//! Everything below this module turns the single-process simulator into
//! N real worker *processes* training together and surviving crashes:
//!
//! * [`wire`] — length-prefixed little-endian codecs: peer frames
//!   carrying [`crate::collective::Message`] payloads, the coordinator
//!   control protocol, and the per-connection hello handshake.
//! * [`transport`] — [`SocketTransport`], the Unix-domain-socket mesh
//!   behind [`crate::collective::RemoteTransport`]: one stream per
//!   ordered rank pair, per-destination writer threads (sends never
//!   block on the peer), per-source reader threads demultiplexing into
//!   per-lane FIFOs, EOF poisoning so a dead peer fails receives
//!   loudly.
//! * [`coord`] — the [`Coordinator`] (registration, seeded shard
//!   assignment via the `Welcome` seed, the interval barrier) and the
//!   pure [`HeartbeatTracker`] failure detector.
//! * [`fault`] — [`FaultPlan`], the deterministic crash/drop/delay/
//!   torn-write injection the drills are built on.
//! * [`worker`] — the `dist-worker` process body: coordinator client,
//!   heartbeats, fault hooks, bit-exact JSON reports.
//! * [`supervisor`] — [`run_dist`]: spawn the gang, watch exits and
//!   heartbeats, and on failure recover by gang restart from the
//!   newest CRC-durable delta ([`scan_recovery_point`]).
//!
//! The invariant the whole stack defends: a run that crashes and
//! recovers produces **bit-identical** final losses and per-group
//! embedding checksums to an uninterrupted run (`tests/dist_drill.rs`
//! drives kill/torn drills through the real binary to assert it).

pub mod coord;
pub mod fault;
pub mod supervisor;
pub mod transport;
pub mod wire;
pub mod worker;

pub use coord::{BeatState, CoordConfig, CoordEvent, Coordinator, HeartbeatTracker};
pub use fault::FaultPlan;
pub use supervisor::{
    dist_report_to_json, run_dist, scan_recovery_point, DistOptions, DistReport,
};
pub use transport::SocketTransport;
pub use worker::{report_to_json, run_worker, WorkerOptions};
