//! Deterministic fault-injection plans for crash-recovery drills.
//!
//! A [`FaultPlan`] is parsed from the `--fault` CLI flag and describes
//! *at most one* fault of each kind, pinned to an exact rank and an
//! exact point in the run, so a drill is reproducible byte-for-byte:
//!
//! ```text
//! kill:rank=1,step=7;drop:rank=0,frame=3;delay:rank=0,frame=5,ms=20;torn:rank=0,seq=2
//! ```
//!
//! * `kill` — the worker calls `abort()` at the top of training step
//!   `step` (before its first collective, so peers die cleanly on EOF).
//! * `drop` — the rank's `frame`-th outbound transport frame fails
//!   transiently on its first send attempt; `util::retry` must recover
//!   it (exercised retries show up in `TrainReport.dist`).
//! * `delay` — the rank sleeps `ms` before sending its `frame`-th
//!   outbound frame (a slow-link stand-in; must not change any bytes).
//! * `torn` — while publishing delta `seq`, the rank truncates its own
//!   group-0 shard file mid-write and then aborts: the torn delta must
//!   be detected by the recovery scan and never applied.
//!
//! Frame indices count the rank's outbound *remote* frames from 0,
//! process-wide across lanes (self-sends never hit the wire). The
//! supervisor hands the plan only to **incarnation 0** workers, so a
//! recovered run is fault-free and converges.

use anyhow::{bail, Context, Result};

/// `kill:rank=K,step=S` — abort at the top of step `S` on rank `K`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub rank: usize,
    pub step: usize,
}

/// `drop:rank=K,frame=N` — `N`-th outbound frame fails once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DropSpec {
    pub rank: usize,
    pub frame: u64,
}

/// `delay:rank=K,frame=N,ms=M` — sleep `M` ms before the `N`-th frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DelaySpec {
    pub rank: usize,
    pub frame: u64,
    pub ms: u64,
}

/// `torn:rank=K,seq=Q` — tear own shard of delta `Q`, then abort.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TornSpec {
    pub rank: usize,
    pub seq: u64,
}

/// The full plan: at most one fault per kind.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub kill: Option<KillSpec>,
    pub drop_frame: Option<DropSpec>,
    pub delay: Option<DelaySpec>,
    pub torn: Option<TornSpec>,
}

fn parse_kv(body: &str, clause: &str) -> Result<std::collections::BTreeMap<String, u64>> {
    let mut kv = std::collections::BTreeMap::new();
    for pair in body.split(',') {
        let (k, v) = pair
            .split_once('=')
            .with_context(|| format!("fault clause `{clause}`: `{pair}` is not key=value"))?;
        let val: u64 = v
            .trim()
            .parse()
            .with_context(|| format!("fault clause `{clause}`: `{v}` is not an integer"))?;
        if kv.insert(k.trim().to_string(), val).is_some() {
            bail!("fault clause `{clause}`: duplicate key `{}`", k.trim());
        }
    }
    Ok(kv)
}

fn need(kv: &std::collections::BTreeMap<String, u64>, key: &str, clause: &str) -> Result<u64> {
    kv.get(key)
        .copied()
        .with_context(|| format!("fault clause `{clause}` is missing `{key}=`"))
}

fn only(
    kv: &std::collections::BTreeMap<String, u64>,
    keys: &[&str],
    clause: &str,
) -> Result<()> {
    for k in kv.keys() {
        if !keys.contains(&k.as_str()) {
            bail!("fault clause `{clause}`: unknown key `{k}` (expected {keys:?})");
        }
    }
    Ok(())
}

impl FaultPlan {
    /// Parse the `--fault` string. Strict: unknown clauses, unknown or
    /// missing keys, and duplicate clauses are errors — a silently
    /// ignored fault would make a drill vacuously pass.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in s.split(';').filter(|c| !c.trim().is_empty()) {
            let clause = clause.trim();
            let (name, body) = clause
                .split_once(':')
                .with_context(|| format!("fault clause `{clause}` is missing `kind:`"))?;
            let kv = parse_kv(body, clause)?;
            match name.trim() {
                "kill" => {
                    only(&kv, &["rank", "step"], clause)?;
                    anyhow::ensure!(plan.kill.is_none(), "duplicate `kill` clause");
                    plan.kill = Some(KillSpec {
                        rank: need(&kv, "rank", clause)? as usize,
                        step: need(&kv, "step", clause)? as usize,
                    });
                }
                "drop" => {
                    only(&kv, &["rank", "frame"], clause)?;
                    anyhow::ensure!(plan.drop_frame.is_none(), "duplicate `drop` clause");
                    plan.drop_frame = Some(DropSpec {
                        rank: need(&kv, "rank", clause)? as usize,
                        frame: need(&kv, "frame", clause)?,
                    });
                }
                "delay" => {
                    only(&kv, &["rank", "frame", "ms"], clause)?;
                    anyhow::ensure!(plan.delay.is_none(), "duplicate `delay` clause");
                    plan.delay = Some(DelaySpec {
                        rank: need(&kv, "rank", clause)? as usize,
                        frame: need(&kv, "frame", clause)?,
                        ms: need(&kv, "ms", clause)?,
                    });
                }
                "torn" => {
                    only(&kv, &["rank", "seq"], clause)?;
                    anyhow::ensure!(plan.torn.is_none(), "duplicate `torn` clause");
                    plan.torn = Some(TornSpec {
                        rank: need(&kv, "rank", clause)? as usize,
                        seq: need(&kv, "seq", clause)?,
                    });
                }
                other => bail!("unknown fault kind `{other}` in `{clause}`"),
            }
        }
        Ok(plan)
    }

    /// Canonical string form (fixed clause order); `parse(encode(p)) == p`.
    pub fn encode(&self) -> String {
        let mut parts = Vec::new();
        if let Some(k) = &self.kill {
            parts.push(format!("kill:rank={},step={}", k.rank, k.step));
        }
        if let Some(d) = &self.drop_frame {
            parts.push(format!("drop:rank={},frame={}", d.rank, d.frame));
        }
        if let Some(d) = &self.delay {
            parts.push(format!("delay:rank={},frame={},ms={}", d.rank, d.frame, d.ms));
        }
        if let Some(t) = &self.torn {
            parts.push(format!("torn:rank={},seq={}", t.rank, t.seq));
        }
        parts.join(";")
    }

    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_plan_roundtrips() {
        let s = "kill:rank=1,step=7;drop:rank=0,frame=3;delay:rank=0,frame=5,ms=20;torn:rank=0,seq=2";
        let p = FaultPlan::parse(s).unwrap();
        assert_eq!(p.kill, Some(KillSpec { rank: 1, step: 7 }));
        assert_eq!(p.drop_frame, Some(DropSpec { rank: 0, frame: 3 }));
        assert_eq!(
            p.delay,
            Some(DelaySpec {
                rank: 0,
                frame: 5,
                ms: 20
            })
        );
        assert_eq!(p.torn, Some(TornSpec { rank: 0, seq: 2 }));
        assert_eq!(p.encode(), s, "canonical order re-encodes verbatim");
        assert_eq!(FaultPlan::parse(&p.encode()).unwrap(), p);
        assert!(!p.is_empty());
    }

    #[test]
    fn single_clause_and_whitespace() {
        let p = FaultPlan::parse(" kill:rank=0,step=12 ; ").unwrap();
        assert_eq!(p.kill, Some(KillSpec { rank: 0, step: 12 }));
        assert!(p.drop_frame.is_none() && p.delay.is_none() && p.torn.is_none());
        // Shuffled clause order parses; encode canonicalizes it.
        let q = FaultPlan::parse("torn:rank=1,seq=3;kill:rank=0,step=1").unwrap();
        assert_eq!(q.encode(), "kill:rank=0,step=1;torn:rank=1,seq=3");
    }

    #[test]
    fn empty_plan() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_empty());
        assert_eq!(p.encode(), "");
        assert_eq!(FaultPlan::parse(&p.encode()).unwrap(), p);
    }

    #[test]
    fn malformed_plans_are_rejected() {
        for bad in [
            "boom:rank=0",                     // unknown kind
            "kill:rank=0",                     // missing step
            "kill:rank=0,step=1,extra=2",      // unknown key
            "kill:rank=0,rank=1,step=2",       // duplicate key
            "kill:rank=0,step=1;kill:rank=1,step=2", // duplicate clause
            "kill:rank=x,step=1",              // non-integer
            "kill=rank0",                      // no colon
            "delay:rank=0,frame=1",            // delay missing ms
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` must not parse");
        }
    }
}
