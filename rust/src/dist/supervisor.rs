//! The supervisor: spawn N worker processes, watch them, and recover.
//!
//! `mtgrboost train-dist` lands in [`run_dist`]. The supervisor owns
//! the [`Coordinator`] and the worker children; the workers own the
//! training. Failure handling is a **gang restart** (the torchelastic
//! model): collectives entangle every rank with every other, so a
//! single dead rank makes the survivors' state unrecoverable in place —
//! on any nonzero child exit *or* heartbeat-timeout event, the
//! supervisor pauses the barrier, kills the whole gang, finds the
//! newest fully-durable delta, and respawns everyone from it under a
//! bumped incarnation (stale sockets and messages from half-dead
//! workers are refused by incarnation tag).
//!
//! The recovery point is [`scan_recovery_point`]: the largest `R` such
//! that deltas `1..=R` all parse, match the world size, and pass the
//! CRC32 footer check on every rank x group shard *and* the dense
//! state. Anything newer — including a torn shard from a crash inside
//! a publish — is deleted, so a recovered worker replays a clean
//! prefix. No full base checkpoint is needed: dist mode disallows
//! TTL/admission (see `TrainerOptions::validate`), so deltas carry
//! full rows (with Adam state) and every resident row appears in some
//! delta `<= R`.
//!
//! Everything observable lands in the merged [`DistReport`]: heartbeat
//! misses, transport retries, gang recoveries, and how many steps were
//! replayed because they fell after the newest durable delta.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::checkpoint::delta::{
    delta_dir, load_delta_group_dims, load_delta_meta, parse_canonical_seq,
    sparse_delta_group_path,
};
use crate::checkpoint::verify_sealed;
use crate::train::{DistStats, TrainerOptions};
use crate::util::json::Json;

use super::coord::{CoordConfig, CoordEvent, Coordinator};
use super::fault::FaultPlan;
use super::worker::{coord_sock, hex64, parse_hex64, report_path};

/// Supervisor-side knobs (everything the workers don't parse from the
/// shared training-option tail).
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Scratch dir for sockets and per-rank reports.
    pub run_dir: PathBuf,
    /// Worker beat cadence.
    pub heartbeat_ms: u64,
    /// Silence that declares a worker dead.
    pub heartbeat_timeout_ms: u64,
    /// Gang restarts to attempt before giving up.
    pub max_recoveries: usize,
    /// Fault plan injected into incarnation 0's workers.
    pub fault: Option<FaultPlan>,
    /// Binary to spawn (`current_exe` in production; tests point at the
    /// built binary).
    pub worker_bin: PathBuf,
    /// The training-option argv tail forwarded verbatim to every worker
    /// (per-rank flags are appended after it and win on conflict).
    pub worker_args: Vec<String>,
}

/// One step's loss bits (from rank 0's report).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepBits {
    pub step: usize,
    pub loss_ctr_bits: u64,
    pub loss_ctcvr_bits: u64,
}

/// The merged outcome of a distributed run: the drill-comparable slice
/// of every rank's report plus the failure/recovery accounting.
#[derive(Clone, Debug)]
pub struct DistReport {
    pub world: usize,
    /// Rank 0's per-step loss bits for the final incarnation (a
    /// recovered run's records start at its resume step).
    pub steps: Vec<StepBits>,
    pub final_loss_ctr_bits: u64,
    pub final_loss_ctcvr_bits: u64,
    /// Element-wise wrapping sums over the rank shards — directly
    /// comparable to a single-process report's `group_checksums`.
    pub group_checksums: Vec<u64>,
    pub group_rows: Vec<usize>,
    pub table_rows: usize,
    pub online_synced_rows: u64,
    pub dist: DistStats,
}

/// Largest `R` with deltas `1..=R` fully durable for `world`, deleting
/// every newer (necessarily torn or unreachable) delta dir. `R == 0`
/// means restart from scratch.
pub fn scan_recovery_point(sync_dir: &Path, world: usize) -> Result<u64> {
    let mut newest_valid = 0u64;
    loop {
        let seq = newest_valid + 1;
        if !delta_dir(sync_dir, seq).is_dir() {
            break;
        }
        if delta_is_durable(sync_dir, seq, world) {
            newest_valid = seq;
        } else {
            break;
        }
    }
    for entry in std::fs::read_dir(sync_dir)
        .with_context(|| format!("read sync dir {}", sync_dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        // Canonical delta dirs past the recovery point are dead weight
        // (a torn delta, or a valid one stranded behind a gap); a
        // recovered run must never see them. Non-canonical names are
        // left for the loaders' own validation to reject.
        if let Ok(Some(seq)) = parse_canonical_seq("delta_", &name) {
            if seq > newest_valid {
                std::fs::remove_dir_all(entry.path())
                    .with_context(|| format!("drop undurable {name}"))?;
            }
        }
    }
    Ok(newest_valid)
}

/// Full durability check for one delta: meta parses, world matches,
/// every rank x group shard and the dense state pass their CRC32
/// footers. Any failure means "not durable" — the distinction between
/// torn, corrupt and missing doesn't change the recovery decision.
fn delta_is_durable(sync_dir: &Path, seq: u64, world: usize) -> bool {
    let Ok(meta) = load_delta_meta(sync_dir, seq) else {
        return false;
    };
    if meta.world != world {
        return false;
    }
    let Ok(dims) = load_delta_group_dims(sync_dir, &meta) else {
        return false;
    };
    for rank in 0..world {
        for group in 0..dims.len() {
            if verify_sealed(&sparse_delta_group_path(sync_dir, seq, rank, world, group))
                .is_err()
            {
                return false;
            }
        }
    }
    verify_sealed(&delta_dir(sync_dir, seq).join("dense.bin")).is_ok()
}

fn spawn_workers(
    topts: &TrainerOptions,
    dopts: &DistOptions,
    incarnation: u32,
) -> Result<Vec<Child>> {
    let world = topts.cluster.world;
    (0..world)
        .map(|rank| {
            // Stale reports must never satisfy the merge step.
            let _ = std::fs::remove_file(report_path(&dopts.run_dir, rank));
            let mut cmd = Command::new(&dopts.worker_bin);
            cmd.arg("dist-worker")
                .args(&dopts.worker_args)
                // Appended per-rank flags override the tail (the CLI
                // parser keeps the last occurrence of a key).
                .arg("--world")
                .arg(world.to_string())
                .arg("--rank")
                .arg(rank.to_string())
                .arg("--incarnation")
                .arg(incarnation.to_string())
                .arg("--run-dir")
                .arg(&dopts.run_dir)
                .arg("--heartbeat-ms")
                .arg(dopts.heartbeat_ms.to_string())
                .stdin(Stdio::null());
            // Faults arm only the first incarnation: drills assert the
            // *recovered* run converges, so it must run clean.
            if incarnation == 0 {
                if let Some(plan) = &dopts.fault {
                    if !plan.is_empty() {
                        cmd.arg("--fault").arg(plan.encode());
                    }
                }
            }
            cmd.spawn()
                .with_context(|| format!("spawn worker rank {rank}"))
        })
        .collect()
}

/// Watch one incarnation: `Ok(true)` when every child exited cleanly,
/// `Ok(false)` on the first nonzero exit or heartbeat-death event
/// (children may still be running; the caller kills them).
fn watch_gang(children: &mut [Child], coord: &Coordinator) -> Result<bool> {
    loop {
        let mut all_done = true;
        for child in children.iter_mut() {
            match child.try_wait().context("poll worker")? {
                Some(status) if !status.success() => {
                    eprintln!("[dist] worker exited with {status}");
                    return Ok(false);
                }
                Some(_) => {}
                None => all_done = false,
            }
        }
        if let Some(CoordEvent::Dead { rank }) = coord.try_event() {
            eprintln!("[dist] rank {rank} heartbeat-timed out");
            return Ok(false);
        }
        if all_done {
            return Ok(true);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Run a multi-process training job to completion, recovering from
/// worker deaths, and merge the per-rank reports.
pub fn run_dist(topts: &TrainerOptions, dopts: &DistOptions) -> Result<DistReport> {
    // Validate what the *workers* will run (dist set), so a config the
    // dist rules reject (TTL, admission, GAUC) fails here instead of
    // crash-looping every worker through max_recoveries.
    let mut probe = topts.clone();
    probe.dist = Some(crate::train::DistTrainOptions::default());
    probe.validate()?;
    let world = topts.cluster.world;
    let ocfg = topts
        .online
        .as_ref()
        .context("train-dist requires --mode online")?;
    let sync_dir = ocfg
        .sync_dir
        .clone()
        .context("train-dist requires --sync-dir")?;
    let sync_interval = ocfg.sync_interval as u64;
    std::fs::create_dir_all(&dopts.run_dir)?;

    let mut coord = Coordinator::start(
        &coord_sock(&dopts.run_dir),
        CoordConfig {
            world,
            heartbeat_ms: dopts.heartbeat_ms,
            timeout_ms: dopts.heartbeat_timeout_ms,
            seed: topts.generator.seed,
        },
    )?;

    let mut incarnation: u32 = 0;
    let mut resume_seq: u64 = 0;
    let mut recoveries = 0u64;
    let mut replayed_steps = 0u64;
    loop {
        coord.reset(resume_seq, incarnation);
        let mut children = spawn_workers(topts, dopts, incarnation)?;
        let clean = watch_gang(&mut children, &coord)?;
        if clean {
            break;
        }
        // Gang restart: pause the barrier so in-flight Readys from
        // survivors can't release anything, take everyone down, then
        // rewind to the newest durable delta.
        coord.pause();
        for child in &mut children {
            let _ = child.kill();
        }
        for child in &mut children {
            let _ = child.wait();
        }
        anyhow::ensure!(
            (recoveries as usize) < dopts.max_recoveries,
            "giving up after {recoveries} gang recoveries (max {})",
            dopts.max_recoveries
        );
        recoveries += 1;
        let point = scan_recovery_point(&sync_dir, world)?;
        replayed_steps += coord
            .max_step()
            .saturating_sub(point * sync_interval);
        eprintln!(
            "[dist] recovery {recoveries}: resuming from delta {point} \
             (incarnation {})",
            incarnation + 1
        );
        resume_seq = point;
        incarnation += 1;
    }

    let stats = DistStats {
        heartbeat_misses: coord.misses(),
        transport_retries: 0, // summed from rank reports below
        recoveries,
        replayed_steps,
    };
    coord.shutdown();
    merge_reports(&dopts.run_dir, world, stats)
}

/// Fold the per-rank `report_rank<r>.json` files into one [`DistReport`].
fn merge_reports(run_dir: &Path, world: usize, mut stats: DistStats) -> Result<DistReport> {
    let mut steps = Vec::new();
    let mut final_ctr = 0u64;
    let mut final_ctcvr = 0u64;
    let mut group_checksums: Vec<u64> = Vec::new();
    let mut group_rows: Vec<usize> = Vec::new();
    let mut table_rows = 0usize;
    let mut online_synced_rows = 0u64;
    for rank in 0..world {
        let path = report_path(run_dir, rank);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read worker report {}", path.display()))?;
        let j = Json::parse(&text).context("parse worker report")?;
        let checksums: Vec<u64> = j
            .get("group_checksums")
            .as_arr()
            .context("report missing group_checksums")?
            .iter()
            .map(|c| parse_hex64(c.as_str().context("checksum not a string")?))
            .collect::<Result<_>>()?;
        if group_checksums.is_empty() {
            group_checksums = vec![0; checksums.len()];
            group_rows = vec![0; checksums.len()];
        }
        for (g, c) in checksums.into_iter().enumerate() {
            group_checksums[g] = group_checksums[g].wrapping_add(c);
        }
        let rows = j
            .get("group_rows")
            .as_arr()
            .context("report missing group_rows")?;
        for (g, r) in rows.iter().enumerate() {
            group_rows[g] += r.expect_usize("group_rows entry")?;
        }
        table_rows += j.expect_usize("table_rows")?;
        stats.transport_retries += j.expect_usize("transport_retries")? as u64;
        if rank == 0 {
            // Step records and the online totals are identical on every
            // rank (losses are global means, the counters are gathered
            // at each boundary); take rank 0's like the single-process
            // merge does.
            final_ctr = parse_hex64(j.expect_str("final_loss_ctr_bits")?)?;
            final_ctcvr = parse_hex64(j.expect_str("final_loss_ctcvr_bits")?)?;
            online_synced_rows = j.expect_usize("online_synced_rows")? as u64;
            for s in j.get("steps").as_arr().context("report missing steps")? {
                steps.push(StepBits {
                    step: s.expect_usize("step")?,
                    loss_ctr_bits: parse_hex64(s.expect_str("loss_ctr_bits")?)?,
                    loss_ctcvr_bits: parse_hex64(s.expect_str("loss_ctcvr_bits")?)?,
                });
            }
        }
    }
    Ok(DistReport {
        world,
        steps,
        final_loss_ctr_bits: final_ctr,
        final_loss_ctcvr_bits: final_ctcvr,
        group_checksums,
        group_rows,
        table_rows,
        online_synced_rows,
        dist: stats,
    })
}

/// The merged report as JSON (`train-dist --report-json`), field names
/// matching the worker/reference reports plus the `dist` accounting.
pub fn dist_report_to_json(r: &DistReport) -> Json {
    let mut j = Json::obj();
    j.set("world", r.world.into());
    let steps: Vec<Json> = r
        .steps
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("step", s.step.into());
            o.set("loss_ctr_bits", hex64(s.loss_ctr_bits).into());
            o.set("loss_ctcvr_bits", hex64(s.loss_ctcvr_bits).into());
            o
        })
        .collect();
    j.set("steps", Json::Arr(steps));
    j.set("final_loss_ctr_bits", hex64(r.final_loss_ctr_bits).into());
    j.set("final_loss_ctcvr_bits", hex64(r.final_loss_ctcvr_bits).into());
    j.set(
        "group_checksums",
        Json::Arr(r.group_checksums.iter().map(|&c| hex64(c).into()).collect()),
    );
    j.set(
        "group_rows",
        Json::Arr(r.group_rows.iter().map(|&n| n.into()).collect()),
    );
    j.set("table_rows", r.table_rows.into());
    j.set("online_synced_rows", r.online_synced_rows.into());
    let mut d = Json::obj();
    d.set("heartbeat_misses", d_u64(r.dist.heartbeat_misses));
    d.set("transport_retries", d_u64(r.dist.transport_retries));
    d.set("recoveries", d_u64(r.dist.recoveries));
    d.set("replayed_steps", d_u64(r.dist.replayed_steps));
    j.set("dist", d);
    j
}

fn d_u64(x: u64) -> Json {
    // Counters are far below 2^53; plain numbers read better than hex.
    (x as usize).into()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mtgr_sup_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn scan_of_empty_dir_is_zero() {
        let d = tmp("empty");
        assert_eq!(scan_recovery_point(&d, 2).unwrap(), 0);
    }

    #[test]
    fn scan_stops_at_torn_delta_and_prunes_it() {
        use crate::checkpoint::delta::{save_delta_groups, DeltaMeta, GroupDelta};
        use crate::checkpoint::SparseRow;
        use crate::optim::adam::{AdamParams, DenseAdam};

        let d = tmp("torn");
        let world = 2;
        let dim = 4;
        let params = [0.5f32; 3];
        let adam = DenseAdam::new(params.len(), AdamParams::default());
        // Write three tiny but real deltas via the production writer.
        for seq in 1..=3u64 {
            let meta = DeltaMeta {
                seq,
                world,
                step: seq * 5,
                base_step: (seq - 1) * 5,
                model: "tiny".to_string(),
                dim,
                param_count: params.len(),
            };
            for rank in 0..world {
                let rows = vec![SparseRow {
                    id: seq * 10 + rank as u64,
                    row: vec![0.25; dim],
                    m: vec![0.0; dim],
                    v: vec![0.0; dim],
                    t: seq,
                }];
                let dense = (rank == 0).then_some((&params[..], &adam));
                save_delta_groups(
                    &d,
                    &meta,
                    rank,
                    dense,
                    &[GroupDelta {
                        dim,
                        upserts: &rows,
                        removed: &[],
                        policy: crate::embedding::precision::PrecisionPolicy::fp32(),
                    }],
                )
                .unwrap();
            }
        }
        assert_eq!(scan_recovery_point(&d, world).unwrap(), 3, "all durable");

        // Tear delta 3's rank-1 shard mid-file: scan must stop at 2 and
        // delete delta 3 entirely.
        let shard = sparse_delta_group_path(&d, 3, 1, world, 0);
        let len = std::fs::metadata(&shard).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&shard).unwrap();
        f.set_len(len / 2).unwrap();
        drop(f);
        assert_eq!(scan_recovery_point(&d, world).unwrap(), 2);
        assert!(!delta_dir(&d, 3).exists(), "torn delta pruned");
        // Idempotent.
        assert_eq!(scan_recovery_point(&d, world).unwrap(), 2);

        // A world mismatch also stops the scan.
        assert_eq!(scan_recovery_point(&d, 4).unwrap(), 0);
    }

    #[test]
    fn dist_report_json_roundtrips_bits() {
        let r = DistReport {
            world: 2,
            steps: vec![StepBits {
                step: 3,
                loss_ctr_bits: 0x3FE6_2E42_FEFA_39EF,
                loss_ctcvr_bits: u64::MAX,
            }],
            final_loss_ctr_bits: 1,
            final_loss_ctcvr_bits: 0x8000_0000_0000_0000,
            group_checksums: vec![u64::MAX, 0xDEAD],
            group_rows: vec![10, 2],
            table_rows: 12,
            online_synced_rows: 99,
            dist: DistStats {
                heartbeat_misses: 4,
                transport_retries: 2,
                recoveries: 1,
                replayed_steps: 7,
            },
        };
        let j = dist_report_to_json(&r);
        let parsed = Json::parse(&j.pretty()).unwrap();
        let cs = parsed.get("group_checksums").as_arr().unwrap();
        assert_eq!(
            parse_hex64(cs[0].as_str().unwrap()).unwrap(),
            u64::MAX,
            "u64::MAX survives JSON exactly (a plain number would round)"
        );
        let d = parsed.get("dist");
        assert_eq!(d.expect_usize("recoveries").unwrap(), 1);
        assert_eq!(d.expect_usize("replayed_steps").unwrap(), 7);
    }
}
