//! The run coordinator: registration, seeded shard assignment, the
//! interval barrier, and heartbeat-based failure detection.
//!
//! The coordinator lives **inside the supervisor process** and listens
//! on `coord.sock` in the run dir. Workers register with their rank and
//! incarnation, get back a [`wire::CoordMsg::Welcome`] carrying the
//! resume point and the run's base generator seed (the single source of
//! truth for seeded shard assignment — no worker ever picks its own
//! resume point), then heartbeat every `heartbeat_ms` and rendezvous at
//! a barrier after publishing each online delta.
//!
//! Failure detection is a [`HeartbeatTracker`] per rank — a **pure**
//! state machine over a millisecond clock, so every timeout edge
//! (exactly-at-deadline, clock regression) is unit-testable without
//! sockets or sleeps. The monitor thread samples the trackers at half
//! the heartbeat interval and reports the first death per incarnation
//! as a [`CoordEvent::Dead`]; the supervisor then pauses the barrier,
//! restarts the gang, and bumps the incarnation so stale messages from
//! half-dead workers are ignored by tag.

use std::collections::HashMap;
use std::io::BufReader;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::wire::{self, CoordMsg};

/// Liveness verdict for one rank at one instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BeatState {
    /// Beat seen within one heartbeat interval.
    Alive,
    /// `k` whole intervals have elapsed without a beat (k >= 1), but the
    /// timeout has not been reached.
    Missed(u64),
    /// The timeout elapsed — `now - last_beat >= timeout_ms`. Note the
    /// `>=`: *exactly at* the deadline is dead, one millisecond before
    /// it is only missed.
    Dead,
}

/// Pure per-rank heartbeat clock. All times are caller-supplied
/// millisecond stamps (the coordinator uses ms since its own `Instant`
/// epoch; tests use literals), so the tracker itself never reads a
/// clock and every edge is deterministic.
#[derive(Clone, Copy, Debug)]
pub struct HeartbeatTracker {
    interval_ms: u64,
    timeout_ms: u64,
    last_beat: u64,
    /// Miss count already credited to the cumulative counter for the
    /// current silence, so repeated [`observe`](Self::observe) calls
    /// during one silence don't double-count.
    credited: u64,
}

impl HeartbeatTracker {
    /// A fresh tracker that considers `now_ms` its first beat.
    /// `interval_ms` is clamped to at least 1 (a zero interval would
    /// divide by zero in miss accounting).
    pub fn new(interval_ms: u64, timeout_ms: u64, now_ms: u64) -> Self {
        HeartbeatTracker {
            interval_ms: interval_ms.max(1),
            timeout_ms,
            last_beat: now_ms,
            credited: 0,
        }
    }

    /// Record a beat. A stamp *earlier* than the last beat (clock
    /// regression, out-of-order delivery) still proves the worker is
    /// alive *now*, so it clears the silence without moving `last_beat`
    /// backwards — a regressed clock must never fake a timeout.
    pub fn beat(&mut self, now_ms: u64) {
        if now_ms > self.last_beat {
            self.last_beat = now_ms;
        }
        self.credited = 0;
    }

    /// Liveness at `now_ms` (pure; does not mutate miss accounting).
    /// `now_ms` earlier than the last beat saturates to zero elapsed.
    pub fn check(&self, now_ms: u64) -> BeatState {
        let elapsed = now_ms.saturating_sub(self.last_beat);
        if elapsed >= self.timeout_ms {
            BeatState::Dead
        } else {
            match elapsed / self.interval_ms {
                0 => BeatState::Alive,
                k => BeatState::Missed(k),
            }
        }
    }

    /// [`check`](Self::check) plus miss accounting: returns the state
    /// and how many *new* whole-interval misses occurred since the last
    /// observation (monotone within one silence; resets on a beat).
    pub fn observe(&mut self, now_ms: u64) -> (BeatState, u64) {
        let state = self.check(now_ms);
        let elapsed = now_ms.saturating_sub(self.last_beat);
        let total = elapsed / self.interval_ms;
        let new = total.saturating_sub(self.credited);
        self.credited = self.credited.max(total);
        (state, new)
    }
}

/// Failure notifications the supervisor consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoordEvent {
    /// Rank `rank` missed heartbeats past the timeout in the current
    /// incarnation. Reported at most once per incarnation.
    Dead { rank: usize },
}

/// Coordinator knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordConfig {
    pub world: usize,
    /// Expected beat cadence.
    pub heartbeat_ms: u64,
    /// Silence length that declares a rank dead.
    pub timeout_ms: u64,
    /// Base generator seed distributed via `Welcome` (ranks derive
    /// their data shard from it).
    pub seed: u64,
}

/// Mutable coordinator state behind one mutex.
struct CoordState {
    /// Current incarnation; messages tagged with any other are stale.
    incarnation: u32,
    /// Resume point handed to registrants of the current incarnation.
    resume_seq: u64,
    /// While paused (during recovery) barriers never release and the
    /// monitor reports no deaths (the gang is known-down).
    paused: bool,
    /// Per-rank liveness; `None` until registered / after `Bye`.
    trackers: Vec<Option<HeartbeatTracker>>,
    /// Per-rank write halves for `Welcome` / `Release`.
    writers: Vec<Option<UnixStream>>,
    /// Barrier attendance per seq.
    ready: HashMap<u64, Vec<bool>>,
    /// Dead already reported this incarnation?
    dead_reported: bool,
}

struct CoordInner {
    cfg: CoordConfig,
    epoch: Instant,
    state: Mutex<CoordState>,
    stop: AtomicBool,
    /// Highest training step any heartbeat has carried (monotone across
    /// incarnations; the supervisor diffs it against the recovery point
    /// to count replayed steps).
    max_step: AtomicU64,
    /// Cumulative whole-interval heartbeat misses across all ranks and
    /// incarnations.
    misses: AtomicU64,
    events: Sender<CoordEvent>,
}

impl CoordInner {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }
}

/// Handle owned by the supervisor. Dropping it shuts the listener and
/// monitor down.
pub struct Coordinator {
    inner: Arc<CoordInner>,
    events: Receiver<CoordEvent>,
    accept_thread: Option<JoinHandle<()>>,
    monitor_thread: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Bind `sock` (unlinking any stale socket first) and start the
    /// accept + monitor threads.
    pub fn start(sock: &Path, cfg: CoordConfig) -> Result<Coordinator> {
        anyhow::ensure!(cfg.world >= 1, "coordinator needs world >= 1");
        anyhow::ensure!(
            cfg.timeout_ms > 0 && cfg.heartbeat_ms > 0,
            "heartbeat and timeout must be positive"
        );
        let _ = std::fs::remove_file(sock);
        let listener = UnixListener::bind(sock)
            .with_context(|| format!("bind coordinator socket {}", sock.display()))?;
        // Nonblocking so the accept loop can poll the stop flag.
        listener.set_nonblocking(true)?;

        let (tx, rx) = std::sync::mpsc::channel();
        let inner = Arc::new(CoordInner {
            cfg,
            epoch: Instant::now(),
            state: Mutex::new(CoordState {
                incarnation: 0,
                resume_seq: 0,
                paused: false,
                trackers: (0..cfg.world).map(|_| None).collect(),
                writers: (0..cfg.world).map(|_| None).collect(),
                ready: HashMap::new(),
                dead_reported: false,
            }),
            stop: AtomicBool::new(false),
            max_step: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            events: tx,
        });

        let accept_inner = Arc::clone(&inner);
        let accept_thread = std::thread::spawn(move || {
            while !accept_inner.stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let conn_inner = Arc::clone(&accept_inner);
                        // Connection readers block on their own stream
                        // and exit on EOF; they are detached on purpose
                        // (a dead worker's socket EOFs when the kernel
                        // reaps it, which may outlive the coordinator).
                        std::thread::spawn(move || conn_main(stream, conn_inner));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });

        let monitor_inner = Arc::clone(&inner);
        let monitor_thread = std::thread::spawn(move || monitor_main(monitor_inner));

        Ok(Coordinator {
            inner,
            events: rx,
            accept_thread: Some(accept_thread),
            monitor_thread: Some(monitor_thread),
        })
    }

    /// Freeze the barrier and failure detector (recovery in progress).
    pub fn pause(&self) {
        self.inner.state.lock().unwrap().paused = true;
    }

    /// Arm the next incarnation: clear liveness/barrier state, set the
    /// resume point future `Welcome`s will carry, and unpause.
    pub fn reset(&self, resume_seq: u64, incarnation: u32) {
        let mut st = self.inner.state.lock().unwrap();
        st.incarnation = incarnation;
        st.resume_seq = resume_seq;
        st.paused = false;
        st.dead_reported = false;
        st.ready.clear();
        for t in &mut st.trackers {
            *t = None;
        }
        for w in &mut st.writers {
            *w = None;
        }
    }

    /// Nonblocking poll for the next failure event.
    pub fn try_event(&self) -> Option<CoordEvent> {
        self.events.try_recv().ok()
    }

    /// Cumulative whole-interval heartbeat misses (all ranks, all
    /// incarnations).
    pub fn misses(&self) -> u64 {
        self.inner.misses.load(Ordering::Relaxed)
    }

    /// Highest training step any heartbeat has reported.
    pub fn max_step(&self) -> u64 {
        self.inner.max_step.load(Ordering::Relaxed)
    }

    /// Stop the accept and monitor threads (idempotent; also run by
    /// `Drop`).
    pub fn shutdown(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.monitor_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection reader: register, then pump heartbeats/barriers until
/// EOF. Malformed or protocol-violating traffic drops the connection;
/// liveness tracking then declares the rank dead if it mattered.
fn conn_main(stream: UnixStream, inner: Arc<CoordInner>) {
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(read_half);
    // Incarnation this connection registered under; learned at
    // Register, then used to drop stale messages after a reset.
    let mut my_inc: Option<u32> = None;
    loop {
        let msg = match wire::read_coord(&mut reader) {
            Ok(m) => m,
            Err(_) => return, // EOF or corrupt stream
        };
        match msg {
            CoordMsg::Register {
                rank,
                incarnation,
                pid: _,
            } => {
                let rank = rank as usize;
                let mut st = inner.state.lock().unwrap();
                if incarnation != st.incarnation || rank >= inner.cfg.world {
                    return; // stale or bogus registrant: drop it
                }
                my_inc = Some(incarnation);
                let now = inner.now_ms();
                st.trackers[rank] = Some(HeartbeatTracker::new(
                    inner.cfg.heartbeat_ms,
                    inner.cfg.timeout_ms,
                    now,
                ));
                let write_half = match stream.try_clone() {
                    Ok(w) => w,
                    Err(_) => return,
                };
                st.writers[rank] = Some(write_half);
                let welcome = CoordMsg::Welcome {
                    resume_seq: st.resume_seq,
                    seed: inner.cfg.seed,
                };
                if let Some(w) = st.writers[rank].as_mut() {
                    if wire::write_coord(w, &welcome).is_err() {
                        return;
                    }
                }
            }
            CoordMsg::Heartbeat { rank, step } => {
                let mut st = inner.state.lock().unwrap();
                if my_inc != Some(st.incarnation) {
                    continue; // stale incarnation: ignore, keep draining
                }
                let now = inner.now_ms();
                if let Some(t) = st
                    .trackers
                    .get_mut(rank as usize)
                    .and_then(|t| t.as_mut())
                {
                    t.beat(now);
                }
                inner.max_step.fetch_max(step, Ordering::Relaxed);
            }
            CoordMsg::Ready { rank, seq } => {
                let rank = rank as usize;
                let mut st = inner.state.lock().unwrap();
                if my_inc != Some(st.incarnation) || rank >= inner.cfg.world {
                    continue;
                }
                let world = inner.cfg.world;
                let attendance = st.ready.entry(seq).or_insert_with(|| vec![false; world]);
                attendance[rank] = true;
                let complete = attendance.iter().all(|&b| b);
                if complete && !st.paused {
                    st.ready.remove(&seq);
                    // Broadcast the release; a write error here means
                    // that worker died after Ready — the heartbeat
                    // monitor owns that failure, not the barrier.
                    for w in st.writers.iter_mut().flatten() {
                        let _ = wire::write_coord(w, &CoordMsg::Release { seq });
                    }
                }
            }
            CoordMsg::Bye { rank } => {
                let mut st = inner.state.lock().unwrap();
                if my_inc == Some(st.incarnation) {
                    if let Some(t) = st.trackers.get_mut(rank as usize) {
                        *t = None; // clean exit: stop tracking liveness
                    }
                }
            }
            // Coordinator-to-worker messages arriving at the
            // coordinator are a protocol violation.
            CoordMsg::Welcome { .. } | CoordMsg::Release { .. } => return,
        }
    }
}

/// Monitor thread: sample every tracker at half the heartbeat interval,
/// accumulate misses, and report the first death per incarnation.
fn monitor_main(inner: Arc<CoordInner>) {
    let every = Duration::from_millis((inner.cfg.heartbeat_ms / 2).max(1));
    while !inner.stop.load(Ordering::Relaxed) {
        std::thread::sleep(every);
        let now = inner.now_ms();
        let mut guard = inner.state.lock().unwrap();
        let st = &mut *guard;
        if st.paused {
            continue;
        }
        let mut dead_rank = None;
        for (rank, slot) in st.trackers.iter_mut().enumerate() {
            if let Some(t) = slot {
                let (state, new_misses) = t.observe(now);
                if new_misses > 0 {
                    inner.misses.fetch_add(new_misses, Ordering::Relaxed);
                }
                if state == BeatState::Dead && dead_rank.is_none() {
                    dead_rank = Some(rank);
                }
            }
        }
        if let Some(rank) = dead_rank {
            if !st.dead_reported {
                st.dead_reported = true;
                let _ = inner.events.send(CoordEvent::Dead { rank });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- HeartbeatTracker edges (pure, no sockets, no sleeps) ----

    #[test]
    fn exactly_at_deadline_is_dead() {
        let t = HeartbeatTracker::new(10, 40, 100);
        assert_eq!(t.check(139), BeatState::Missed(3), "1ms early: not dead");
        assert_eq!(t.check(140), BeatState::Dead, ">= timeout is dead");
        assert_eq!(t.check(141), BeatState::Dead);
    }

    #[test]
    fn alive_then_missed_progression() {
        let t = HeartbeatTracker::new(10, 100, 0);
        assert_eq!(t.check(0), BeatState::Alive);
        assert_eq!(t.check(9), BeatState::Alive);
        assert_eq!(t.check(10), BeatState::Missed(1));
        assert_eq!(t.check(35), BeatState::Missed(3));
        assert_eq!(t.check(99), BeatState::Missed(9));
        assert_eq!(t.check(100), BeatState::Dead);
    }

    #[test]
    fn clock_regression_is_harmless() {
        let mut t = HeartbeatTracker::new(10, 40, 100);
        t.beat(120);
        // A beat stamped before the last one proves liveness but must
        // not move the deadline backwards...
        t.beat(90);
        assert_eq!(t.check(125), BeatState::Alive, "deadline anchored at 120");
        // ...and a regressed observation clock must not fake a timeout.
        assert_eq!(t.check(80), BeatState::Alive, "now < last_beat saturates");
        assert_eq!(t.check(160), BeatState::Dead, "real deadline still fires");
    }

    #[test]
    fn observe_accumulates_misses_monotonically() {
        let mut t = HeartbeatTracker::new(10, 1000, 0);
        assert_eq!(t.observe(5), (BeatState::Alive, 0));
        assert_eq!(t.observe(25), (BeatState::Missed(2), 2));
        // Re-observing the same silence credits only the delta.
        assert_eq!(t.observe(25), (BeatState::Missed(2), 0));
        assert_eq!(t.observe(31), (BeatState::Missed(3), 1));
        // A beat ends the silence and resets the credit.
        t.beat(31);
        assert_eq!(t.observe(35), (BeatState::Alive, 0));
        assert_eq!(t.observe(52), (BeatState::Missed(2), 2));
        // Death still counts its missed intervals.
        let mut d = HeartbeatTracker::new(10, 40, 0);
        let (state, new) = d.observe(40);
        assert_eq!(state, BeatState::Dead);
        assert_eq!(new, 4);
    }

    #[test]
    fn zero_interval_is_clamped() {
        let t = HeartbeatTracker::new(0, 10, 0);
        assert_eq!(t.check(5), BeatState::Missed(5), "interval clamped to 1");
    }

    // ---- Coordinator over real sockets ----

    fn tmp_sock(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("mtgr_coord_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("coord.sock")
    }

    fn fake_worker(sock: &Path, rank: u32, incarnation: u32) -> (UnixStream, BufReader<UnixStream>) {
        let mut stream = UnixStream::connect(sock).unwrap();
        wire::write_coord(
            &mut stream,
            &CoordMsg::Register {
                rank,
                incarnation,
                pid: std::process::id(),
            },
        )
        .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    #[test]
    fn register_welcome_then_silence_is_reported_dead() {
        let sock = tmp_sock("death");
        let coord = Coordinator::start(
            &sock,
            CoordConfig {
                world: 1,
                heartbeat_ms: 10,
                timeout_ms: 80,
                seed: 0xABCD,
            },
        )
        .unwrap();
        let (mut w, mut r) = fake_worker(&sock, 0, 0);
        let welcome = wire::read_coord(&mut r).unwrap();
        assert_eq!(
            welcome,
            CoordMsg::Welcome {
                resume_seq: 0,
                seed: 0xABCD
            }
        );
        // Beat for a bit, reporting a step, then go silent.
        for step in 0..3 {
            wire::write_coord(&mut w, &CoordMsg::Heartbeat { rank: 0, step }).unwrap();
            std::thread::sleep(Duration::from_millis(5));
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        let event = loop {
            if let Some(e) = coord.try_event() {
                break e;
            }
            assert!(Instant::now() < deadline, "death not detected in time");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(event, CoordEvent::Dead { rank: 0 });
        assert!(coord.misses() > 0, "silence accrued misses");
        assert_eq!(coord.max_step(), 2, "heartbeats carried the step");
    }

    #[test]
    fn barrier_releases_when_all_ranks_ready() {
        let sock = tmp_sock("barrier");
        let _coord = Coordinator::start(
            &sock,
            CoordConfig {
                world: 2,
                heartbeat_ms: 50,
                timeout_ms: 60_000,
                seed: 1,
            },
        )
        .unwrap();
        let (mut w0, mut r0) = fake_worker(&sock, 0, 0);
        let (mut w1, mut r1) = fake_worker(&sock, 1, 0);
        wire::read_coord(&mut r0).unwrap();
        wire::read_coord(&mut r1).unwrap();

        wire::write_coord(&mut w1, &CoordMsg::Ready { rank: 1, seq: 1 }).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        wire::write_coord(&mut w0, &CoordMsg::Ready { rank: 0, seq: 1 }).unwrap();
        // Both sides (blocking reads) get the release.
        assert_eq!(wire::read_coord(&mut r0).unwrap(), CoordMsg::Release { seq: 1 });
        assert_eq!(wire::read_coord(&mut r1).unwrap(), CoordMsg::Release { seq: 1 });
    }

    #[test]
    fn paused_barrier_holds_and_stale_incarnation_is_ignored() {
        let sock = tmp_sock("pause");
        let coord = Coordinator::start(
            &sock,
            CoordConfig {
                world: 1,
                heartbeat_ms: 50,
                timeout_ms: 60_000,
                seed: 1,
            },
        )
        .unwrap();
        let (mut w, mut r) = fake_worker(&sock, 0, 0);
        wire::read_coord(&mut r).unwrap();

        coord.pause();
        wire::write_coord(&mut w, &CoordMsg::Ready { rank: 0, seq: 1 }).unwrap();
        // No release while paused: poll with a read timeout.
        r.get_ref()
            .set_read_timeout(Some(Duration::from_millis(150)))
            .unwrap();
        assert!(
            wire::read_coord(&mut r).is_err(),
            "paused barrier must not release"
        );

        // Next incarnation welcomes with the new resume point; the
        // stale worker's registration is refused (connection dropped).
        coord.reset(3, 1);
        let (_w2, mut r2) = fake_worker(&sock, 0, 1);
        assert_eq!(
            wire::read_coord(&mut r2).unwrap(),
            CoordMsg::Welcome {
                resume_seq: 3,
                seed: 1
            }
        );
        let (_w3, mut r3) = fake_worker(&sock, 0, 0); // stale incarnation
        r3.get_ref()
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        assert!(
            wire::read_coord(&mut r3).is_err(),
            "stale registrant gets dropped, not welcomed"
        );
    }
}
