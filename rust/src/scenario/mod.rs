//! Scenario engine: named adversarial / long-run workload presets.
//!
//! Production distributed-training systems are broken by *workloads*,
//! not by unit tests: pathological sequence-length distributions that
//! defeat the balancer, flash-sale days that mint millions of fresh IDs
//! per hour and churn the admission/eviction machinery, multi-tenant
//! schemas whose per-tier capacity budgets force evictions, and
//! multi-day soak runs where any unbounded data structure eventually
//! shows. A [`Scenario`] is a small declarative spec that *composes
//! with* the existing generator / streaming / online stack — it only
//! reshapes [`GeneratorConfig`], picks a schema preset, tunes
//! [`AdmissionConfig`] / [`OnlineOptions`] defaults and carries a
//! per-group row budget; the trainer hot path is unchanged.
//!
//! Presets (`--scenario <name>`):
//!
//! - **`skew-storm`** — heavy-tailed lognormal lengths (σ = 2.0) mixing
//!   length-1 stubs with cap-length monsters in one stream; stresses
//!   the dynamic batcher's token-budget packing and carry-over.
//! - **`churn-storm`** — a flash-sale day cadence: most sequences carry
//!   brand-new user/item IDs, the generator day advances every other
//!   chunk, and admission runs with day decay + re-admission
//!   hysteresis; stresses admission/eviction churn. Online-only.
//! - **`multi-tenant`** — the three-tier 1D/8D/64D
//!   `meituan-tiered` schema with a per-group resident-row budget, so
//!   the capacity pressure of co-tenant tables is exercised; offline
//!   only (row budgets and TTL sweeps are mutually exclusive gates).
//! - **`soak`** — hours of simulated online days in one bounded run:
//!   frequent day advance, TTL expiry on by default, admission decay
//!   on; the soak suite asserts resident rows stay bounded.
//!
//! Everything a scenario does is deterministic and seed-stable, so the
//! bit-identity guarantees (threads × overlap × cross-step) hold under
//! every preset.

use crate::data::generator::GeneratorConfig;
use crate::online::{AdmissionConfig, OnlineOptions};

/// Which preset a [`Scenario`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    SkewStorm,
    ChurnStorm,
    MultiTenant,
    Soak,
}

/// A declarative workload scenario; resolve one with
/// [`Scenario::by_name`] and apply it via [`Scenario::shape_generator`]
/// / [`Scenario::apply_online_defaults`].
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub kind: ScenarioKind,
    /// Schema preset forced by the scenario (`--schema` must agree).
    pub schema_override: Option<&'static str>,
    /// Scenario only makes sense under `--mode online`.
    pub requires_online: bool,
    /// Scenario is incompatible with `--mode online`.
    pub forbids_online: bool,
    /// Per-merge-group resident-row budget (capacity pressure).
    pub row_budget: Option<usize>,
    /// Override for [`OnlineOptions::day_every`].
    pub day_every: Option<usize>,
    /// Enable count-min day decay on the admission sketch.
    pub sketch_day_decay: bool,
    /// Re-admission hysteresis margin for evicted IDs.
    pub readmit_margin: u32,
    /// Admission `(threshold, admit_prob)` installed when the user did
    /// not configure admission themselves.
    pub default_admission: Option<(u32, f64)>,
}

impl Scenario {
    fn base(name: &'static str, kind: ScenarioKind) -> Scenario {
        Scenario {
            name,
            kind,
            schema_override: None,
            requires_online: false,
            forbids_online: false,
            row_budget: None,
            day_every: None,
            sketch_day_decay: false,
            readmit_margin: 0,
            default_admission: None,
        }
    }

    /// Pathological sequence-length distribution: same mean-ish token
    /// volume, enormous variance. Works in both offline and online
    /// modes.
    pub fn skew_storm() -> Scenario {
        Scenario::base("skew-storm", ScenarioKind::SkewStorm)
    }

    /// Flash-sale ID churn: most sequences reference fresh IDs, days
    /// advance fast, admission decays across days with re-admission
    /// hysteresis. Online-only (the churn machinery lives in the
    /// online gate).
    pub fn churn_storm() -> Scenario {
        Scenario {
            requires_online: true,
            day_every: Some(2),
            sketch_day_decay: true,
            readmit_margin: 2,
            default_admission: Some((3, 0.05)),
            ..Scenario::base("churn-storm", ScenarioKind::ChurnStorm)
        }
    }

    /// Three-tier 1D/8D/64D schema with per-group capacity budgets.
    /// Offline-only: the row-budget gate and the online TTL gate both
    /// want to own eviction, and composing them would make eviction
    /// order ambiguous.
    pub fn multi_tenant() -> Scenario {
        Scenario {
            schema_override: Some("meituan-tiered"),
            forbids_online: true,
            row_budget: Some(1500),
            ..Scenario::base("multi-tenant", ScenarioKind::MultiTenant)
        }
    }

    /// Long-run soak: many simulated online days in one run, TTL and
    /// admission decay on by default so resident state is bounded.
    pub fn soak() -> Scenario {
        Scenario {
            requires_online: true,
            day_every: Some(4),
            sketch_day_decay: true,
            readmit_margin: 1,
            default_admission: Some((2, 0.1)),
            ..Scenario::base("soak", ScenarioKind::Soak)
        }
    }

    /// Preset names accepted by `--scenario`.
    pub fn preset_names() -> &'static [&'static str] {
        &["skew-storm", "churn-storm", "multi-tenant", "soak"]
    }

    /// Resolve a preset by name; the error lists the known presets.
    pub fn by_name(name: &str) -> anyhow::Result<Scenario> {
        match name {
            "skew-storm" => Ok(Scenario::skew_storm()),
            "churn-storm" => Ok(Scenario::churn_storm()),
            "multi-tenant" => Ok(Scenario::multi_tenant()),
            "soak" => Ok(Scenario::soak()),
            other => anyhow::bail!(
                "unknown scenario `{other}` (expected one of {:?})",
                Self::preset_names()
            ),
        }
    }

    /// Reshape the workload generator for this scenario. Only
    /// distributional knobs are touched — the seed is left alone so
    /// per-rank seed mixing happens exactly as without a scenario.
    pub fn shape_generator(&self, g: &mut GeneratorConfig) {
        match self.kind {
            ScenarioKind::SkewStorm => {
                // Mean exp(4 + 2²/2) ≈ 400 but σ so large the stream
                // mixes length-1 stubs with cap-length monsters.
                g.len_mu = 4.0;
                g.len_sigma = 2.0;
                g.min_len = 1;
                g.max_len = 3000;
            }
            ScenarioKind::ChurnStorm => {
                g.new_user_rate = 0.6;
                g.new_item_rate = 0.5;
                g.num_users = 400_000;
                g.num_items = 400_000;
            }
            ScenarioKind::MultiTenant => {
                // Moderate lengths, default churn: the pressure comes
                // from the tiered schema + row budget, not the stream.
                g.len_mu = 3.0;
                g.len_sigma = 0.8;
                g.min_len = 2;
                g.max_len = 256;
            }
            ScenarioKind::Soak => {
                // Sustained churn, but bounded ID spaces so the TTL
                // sweeper has revisits to keep rows alive.
                g.new_user_rate = 0.2;
                g.new_item_rate = 0.15;
            }
        }
    }

    /// Check mode compatibility (`online` = `--mode online` active).
    pub fn validate(&self, online: bool) -> anyhow::Result<()> {
        if self.requires_online && !online {
            anyhow::bail!(
                "scenario `{}` requires --mode online (its churn/TTL machinery \
                 lives in the online gate)",
                self.name
            );
        }
        if self.forbids_online && online {
            anyhow::bail!(
                "scenario `{}` is offline-only: per-group row budgets and the \
                 online TTL sweeper are mutually exclusive eviction gates",
                self.name
            );
        }
        Ok(())
    }

    /// Apply the scenario's sketch-decay / hysteresis knobs to an
    /// admission config.
    pub fn tune_admission(&self, a: &mut AdmissionConfig) {
        if self.sketch_day_decay {
            a.day_decay = true;
        }
        if self.readmit_margin > 0 {
            a.readmit_margin = self.readmit_margin;
        }
    }

    /// Fill in online defaults: day cadence, default admission policy
    /// (only when the user configured none), and — for `soak` — a TTL
    /// default of 4 sync intervals so resident rows are bounded.
    pub fn apply_online_defaults(&self, o: &mut OnlineOptions) {
        if let Some(de) = self.day_every {
            o.day_every = de;
        }
        match o.admission.as_mut() {
            Some(a) => self.tune_admission(a),
            None => {
                if let Some((threshold, prob)) = self.default_admission {
                    let mut a = AdmissionConfig::new(threshold, prob);
                    self.tune_admission(&mut a);
                    o.admission = Some(a);
                }
            }
        }
        if self.kind == ScenarioKind::Soak && o.feature_ttl == 0 {
            o.feature_ttl = 4 * o.sync_interval as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_unknown_errors() {
        for name in Scenario::preset_names() {
            let s = Scenario::by_name(name).unwrap();
            assert_eq!(s.name, *name);
        }
        let err = Scenario::by_name("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"));
        assert!(err.contains("skew-storm"), "error lists presets: {err}");
    }

    #[test]
    fn skew_storm_reshapes_lengths_only() {
        let s = Scenario::skew_storm();
        let mut g = GeneratorConfig::default();
        let before = g.clone();
        s.shape_generator(&mut g);
        assert_eq!(g.len_mu, 4.0);
        assert_eq!(g.len_sigma, 2.0);
        assert_eq!(g.min_len, 1);
        assert_eq!(g.seed, before.seed, "seed untouched");
        assert_eq!(g.new_user_rate, before.new_user_rate);
        assert!(s.validate(false).is_ok(), "skew-storm runs offline");
        assert!(s.validate(true).is_ok(), "and online");
    }

    #[test]
    fn churn_storm_requires_online_and_floods_ids() {
        let s = Scenario::churn_storm();
        assert!(s.validate(false).is_err());
        assert!(s.validate(true).is_ok());
        let mut g = GeneratorConfig::default();
        s.shape_generator(&mut g);
        assert!(g.new_user_rate >= 0.5);
        assert!(g.new_item_rate >= 0.5);
    }

    #[test]
    fn multi_tenant_forces_tiered_schema_and_forbids_online() {
        let s = Scenario::multi_tenant();
        assert_eq!(s.schema_override, Some("meituan-tiered"));
        assert!(s.row_budget.is_some());
        assert!(s.validate(true).is_err());
        assert!(s.validate(false).is_ok());
    }

    #[test]
    fn online_defaults_fill_admission_and_ttl() {
        let soak = Scenario::soak();
        let mut o = OnlineOptions::new(5);
        soak.apply_online_defaults(&mut o);
        assert_eq!(o.day_every, 4);
        assert_eq!(o.feature_ttl, 20, "soak TTL defaults to 4 intervals");
        let a = o.admission.as_ref().expect("default admission installed");
        assert_eq!(a.threshold, 2);
        assert!(a.day_decay);
        assert_eq!(a.readmit_margin, 1);
        // A user-provided admission config is tuned, not replaced.
        let mut o2 = OnlineOptions::new(5);
        o2.admission = Some(AdmissionConfig::new(7, 0.0));
        Scenario::churn_storm().apply_online_defaults(&mut o2);
        let a2 = o2.admission.as_ref().unwrap();
        assert_eq!(a2.threshold, 7, "user threshold kept");
        assert!(a2.day_decay, "decay still applied");
        assert_eq!(a2.readmit_margin, 2);
        assert_eq!(o2.feature_ttl, 0, "only soak defaults a TTL");
    }
}
