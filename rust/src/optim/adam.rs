//! Adam (Kingma & Ba) for dense vectors and sparse embedding rows.

use crate::embedding::dedup::IdMap;
use crate::embedding::{ConcurrentEmbeddingStore, EmbeddingStore, GlobalId};
use crate::util::pool::WorkerPool;
use crate::util::tuning::TunableThreshold;

/// Default parameter count above which [`DenseAdam::step_pooled`]
/// chunks the element loop across the pool (below it, fork/join
/// overhead dominates). The live value is [`PAR_DENSE`]
/// (env `MTGR_PAR_DENSE_THRESHOLD`).
pub const PAR_DENSE_THRESHOLD: usize = crate::util::tuning::calibrated::PAR_DENSE;

/// Runtime knob for the serial→parallel dense-Adam switch.
pub static PAR_DENSE: TunableThreshold =
    TunableThreshold::new("MTGR_PAR_DENSE_THRESHOLD", PAR_DENSE_THRESHOLD);

/// Adam hyperparameters (paper §6.1 uses Adam for both sparse and dense).
#[derive(Clone, Copy, Debug)]
pub struct AdamParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Width of the straight-line inner blocks the Adam kernels unroll to
/// (matches [`crate::embedding::dedup::SIMD_BLOCK`]). Blocking only
/// regroups independent per-element updates, so every blocked path is
/// bit-identical to the scalar loop.
pub const ADAM_BLOCK: usize = 8;

/// Per-call Adam coefficients with the bias corrections baked in
/// (`bcX = 1 − βX^t`; sparse rows carry per-row `t`, dense uses the
/// global step count).
#[derive(Clone, Copy)]
struct AdamCoeffs {
    scale: f32,
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
}

/// One Adam element: update the first/second moments in place and
/// return the bias-corrected step `lr·m̂ / (√v̂ + ε)`. Callers subtract
/// it from the parameter (dense) or negate it into a delta (sparse);
/// IEEE negation is a sign flip, so both forms are bitwise equal to the
/// historical inline expressions.
#[inline(always)]
fn adam_elem(m: &mut f32, v: &mut f32, g_raw: f32, c: AdamCoeffs) -> f32 {
    let g = g_raw * c.scale;
    *m = c.b1 * *m + (1.0 - c.b1) * g;
    *v = c.b2 * *v + (1.0 - c.b2) * g * g;
    let mhat = *m / c.bc1;
    let vhat = *v / c.bc2;
    c.lr * mhat / (vhat.sqrt() + c.eps)
}

/// `p[j] -= step(g[j])` over one span (same-length slices).
#[inline(always)]
fn adam_span_params(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], c: AdamCoeffs) {
    for (((p, m), v), &g) in p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g) {
        *p -= adam_elem(m, v, g, c);
    }
}

/// `delta[j] = -step(g[j])` over one span (same-length slices).
#[inline(always)]
fn adam_span_delta(delta: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], c: AdamCoeffs) {
    for (((d, m), v), &g) in delta.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g) {
        *d = -adam_elem(m, v, g, c);
    }
}

/// [`adam_span_params`] split into [`ADAM_BLOCK`]-wide exact chunks
/// (the array conversions pin the block length so the autovectorizer
/// emits straight vector lanes) plus a scalar tail for odd lengths.
#[inline]
fn adam_blocked_params(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], c: AdamCoeffs) {
    let mut pc = p.chunks_exact_mut(ADAM_BLOCK);
    let mut mc = m.chunks_exact_mut(ADAM_BLOCK);
    let mut vc = v.chunks_exact_mut(ADAM_BLOCK);
    let mut gc = g.chunks_exact(ADAM_BLOCK);
    for (((pb, mb), vb), gb) in (&mut pc).zip(&mut mc).zip(&mut vc).zip(&mut gc) {
        let pb: &mut [f32; ADAM_BLOCK] = pb.try_into().unwrap();
        let mb: &mut [f32; ADAM_BLOCK] = mb.try_into().unwrap();
        let vb: &mut [f32; ADAM_BLOCK] = vb.try_into().unwrap();
        let gb: &[f32; ADAM_BLOCK] = gb.try_into().unwrap();
        adam_span_params(pb, mb, vb, gb, c);
    }
    adam_span_params(
        pc.into_remainder(),
        mc.into_remainder(),
        vc.into_remainder(),
        gc.remainder(),
        c,
    );
}

/// [`adam_span_delta`] with the same blocked structure as
/// [`adam_blocked_params`].
#[inline]
fn adam_blocked_delta(delta: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], c: AdamCoeffs) {
    let mut dc = delta.chunks_exact_mut(ADAM_BLOCK);
    let mut mc = m.chunks_exact_mut(ADAM_BLOCK);
    let mut vc = v.chunks_exact_mut(ADAM_BLOCK);
    let mut gc = g.chunks_exact(ADAM_BLOCK);
    for (((db, mb), vb), gb) in (&mut dc).zip(&mut mc).zip(&mut vc).zip(&mut gc) {
        let db: &mut [f32; ADAM_BLOCK] = db.try_into().unwrap();
        let mb: &mut [f32; ADAM_BLOCK] = mb.try_into().unwrap();
        let vb: &mut [f32; ADAM_BLOCK] = vb.try_into().unwrap();
        let gb: &[f32; ADAM_BLOCK] = gb.try_into().unwrap();
        adam_span_delta(db, mb, vb, gb, c);
    }
    adam_span_delta(
        dc.into_remainder(),
        mc.into_remainder(),
        vc.into_remainder(),
        gc.remainder(),
        c,
    );
}

/// Adam over the flat dense parameter vector.
#[derive(Clone, Debug)]
pub struct DenseAdam {
    pub hp: AdamParams,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl DenseAdam {
    pub fn new(n: usize, hp: AdamParams) -> Self {
        DenseAdam {
            hp,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// One update. `grads` are *sums*; `scale` converts them to the mean
    /// (the weighted-averaging factor 1/total_samples from §5.1).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], scale: f32) {
        self.step_pooled(params, grads, scale, None);
    }

    /// [`step`](Self::step) with the element loop chunked across `pool`
    /// (per-element math is independent, so results are bit-identical
    /// for every pool size; small vectors stay on the serial path).
    pub fn step_pooled(
        &mut self,
        params: &mut [f32],
        grads: &[f32],
        scale: f32,
        pool: Option<&WorkerPool>,
    ) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1 = self.hp.beta1;
        let b2 = self.hp.beta2;
        let c = AdamCoeffs {
            scale,
            b1,
            b2,
            bc1: 1.0 - b1.powi(self.t as i32),
            bc2: 1.0 - b2.powi(self.t as i32),
            lr: self.hp.lr,
            eps: self.hp.eps,
        };
        let kernel = |r: std::ops::Range<usize>, p: &mut [f32], m: &mut [f32], v: &mut [f32]| {
            adam_blocked_params(p, m, v, &grads[r], c);
        };
        match pool {
            Some(pl) if pl.threads() > 1 && params.len() >= PAR_DENSE.get() => {
                use crate::util::pool::SharedSliceMut;
                let pw = SharedSliceMut::new(params);
                let mw = SharedSliceMut::new(&mut self.m);
                let vw = SharedSliceMut::new(&mut self.v);
                let kernel = &kernel;
                let (pw, mw, vw) = (&pw, &mw, &vw);
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    WorkerPool::chunk_ranges(pw.len(), pl.threads())
                        .into_iter()
                        .map(|r| {
                            Box::new(move || {
                                // SAFETY: chunk ranges are disjoint and
                                // each range is handed to one task, so
                                // the three windows below are written
                                // by exactly one chunk each.
                                unsafe {
                                    kernel(
                                        r.clone(),
                                        pw.slice_mut(r.start, r.len()),
                                        mw.slice_mut(r.start, r.len()),
                                        vw.slice_mut(r.start, r.len()),
                                    );
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                pl.run_scope(tasks);
            }
            _ => {
                let n = params.len();
                kernel(0..n, params, &mut self.m, &mut self.v);
            }
        }
    }

    /// Serialize optimizer state (for checkpointing): m ++ v ++ t.
    pub fn state_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.m.len() * 8 + 8);
        for x in self.m.iter().chain(self.v.iter()) {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out.extend_from_slice(&self.t.to_le_bytes());
        out
    }

    pub fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let n = self.m.len();
        anyhow::ensure!(
            bytes.len() == n * 8 + 8,
            "dense adam state size mismatch: {} vs {}",
            bytes.len(),
            n * 8 + 8
        );
        for i in 0..n {
            self.m[i] = f32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 0..n {
            let off = (n + i) * 4;
            self.v[i] = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        }
        self.t = u64::from_le_bytes(bytes[n * 8..].try_into().unwrap());
        Ok(())
    }
}

/// Per-row Adam state for sparse embeddings.
#[derive(Clone, Debug)]
pub struct RowState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

/// Row-wise Adam for embedding rows; state materializes lazily on first
/// update (only activated rows carry state — §5.2).
#[derive(Clone, Debug)]
pub struct SparseAdam {
    pub hp: AdamParams,
    pub dim: usize,
    state: IdMap<RowState>,
}

impl SparseAdam {
    pub fn new(dim: usize, hp: AdamParams) -> Self {
        SparseAdam {
            hp,
            dim,
            state: IdMap::default(),
        }
    }

    pub fn tracked_rows(&self) -> usize {
        self.state.len()
    }

    /// Update the rows for `ids` in `table` with (sum) gradients `grads`
    /// scaled by `scale`. Rows absent from the table (e.g. evicted
    /// between forward and backward) are skipped.
    pub fn step<S: EmbeddingStore>(
        &mut self,
        table: &mut S,
        ids: &[GlobalId],
        grads: &[f32],
        scale: f32,
    ) {
        assert_eq!(grads.len(), ids.len() * self.dim);
        let d = self.dim;
        let hp = self.hp;
        let mut delta = vec![0.0f32; d];
        for (i, &id) in ids.iter().enumerate() {
            let st = self.state.entry(id).or_insert_with(|| RowState {
                m: vec![0.0; d],
                v: vec![0.0; d],
                t: 0,
            });
            st.t += 1;
            let c = AdamCoeffs {
                scale,
                b1: hp.beta1,
                b2: hp.beta2,
                bc1: 1.0 - hp.beta1.powi(st.t as i32),
                bc2: 1.0 - hp.beta2.powi(st.t as i32),
                lr: hp.lr,
                eps: hp.eps,
            };
            adam_blocked_delta(&mut delta, &mut st.m, &mut st.v, &grads[i * d..(i + 1) * d], c);
            table.apply_delta(id, &delta);
        }
    }

    /// [`step`](Self::step) over a concurrently updatable table,
    /// fanning the per-row Adam math and `apply_delta` calls across the
    /// pool. `ids` must be unique (the sparse accumulator drains unique
    /// sorted ids) — rows and their optimizer states are then disjoint,
    /// so the update is embarrassingly parallel and **bit-identical**
    /// to the serial [`step`](Self::step) for every pool size.
    pub fn step_concurrent<S: ConcurrentEmbeddingStore + ?Sized>(
        &mut self,
        pool: &WorkerPool,
        table: &S,
        ids: &[GlobalId],
        grads: &[f32],
        scale: f32,
    ) {
        assert_eq!(grads.len(), ids.len() * self.dim);
        // Always-on uniqueness check: duplicate ids would alias the raw
        // row-state pointers below and race across pool threads (UB), so
        // this must hold in release builds too. The accumulator drains
        // strictly ascending ids, so the common case is one O(n) scan;
        // only unsorted input pays the sort-based fallback.
        if !ids.windows(2).all(|w| w[0] < w[1]) {
            let mut v = ids.to_vec();
            v.sort_unstable();
            assert!(
                v.windows(2).all(|w| w[0] != w[1]),
                "step_concurrent requires unique ids"
            );
        }
        let d = self.dim;
        // Phase 1 (serial): materialize every row's state, then collect
        // stable pointers. No map mutation happens after this point, so
        // the pointers stay valid through the parallel region.
        for &id in ids {
            self.state.entry(id).or_insert_with(|| RowState {
                m: vec![0.0; d],
                v: vec![0.0; d],
                t: 0,
            });
        }
        struct StatePtrs(Vec<*mut RowState>);
        unsafe impl Send for StatePtrs {}
        unsafe impl Sync for StatePtrs {}
        let states = StatePtrs(
            ids.iter()
                .map(|id| self.state.get_mut(id).unwrap() as *mut RowState)
                .collect(),
        );
        let hp = self.hp;
        // Phase 2 (parallel): per-row Adam + delta application. Chunk
        // boundaries cannot affect the result — every row is touched by
        // exactly one task and rows are independent.
        pool.parallel_for(ids.len(), |range| {
            let mut delta = vec![0.0f32; d];
            for i in range {
                // SAFETY: `ids` are unique, so `states.0[i]` are
                // pairwise distinct; the map is not mutated while the
                // scope runs (phase 1 finished, `self` is borrowed).
                let st = unsafe { &mut *states.0[i] };
                st.t += 1;
                let c = AdamCoeffs {
                    scale,
                    b1: hp.beta1,
                    b2: hp.beta2,
                    bc1: 1.0 - hp.beta1.powi(st.t as i32),
                    bc2: 1.0 - hp.beta2.powi(st.t as i32),
                    lr: hp.lr,
                    eps: hp.eps,
                };
                adam_blocked_delta(
                    &mut delta,
                    &mut st.m,
                    &mut st.v,
                    &grads[i * d..(i + 1) * d],
                    c,
                );
                table.apply_delta(ids[i], &delta);
            }
        });
    }

    /// Iterate over (id, state) for checkpointing.
    pub fn iter_state(&self) -> impl Iterator<Item = (&GlobalId, &RowState)> {
        self.state.iter()
    }

    /// Restore one row's state (checkpoint load).
    pub fn restore_row(&mut self, id: GlobalId, st: RowState) {
        assert_eq!(st.m.len(), self.dim);
        assert_eq!(st.v.len(), self.dim);
        self.state.insert(id, st);
    }

    /// Drop state for ids not owned anymore (resharding) or evicted.
    pub fn retain(&mut self, keep: impl Fn(GlobalId) -> bool) {
        self.state.retain(|id, _| keep(*id));
    }

    /// Drop a single row's state (TTL expiry / eviction); returns
    /// whether any state was tracked.
    pub fn drop_row(&mut self, id: GlobalId) -> bool {
        self.state.remove(&id).is_some()
    }

    pub fn row_state(&self, id: GlobalId) -> Option<&RowState> {
        self.state.get(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};

    #[test]
    fn dense_adam_minimizes_quadratic() {
        // f(p) = ||p - target||²; Adam must converge.
        let target = [3.0f32, -1.0, 0.5];
        let mut p = vec![0.0f32; 3];
        let mut opt = DenseAdam::new(3, AdamParams {
            lr: 0.05,
            ..Default::default()
        });
        for _ in 0..500 {
            let grads: Vec<f32> = p.iter().zip(&target).map(|(x, t)| 2.0 * (x - t)).collect();
            opt.step(&mut p, &grads, 1.0);
        }
        for (x, t) in p.iter().zip(&target) {
            assert!((x - t).abs() < 0.05, "{x} vs {t}");
        }
        assert_eq!(opt.step_count(), 500);
    }

    #[test]
    fn dense_adam_scale_equivalence() {
        // step(g_sum, scale=1/n) == step(g_mean, 1.0).
        let mut p1 = vec![1.0f32, 2.0];
        let mut p2 = p1.clone();
        let mut o1 = DenseAdam::new(2, AdamParams::default());
        let mut o2 = DenseAdam::new(2, AdamParams::default());
        o1.step(&mut p1, &[10.0, -6.0], 0.5);
        o2.step(&mut p2, &[5.0, -3.0], 1.0);
        assert_eq!(p1, p2);
    }

    #[test]
    fn dense_state_roundtrip() {
        let mut p = vec![0.3f32; 4];
        let mut o1 = DenseAdam::new(4, AdamParams::default());
        for i in 0..7 {
            o1.step(&mut p, &[0.1 * i as f32; 4], 1.0);
        }
        let bytes = o1.state_bytes();
        let mut o2 = DenseAdam::new(4, AdamParams::default());
        o2.restore_state(&bytes).unwrap();
        // Next step identical from both.
        let mut pa = p.clone();
        let mut pb = p.clone();
        o1.step(&mut pa, &[0.5; 4], 1.0);
        o2.step(&mut pb, &[0.5; 4], 1.0);
        assert_eq!(pa, pb);
        assert!(o2.restore_state(&bytes[1..]).is_err());
    }

    #[test]
    fn sparse_adam_updates_only_activated_rows() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(2).with_capacity(64),
        );
        let mut buf = vec![0.0; 2];
        t.lookup_or_insert(1, &mut buf);
        let before1 = buf.clone();
        t.lookup_or_insert(2, &mut buf);
        let before2 = buf.clone();

        let mut opt = SparseAdam::new(2, AdamParams::default());
        opt.step(&mut t, &[1], &[1.0, -1.0], 1.0);
        assert_eq!(opt.tracked_rows(), 1);

        let mut after1 = vec![0.0; 2];
        let mut after2 = vec![0.0; 2];
        t.lookup(1, &mut after1);
        t.lookup(2, &mut after2);
        assert_ne!(after1, before1, "activated row updated");
        assert_eq!(after2, before2, "untouched row unchanged");
        // Adam first step moves by ≈ lr in -sign(g).
        assert!(after1[0] < before1[0] && after1[1] > before1[1]);
    }

    #[test]
    fn sparse_adam_per_row_time_steps() {
        // Rows updated at different frequencies keep independent bias
        // correction — verify via matching a dense Adam on one row.
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(3).with_capacity(64),
        );
        let mut init = vec![0.0; 3];
        t.lookup_or_insert(7, &mut init);
        let mut sparse = SparseAdam::new(3, AdamParams::default());

        let mut dense_p = init.clone();
        let mut dense = DenseAdam::new(3, AdamParams::default());
        for step in 0..5 {
            let g = vec![0.2 * (step + 1) as f32; 3];
            sparse.step(&mut t, &[7], &g, 1.0);
            dense.step(&mut dense_p, &g, 1.0);
        }
        let mut row = vec![0.0; 3];
        t.lookup(7, &mut row);
        for (a, b) in row.iter().zip(&dense_p) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn step_concurrent_bit_identical_to_serial_step() {
        use crate::embedding::concurrent::ConcurrentDynamicTable;
        let cfg = DynamicTableConfig::new(4).with_capacity(4096).with_seed(3);
        let mut serial_table = ConcurrentDynamicTable::new(cfg.clone(), 8);
        let conc_table = ConcurrentDynamicTable::new(cfg, 8);
        let ids: Vec<u64> = (0..3000).collect();
        let mut buf = vec![0.0f32; 4];
        for &id in &ids {
            EmbeddingStore::lookup_or_insert(&mut serial_table, id, &mut buf);
            ConcurrentDynamicTable::lookup_or_insert(&conc_table, id, &mut buf);
        }
        let mut o1 = SparseAdam::new(4, AdamParams::default());
        let mut o2 = SparseAdam::new(4, AdamParams::default());
        let pool = crate::util::pool::WorkerPool::new(4);
        for round in 0..3usize {
            let grads: Vec<f32> = (0..ids.len() * 4)
                .map(|i| ((i + round) % 13) as f32 * 0.01 - 0.05)
                .collect();
            o1.step(&mut serial_table, &ids, &grads, 0.5);
            o2.step_concurrent(&pool, &conc_table, &ids, &grads, 0.5);
        }
        assert_eq!(
            serial_table.content_checksum(),
            conc_table.content_checksum(),
            "table contents diverged"
        );
        for &id in &ids[..50] {
            let a = o1.row_state(id).unwrap();
            let b = o2.row_state(id).unwrap();
            assert_eq!(a.m, b.m, "id {id} m");
            assert_eq!(a.v, b.v, "id {id} v");
            assert_eq!(a.t, b.t, "id {id} t");
        }
    }

    #[test]
    fn dense_step_pooled_bit_identical_to_serial() {
        // Above the parallel threshold, every pool size must reproduce
        // the serial update bit-for-bit (per-element math is
        // independent; chunking cannot change it).
        let n = 10_000usize;
        let grads: Vec<f32> = (0..n).map(|i| ((i % 31) as f32 - 15.0) * 0.01).collect();
        let mut p_ref = vec![0.25f32; n];
        let mut o_ref = DenseAdam::new(n, AdamParams::default());
        for _ in 0..3 {
            o_ref.step(&mut p_ref, &grads, 0.5);
        }
        for threads in [1usize, 2, 4] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            let mut p = vec![0.25f32; n];
            let mut o = DenseAdam::new(n, AdamParams::default());
            for _ in 0..3 {
                o.step_pooled(&mut p, &grads, 0.5, Some(&pool));
            }
            assert_eq!(p, p_ref, "{threads} threads");
            assert_eq!(o.state_bytes(), o_ref.state_bytes(), "{threads} threads state");
        }
    }

    #[test]
    fn sparse_retain_drops_state() {
        let mut t = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(1).with_capacity(64),
        );
        let mut buf = vec![0.0];
        for id in 0..10 {
            t.lookup_or_insert(id, &mut buf);
        }
        let mut opt = SparseAdam::new(1, AdamParams::default());
        let flat: Vec<f32> = (0..10).map(|_| 1.0).collect();
        let ids: Vec<u64> = (0..10).collect();
        opt.step(&mut t, &ids, &flat, 1.0);
        assert_eq!(opt.tracked_rows(), 10);
        opt.retain(|id| id % 2 == 0);
        assert_eq!(opt.tracked_rows(), 5);
        assert!(opt.row_state(1).is_none());
        assert!(opt.row_state(2).is_some());
    }
}
