//! Optimizers and gradient accumulation (§3 "Backward Update", §5.2
//! "Gradient Accumulation").
//!
//! - [`DenseAdam`] — Adam over the flat dense parameter vector (the L2
//!   model's gradients come back from the PJRT train artifact; the
//!   optimizer state lives in Rust, never in the compiled graph).
//! - [`SparseAdam`] — row-wise Adam for embedding rows with lazily
//!   materialized per-row state; only *activated* rows are updated
//!   (§5.2: "we avoid full parameter updates for sparse embeddings,
//!   instead selectively updating only activated parts").
//! - [`DenseAccumulator`] / [`SparseAccumulator`] — gradient
//!   accumulation across micro-batches; sparse accumulation is keyed by
//!   embedding ID so duplicate activations across batches sum before a
//!   single collective update.

pub mod adam;

pub use adam::{AdamParams, DenseAdam, SparseAdam};

use crate::embedding::dedup::IdMap;
use crate::embedding::GlobalId;

/// Dense gradient accumulator (sums; caller divides by sample count via
/// the weighted-averaging scale).
#[derive(Clone, Debug)]
pub struct DenseAccumulator {
    grads: Vec<f32>,
    /// Accumulated sample count (for weighted averaging).
    pub samples: u64,
    /// Micro-batches accumulated since the last take().
    pub micro_batches: usize,
}

impl DenseAccumulator {
    pub fn new(n: usize) -> Self {
        DenseAccumulator {
            grads: vec![0.0; n],
            samples: 0,
            micro_batches: 0,
        }
    }

    pub fn add(&mut self, grads: &[f32], samples: u64) {
        assert_eq!(grads.len(), self.grads.len());
        for (a, g) in self.grads.iter_mut().zip(grads) {
            *a += g;
        }
        self.samples += samples;
        self.micro_batches += 1;
    }

    /// Drain the accumulated sums, resetting to zero.
    pub fn take(&mut self) -> (Vec<f32>, u64) {
        let samples = self.samples;
        self.samples = 0;
        self.micro_batches = 0;
        let n = self.grads.len();
        let grads = std::mem::replace(&mut self.grads, vec![0.0; n]);
        (grads, samples)
    }

    pub fn is_empty(&self) -> bool {
        self.micro_batches == 0
    }
}

/// Sparse (ID-keyed) gradient accumulator: "gradients from identical IDs
/// across multiple batches are accumulated and then updated collectively"
/// (§5.2).
#[derive(Clone, Debug, Default)]
pub struct SparseAccumulator {
    pub dim: usize,
    grads: IdMap<Vec<f32>>,
    pub samples: u64,
    pub micro_batches: usize,
}

impl SparseAccumulator {
    pub fn new(dim: usize) -> Self {
        SparseAccumulator {
            dim,
            grads: IdMap::default(),
            samples: 0,
            micro_batches: 0,
        }
    }

    /// Add one micro-batch's aggregated (id, grad) pairs.
    pub fn add(&mut self, ids: &[GlobalId], grads: &[f32], samples: u64) {
        assert_eq!(grads.len(), ids.len() * self.dim);
        for (i, &id) in ids.iter().enumerate() {
            let g = &grads[i * self.dim..(i + 1) * self.dim];
            match self.grads.get_mut(&id) {
                Some(acc) => {
                    for (a, x) in acc.iter_mut().zip(g) {
                        *a += x;
                    }
                }
                None => {
                    self.grads.insert(id, g.to_vec());
                }
            }
        }
        self.samples += samples;
        self.micro_batches += 1;
    }

    /// Drain as (ids, flat grads) in deterministic (sorted-id) order.
    pub fn take(&mut self) -> (Vec<GlobalId>, Vec<f32>, u64) {
        let mut ids: Vec<GlobalId> = self.grads.keys().copied().collect();
        ids.sort_unstable();
        let mut flat = Vec::with_capacity(ids.len() * self.dim);
        for id in &ids {
            flat.extend_from_slice(&self.grads[id]);
        }
        let samples = self.samples;
        self.grads.clear();
        self.samples = 0;
        self.micro_batches = 0;
        (ids, flat, samples)
    }

    pub fn unique_ids(&self) -> usize {
        self.grads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.micro_batches == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_accumulates_and_resets() {
        let mut acc = DenseAccumulator::new(3);
        acc.add(&[1.0, 2.0, 3.0], 4);
        acc.add(&[0.5, 0.5, 0.5], 2);
        assert_eq!(acc.micro_batches, 2);
        let (g, n) = acc.take();
        assert_eq!(g, vec![1.5, 2.5, 3.5]);
        assert_eq!(n, 6);
        assert!(acc.is_empty());
        let (g2, n2) = acc.take();
        assert_eq!(g2, vec![0.0; 3]);
        assert_eq!(n2, 0);
    }

    #[test]
    fn sparse_merges_duplicate_ids_across_batches() {
        let mut acc = SparseAccumulator::new(2);
        acc.add(&[10, 20], &[1.0, 1.0, 2.0, 2.0], 3);
        acc.add(&[20, 30], &[0.5, 0.5, 9.0, 9.0], 3);
        assert_eq!(acc.unique_ids(), 3);
        let (ids, flat, n) = acc.take();
        assert_eq!(ids, vec![10, 20, 30]);
        assert_eq!(flat, vec![1.0, 1.0, 2.5, 2.5, 9.0, 9.0]);
        assert_eq!(n, 6);
        assert!(acc.is_empty());
    }
}
