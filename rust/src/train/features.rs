//! Batch → embedding-input plumbing: flatten feature-ID occurrences for
//! the sharded lookup, pool looked-up rows into the (B, L, d) embedding
//! tensor the L2 model consumes, and scatter the model's embedding
//! gradient back onto the contributing occurrences.
//!
//! The occurrence stream is split **per merge group**
//! ([`crate::embedding::merge::MergePlan`]): each feature routes to
//! exactly one group, and each group's IDs form their own
//! occurrence-ordered list at the group's embedding width — the unit
//! the per-group [`crate::embedding::sharded::ShardedEmbedding`]
//! exchanges operate on. With a homogeneous schema there is exactly one
//! group and the stream is byte-identical to the historical flat
//! layout.
//!
//! Layout within a group: for each sequence `b` (in batch order) the
//! group's occurrences are `its context ids`, then `its token-feature
//! ids` per token, features in declaration order. Token embeddings are
//! the SUM of their feature rows plus the pooled context embedding
//! (context features influence every position); rows narrower than the
//! model dim add into the *leading* components (zero-extension).
//! Gradients mirror that sum exactly: each contributing occurrence
//! receives the leading `dim_g` components of the token's gradient;
//! context occurrences receive the sequence-summed gradient.

use crate::balance::Batch;
use crate::data::schema::Schema;
use crate::embedding::merge::MergePlan;
use crate::embedding::GlobalId;
use crate::util::pool::{SharedSliceMut, WorkerPool};

/// One merge group's flattened occurrence ids + pooling layout.
#[derive(Clone, Debug)]
pub struct GroupIds {
    /// The group's embedding dim (row width on the wire and in the
    /// shard table).
    pub dim: usize,
    /// Occurrence-ordered global IDs of this group (context-first per
    /// sequence).
    pub ids: Vec<GlobalId>,
    /// Per-sequence (context_offset, token_offset, len) in this group's
    /// occurrence space.
    layout: Vec<(usize, usize, usize)>,
    /// Context / token features routed to this group.
    n_ctx: usize,
    n_tok: usize,
}

/// Flattened occurrence ids for a batch, one stream per merge group.
#[derive(Clone, Debug)]
pub struct BatchIds {
    /// Per merge-plan group, in group order.
    pub groups: Vec<GroupIds>,
    n_sequences: usize,
}

impl BatchIds {
    /// Build the occurrence streams for a batch under the merge plan
    /// (serial reference; see [`build_pooled`](Self::build_pooled)).
    pub fn build(batch: &Batch, schema: &Schema, plan: &MergePlan) -> BatchIds {
        Self::build_pooled(batch, schema, plan, None)
    }

    /// [`build`](Self::build) with the per-token ID-mapping pass fanned
    /// across `pool`. Every sequence owns a contiguous occurrence span
    /// *per group* whose bounds are a pure function of the sequence
    /// lengths, so chunks write disjoint windows and each id is a pure
    /// function of its occurrence: the output is bit-identical for
    /// every pool size.
    pub fn build_pooled(
        batch: &Batch,
        schema: &Schema,
        plan: &MergePlan,
        pool: Option<&WorkerPool>,
    ) -> BatchIds {
        let n_groups = plan.num_groups();
        let n = batch.sequences.len();
        // Route features to groups (declaration order within a group).
        let mut ctx_feats: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        let mut tok_feats: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        for (f, fc) in schema.context_features.iter().enumerate() {
            ctx_feats[plan.feature_to_table[&fc.name].0].push(f);
        }
        for (f, fc) in schema.token_features.iter().enumerate() {
            tok_feats[plan.feature_to_table[&fc.name].0].push(f);
        }
        // Span layouts first (cheap, serial): in group `g`, sequence `b`
        // owns occurrences `[layouts[g][b].0, layouts[g][b].0 + n_ctx_g
        // + len·n_tok_g)`.
        let mut layouts: Vec<Vec<(usize, usize, usize)>> = Vec::with_capacity(n_groups);
        let mut totals: Vec<usize> = Vec::with_capacity(n_groups);
        for g in 0..n_groups {
            let (n_ctx, n_tok) = (ctx_feats[g].len(), tok_feats[g].len());
            let mut layout = Vec::with_capacity(n);
            let mut off = 0usize;
            for seq in &batch.sequences {
                layout.push((off, off + n_ctx, seq.len()));
                off += n_ctx + seq.len() * n_tok;
            }
            layouts.push(layout);
            totals.push(off);
        }
        let mut ids_bufs: Vec<Vec<GlobalId>> =
            totals.iter().map(|&t| vec![0; t]).collect();

        // Map one sequence's ids of one group into its span (`dst`
        // starts at the sequence's first occurrence in that group).
        let write_seq = |g: usize, b: usize, dst: &mut [GlobalId]| {
            let seq = &batch.sequences[b];
            let mut k = 0usize;
            for &f in &ctx_feats[g] {
                let (_g, gid) =
                    plan.global_id(&schema.context_features[f].name, seq.context[f]);
                dst[k] = gid;
                k += 1;
            }
            for tok in &seq.tokens {
                for &f in &tok_feats[g] {
                    let (_g, gid) =
                        plan.global_id(&schema.token_features[f].name, tok[f]);
                    dst[k] = gid;
                    k += 1;
                }
            }
        };
        // First occurrence of sequence `b` in group `g` (end = total).
        let occ_start =
            |g: usize, b: usize| -> usize { if b < n { layouts[g][b].0 } else { totals[g] } };
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                let windows: Vec<SharedSliceMut<GlobalId>> = ids_bufs
                    .iter_mut()
                    .map(|v| SharedSliceMut::new(&mut v[..]))
                    .collect();
                let windows = &windows;
                let write_seq = &write_seq;
                let occ_start = &occ_start;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    WorkerPool::chunk_ranges(n, p.threads())
                        .into_iter()
                        .map(|sr| {
                            Box::new(move || {
                                for g in 0..n_groups {
                                    let (o0, o1) =
                                        (occ_start(g, sr.start), occ_start(g, sr.end));
                                    // SAFETY: sequence chunks are
                                    // disjoint and each owns the
                                    // contiguous per-group occurrence
                                    // span [o0, o1).
                                    let dst = unsafe { windows[g].slice_mut(o0, o1 - o0) };
                                    let mut cur = 0usize;
                                    for b in sr.clone() {
                                        let span = occ_start(g, b + 1) - occ_start(g, b);
                                        write_seq(g, b, &mut dst[cur..cur + span]);
                                        cur += span;
                                    }
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                p.run_scope(tasks);
            }
            _ => {
                for g in 0..n_groups {
                    for b in 0..n {
                        let start = layouts[g][b].0;
                        let span = occ_start(g, b + 1) - start;
                        write_seq(g, b, &mut ids_bufs[g][start..start + span]);
                    }
                }
            }
        }
        let groups = ids_bufs
            .into_iter()
            .zip(layouts)
            .enumerate()
            .map(|(g, (ids, layout))| GroupIds {
                dim: plan.groups[g].dim,
                ids,
                layout,
                n_ctx: ctx_feats[g].len(),
                n_tok: tok_feats[g].len(),
            })
            .collect();
        BatchIds {
            groups,
            n_sequences: n,
        }
    }

    pub fn num_sequences(&self) -> usize {
        self.n_sequences
    }

    /// Total occurrences across all groups.
    pub fn total_ids(&self) -> usize {
        self.groups.iter().map(|g| g.ids.len()).sum()
    }

    /// Token count of sequence `b`.
    fn seq_len(&self, b: usize) -> usize {
        self.groups.first().map_or(0, |g| g.layout[b].2)
    }

    /// Pool looked-up rows (one occurrence-ordered buffer per group,
    /// `groups[g].dim` wide) into the padded (bucket_b, bucket_l, dim)
    /// embedding tensor. Sequences beyond `bucket_l` tokens are *not*
    /// truncated by this function — callers must have bucketized
    /// correctly (asserted).
    pub fn pool(
        &self,
        rows: &[Vec<f32>],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
    ) -> Vec<f32> {
        let mut emb = Vec::new();
        self.pool_into(rows, dim, bucket_b, bucket_l, None, &mut emb);
        emb
    }

    /// Pool one sequence's rows into its (bucket_l, dim) slot.
    fn pool_one(
        &self,
        b: usize,
        rows: &[Vec<f32>],
        dim: usize,
        bucket_l: usize,
        dst: &mut [f32],
    ) {
        let len = self.seq_len(b);
        assert!(len <= bucket_l, "sequence exceeds bucket length");
        // Pooled context embedding: narrower groups add into the
        // leading components.
        let mut ctx = vec![0.0f32; dim];
        for (gi, g) in self.groups.iter().enumerate() {
            let (ctx_off, _, _) = g.layout[b];
            for c in 0..g.n_ctx {
                let r = &rows[gi][(ctx_off + c) * g.dim..(ctx_off + c + 1) * g.dim];
                for (a, x) in ctx[..g.dim].iter_mut().zip(r) {
                    *a += x;
                }
            }
        }
        for t in 0..len {
            let e = &mut dst[t * dim..(t + 1) * dim];
            e.copy_from_slice(&ctx);
            for (gi, g) in self.groups.iter().enumerate() {
                let (_, tok_off, _) = g.layout[b];
                for f in 0..g.n_tok {
                    let occ = tok_off + t * g.n_tok + f;
                    let r = &rows[gi][occ * g.dim..(occ + 1) * g.dim];
                    for (a, x) in e[..g.dim].iter_mut().zip(r) {
                        *a += x;
                    }
                }
            }
        }
    }

    /// [`pool`](Self::pool) into a caller-owned buffer (reused across
    /// steps — no allocation in steady state), fanning sequences across
    /// `pool` when supplied. Per-sequence output slots are disjoint, so
    /// the result is bit-identical for every pool size.
    pub fn pool_into(
        &self,
        rows: &[Vec<f32>],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
        pool: Option<&WorkerPool>,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(rows.len(), self.groups.len(), "one row buffer per group");
        for (g, r) in self.groups.iter().zip(rows) {
            assert_eq!(r.len(), g.ids.len() * g.dim, "group row arity");
        }
        assert!(self.n_sequences <= bucket_b, "batch exceeds bucket");
        out.clear();
        out.resize(bucket_b * bucket_l * dim, 0.0);
        let n = self.n_sequences;
        if n == 0 {
            return;
        }
        let stride = bucket_l * dim;
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                p.parallel_for_chunks_mut(&mut out[..n * stride], n, stride, |r, chunk| {
                    for (j, b) in r.enumerate() {
                        self.pool_one(
                            b,
                            rows,
                            dim,
                            bucket_l,
                            &mut chunk[j * stride..(j + 1) * stride],
                        );
                    }
                });
            }
            _ => {
                for b in 0..n {
                    self.pool_one(b, rows, dim, bucket_l, &mut out[b * stride..(b + 1) * stride]);
                }
            }
        }
    }

    /// Scatter one sequence's gradient into each group's occurrence
    /// positions, relative to `base[g]` (the first occurrence index of
    /// `dst[g]` in group `g`'s occurrence space).
    fn scatter_one(
        &self,
        b: usize,
        emb_grad: &[f32],
        dim: usize,
        bucket_l: usize,
        base: &[usize],
        dst: &mut [&mut [f32]],
    ) {
        let len = self.seq_len(b);
        // Context occurrences accumulate the sequence-summed grad.
        let mut ctx_g = vec![0.0f32; dim];
        for t in 0..len {
            let src = (b * bucket_l + t) * dim;
            let g_row = &emb_grad[src..src + dim];
            for (a, x) in ctx_g.iter_mut().zip(g_row) {
                *a += x;
            }
            for (gi, g) in self.groups.iter().enumerate() {
                let (_, tok_off, _) = g.layout[b];
                for f in 0..g.n_tok {
                    let occ = tok_off + t * g.n_tok + f - base[gi];
                    dst[gi][occ * g.dim..(occ + 1) * g.dim]
                        .copy_from_slice(&g_row[..g.dim]);
                }
            }
        }
        for (gi, g) in self.groups.iter().enumerate() {
            let (ctx_off, _, _) = g.layout[b];
            for c in 0..g.n_ctx {
                let occ = ctx_off + c - base[gi];
                dst[gi][occ * g.dim..(occ + 1) * g.dim].copy_from_slice(&ctx_g[..g.dim]);
            }
        }
    }

    /// Scatter the model's embedding gradient (bucket_b, bucket_l, dim)
    /// back to per-group occurrence order (matching `groups[g].ids`).
    pub fn scatter_grad(
        &self,
        emb_grad: &[f32],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
    ) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        self.scatter_grad_into(emb_grad, dim, bucket_b, bucket_l, None, &mut out);
        out
    }

    /// [`scatter_grad`](Self::scatter_grad) into caller-owned buffers
    /// (one per group), fanning sequence chunks across `pool`. Each
    /// sequence owns a contiguous occurrence span per group (context
    /// ids then token ids, in batch order — the `build` layout), so
    /// chunk windows are disjoint and the result is bit-identical for
    /// every pool size.
    pub fn scatter_grad_into(
        &self,
        emb_grad: &[f32],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
        pool: Option<&WorkerPool>,
        outs: &mut Vec<Vec<f32>>,
    ) {
        assert_eq!(emb_grad.len(), bucket_b * bucket_l * dim);
        let n_groups = self.groups.len();
        outs.resize_with(n_groups, Vec::new);
        for (g, o) in self.groups.iter().zip(outs.iter_mut()) {
            o.clear();
            o.resize(g.ids.len() * g.dim, 0.0);
        }
        let n = self.n_sequences;
        if n == 0 {
            return;
        }
        // First occurrence of sequence `b` in group `g`'s space.
        let occ_start = |g: usize, b: usize| -> usize {
            if b < n {
                self.groups[g].layout[b].0
            } else {
                self.groups[g].ids.len()
            }
        };
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                let windows: Vec<SharedSliceMut<f32>> = outs
                    .iter_mut()
                    .map(|o| SharedSliceMut::new(&mut o[..]))
                    .collect();
                let windows = &windows;
                let occ_start = &occ_start;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    WorkerPool::chunk_ranges(n, p.threads())
                        .into_iter()
                        .map(|sr| {
                            Box::new(move || {
                                let base: Vec<usize> =
                                    (0..n_groups).map(|g| occ_start(g, sr.start)).collect();
                                let mut dsts: Vec<&mut [f32]> = (0..n_groups)
                                    .map(|g| {
                                        let o1 = occ_start(g, sr.end);
                                        let d = self.groups[g].dim;
                                        // SAFETY: sequence chunks are
                                        // disjoint and each owns the
                                        // contiguous per-group span
                                        // [base[g], o1).
                                        unsafe {
                                            windows[g].slice_mut(
                                                base[g] * d,
                                                (o1 - base[g]) * d,
                                            )
                                        }
                                    })
                                    .collect();
                                for b in sr.clone() {
                                    self.scatter_one(
                                        b, emb_grad, dim, bucket_l, &base, &mut dsts,
                                    );
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                p.run_scope(tasks);
            }
            _ => {
                let base = vec![0usize; n_groups];
                let mut dsts: Vec<&mut [f32]> =
                    outs.iter_mut().map(|o| &mut o[..]).collect();
                for b in 0..n {
                    self.scatter_one(b, emb_grad, dim, bucket_l, &base, &mut dsts);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Sequence;
    use crate::embedding::merge::MergePlan;

    fn setup() -> (Schema, MergePlan, Batch) {
        let schema = Schema::meituan_like(4, 1);
        let plan = MergePlan::build(&schema.all_features());
        let seqs = vec![
            Sequence {
                user_id: 1,
                context: vec![10, 20, 30],
                tokens: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
                labels: [1.0, 0.0],
            },
            Sequence {
                user_id: 2,
                context: vec![11, 21, 31],
                tokens: vec![vec![9, 10, 11, 12]],
                labels: [0.0, 0.0],
            },
        ];
        let tokens = seqs.iter().map(|s| s.len()).sum();
        (
            schema,
            plan,
            Batch {
                sequences: seqs,
                tokens,
            },
        )
    }

    /// A mixed-dim batch: 8D context group + 4D token group (5 token
    /// features incl. the exp_item alias).
    fn setup_mixed() -> (Schema, MergePlan, Batch) {
        let mut schema = Schema::meituan_mixed(4);
        // meituan_mixed(4) clamps context to the model dim (one group);
        // narrow it back to 2D so the plan genuinely forms two groups.
        for f in schema.context_features.iter_mut() {
            f.dim = 2;
        }
        let plan = MergePlan::build(&schema.all_features());
        assert_eq!(plan.num_groups(), 2);
        let seqs: Vec<Sequence> = (0..5)
            .map(|i| Sequence {
                user_id: i as u64,
                context: vec![10 + i as u64, 20 + i as u64, 30 + i as u64],
                tokens: vec![vec![i as u64, 1, 2, 3, 90 + i as u64]; 1 + (i % 3)],
                labels: [0.0, 1.0],
            })
            .collect();
        let tokens = seqs.iter().map(|s| s.len()).sum();
        (
            schema,
            plan,
            Batch {
                sequences: seqs,
                tokens,
            },
        )
    }

    #[test]
    fn occurrence_count_and_order() {
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        assert_eq!(bi.groups.len(), 1, "homogeneous schema: one group");
        // 3 ctx + 2×4 tok for seq 0; 3 ctx + 1×4 for seq 1.
        assert_eq!(bi.groups[0].ids.len(), 3 + 8 + 3 + 4);
        assert_eq!(bi.total_ids(), 3 + 8 + 3 + 4);
        assert_eq!(bi.num_sequences(), 2);
        // Same local id in different features maps to different globals.
        let (_, item1) = plan.global_id("item_id", 1);
        assert_eq!(bi.groups[0].ids[3], item1);
    }

    #[test]
    fn mixed_schema_splits_occurrences_per_group() {
        let (schema, plan, batch) = setup_mixed();
        let bi = BatchIds::build(&batch, &schema, &plan);
        assert_eq!(bi.groups.len(), 2);
        // Group dims follow the plan (sorted ascending by dim).
        assert_eq!(bi.groups[0].dim, 2);
        assert_eq!(bi.groups[1].dim, 4);
        let total_tokens: usize = batch.sequences.iter().map(|s| s.len()).sum();
        // 2D group: only the 3 context features.
        assert_eq!(bi.groups[0].ids.len(), 3 * batch.sequences.len());
        // 4D group: 5 token features per token.
        assert_eq!(bi.groups[1].ids.len(), 5 * total_tokens);
        // The alias feature resolves to the same global id space as its
        // host table.
        let (_, a) = plan.global_id("item_id", 7);
        let (_, b) = plan.global_id("exp_item_id", 7);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_sums_context_and_token_features() {
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let dim = 4;
        // rows[i] = constant i+1 so pooled values are countable.
        let rows: Vec<f32> = (0..bi.groups[0].ids.len())
            .flat_map(|i| vec![(i + 1) as f32; dim])
            .collect();
        let emb = bi.pool(&[rows], dim, 3, 4);
        assert_eq!(emb.len(), 3 * 4 * dim);
        // Seq 0 token 0 = ctx rows (1+2+3) + token rows (4+5+6+7) = 28.
        assert_eq!(emb[0], 28.0);
        // Seq 0 token 1 (slot 1 of bucket_l 4) = 6 + (8+9+10+11) = 44.
        assert_eq!(emb[dim], 44.0);
        // Padded positions zero.
        assert_eq!(emb[2 * dim], 0.0);
        assert_eq!(emb[2 * 4 * dim], 0.0); // padded sequence slot
    }

    #[test]
    fn narrow_rows_pool_into_leading_components() {
        let (schema, plan, batch) = setup_mixed();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let dim = 4;
        // Context rows (2D) all ones; token rows (4D) all zero → every
        // real token position must read [3, 3, 0, 0] (3 ctx features).
        let rows = vec![
            vec![1.0f32; bi.groups[0].ids.len() * 2],
            vec![0.0f32; bi.groups[1].ids.len() * 4],
        ];
        let emb = bi.pool(&rows, dim, 8, 4);
        let e0 = &emb[0..dim];
        assert_eq!(e0, &[3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn scatter_is_adjoint_of_pool() {
        // <pool(rows), g> == <rows, scatter(g)> over random data.
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let dim = 4;
        let mut rng = crate::util::rng::Xoshiro256::new(2);
        let rows: Vec<f32> = (0..bi.groups[0].ids.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let g: Vec<f32> = (0..3 * 4 * dim).map(|_| rng.next_f32() - 0.5).collect();
        let emb = bi.pool(std::slice::from_ref(&rows), dim, 3, 4);
        let occ_g = bi.scatter_grad(&g, dim, 3, 4);
        let lhs: f64 = emb.iter().zip(&g).map(|(a, b)| (*a * *b) as f64).sum();
        let rhs: f64 = rows.iter().zip(&occ_g[0]).map(|(a, b)| (*a * *b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn scatter_is_adjoint_of_pool_mixed_dims() {
        // The adjoint identity must hold across heterogeneous groups:
        // <pool(rows), g> == Σ_g <rows_g, scatter(g)_g>.
        let (schema, plan, batch) = setup_mixed();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let dim = 4;
        let mut rng = crate::util::rng::Xoshiro256::new(11);
        let rows: Vec<Vec<f32>> = bi
            .groups
            .iter()
            .map(|g| {
                (0..g.ids.len() * g.dim)
                    .map(|_| rng.next_f32() - 0.5)
                    .collect()
            })
            .collect();
        let bucket = (8usize, 4usize);
        let g: Vec<f32> = (0..bucket.0 * bucket.1 * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let emb = bi.pool(&rows, dim, bucket.0, bucket.1);
        let occ_g = bi.scatter_grad(&g, dim, bucket.0, bucket.1);
        let lhs: f64 = emb.iter().zip(&g).map(|(a, b)| (*a * *b) as f64).sum();
        let rhs: f64 = rows
            .iter()
            .zip(&occ_g)
            .map(|(r, og)| r.iter().zip(og).map(|(a, b)| (*a * *b) as f64).sum::<f64>())
            .sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn build_pooled_bit_identical_for_every_pool_size() {
        // A batch large enough that several chunks form at 4 threads,
        // with ragged lengths so span boundaries are nontrivial — run
        // over BOTH the homogeneous and the mixed-dim schema.
        for mixed in [false, true] {
            // Mixed: 8D context group + 16D token group (2 groups).
            let schema = if mixed {
                Schema::meituan_mixed(16)
            } else {
                Schema::meituan_like(4, 1)
            };
            let d = schema.max_dim();
            let n_tok_feat = schema.num_token_features();
            let plan = MergePlan::build(&schema.all_features());
            let seqs: Vec<Sequence> = (0..37)
                .map(|i| Sequence {
                    user_id: i as u64,
                    context: vec![i as u64, 2 * i as u64, 3 * i as u64],
                    tokens: vec![
                        (0..n_tok_feat as u64).map(|f| i as u64 + f).collect();
                        1 + (i * 7) % 13
                    ],
                    labels: [0.0, 1.0],
                })
                .collect();
            let tokens = seqs.iter().map(|s| s.len()).sum();
            let batch = Batch {
                sequences: seqs,
                tokens,
            };
            let serial = BatchIds::build(&batch, &schema, &plan);
            if mixed {
                assert_eq!(serial.groups.len(), 2, "mixed schema must form 2 groups");
            }
            // Pooled scatter reference for the same batch.
            let grad: Vec<f32> = (0..64 * 16 * d).map(|i| (i % 23) as f32 * 0.5).collect();
            let ref_rows: Vec<Vec<f32>> = serial
                .groups
                .iter()
                .map(|g| (0..g.ids.len() * g.dim).map(|i| (i % 7) as f32).collect())
                .collect();
            let ref_emb = serial.pool(&ref_rows, d, 64, 16);
            let ref_scatter = serial.scatter_grad(&grad, d, 64, 16);
            for threads in [1usize, 2, 4] {
                let pool = crate::util::pool::WorkerPool::new(threads);
                let pooled = BatchIds::build_pooled(&batch, &schema, &plan, Some(&pool));
                assert_eq!(pooled.groups.len(), serial.groups.len());
                for (gp, gs) in pooled.groups.iter().zip(&serial.groups) {
                    assert_eq!(gp.ids, gs.ids, "mixed={mixed} {threads}t: ids diverged");
                    assert_eq!(gp.layout, gs.layout, "mixed={mixed} {threads}t: layout");
                }
                let mut emb = Vec::new();
                pooled.pool_into(&ref_rows, d, 64, 16, Some(&pool), &mut emb);
                assert_eq!(emb, ref_emb, "mixed={mixed} {threads}t: pooled emb");
                let mut sc = Vec::new();
                pooled.scatter_grad_into(&grad, d, 64, 16, Some(&pool), &mut sc);
                assert_eq!(sc, ref_scatter, "mixed={mixed} {threads}t: scatter");
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceeds bucket")]
    fn oversized_batch_rejected() {
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let rows = vec![vec![0.0; bi.groups[0].ids.len() * 4]];
        let _ = bi.pool(&rows, 4, 1, 4); // 2 sequences into bucket_b = 1
    }
}
