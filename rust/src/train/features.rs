//! Batch → embedding-input plumbing: flatten feature-ID occurrences for
//! the sharded lookup, pool looked-up rows into the (B, L, d) embedding
//! tensor the L2 model consumes, and scatter the model's embedding
//! gradient back onto the contributing occurrences.
//!
//! Layout: for each sequence `b` (in batch order) the occurrence stream
//! is `context ids (C)`, then `F token-feature ids` per token. Token
//! embeddings are the SUM of their feature rows plus the pooled context
//! embedding (context features influence every position); gradients
//! mirror that sum exactly (each contributing occurrence receives the
//! token's gradient; context occurrences receive the sequence-summed
//! gradient).

use crate::balance::Batch;
use crate::data::schema::Schema;
use crate::embedding::merge::MergePlan;
use crate::embedding::GlobalId;
use crate::util::pool::{SharedSliceMut, WorkerPool};

/// Flattened occurrence ids + the layout needed to pool and scatter.
#[derive(Clone, Debug)]
pub struct BatchIds {
    /// Occurrence-ordered global IDs (context-first per sequence).
    pub ids: Vec<GlobalId>,
    /// Per-sequence (context_offset, token_offset, len).
    layout: Vec<(usize, usize, usize)>,
    n_ctx: usize,
    n_tok_feat: usize,
}

impl BatchIds {
    /// Build the occurrence stream for a batch under the merge plan
    /// (serial reference; see [`build_pooled`](Self::build_pooled)).
    pub fn build(batch: &Batch, schema: &Schema, plan: &MergePlan) -> BatchIds {
        Self::build_pooled(batch, schema, plan, None)
    }

    /// [`build`](Self::build) with the per-token ID-mapping pass fanned
    /// across `pool` — the last serial per-token pass in the step.
    /// Every sequence owns a contiguous occurrence span whose bounds
    /// are a pure function of the sequence lengths, so chunks write
    /// disjoint windows and each id is a pure function of its
    /// occurrence: the output is bit-identical for every pool size.
    pub fn build_pooled(
        batch: &Batch,
        schema: &Schema,
        plan: &MergePlan,
        pool: Option<&WorkerPool>,
    ) -> BatchIds {
        let n_ctx = schema.num_context_features();
        let n_tok = schema.num_token_features();
        let n = batch.sequences.len();
        // Span layout first (cheap, serial): sequence `b` owns
        // occurrences `[layout[b].0, layout[b].0 + n_ctx + len·n_tok)`.
        let mut layout = Vec::with_capacity(n);
        let mut off = 0usize;
        for seq in &batch.sequences {
            layout.push((off, off + n_ctx, seq.len()));
            off += n_ctx + seq.len() * n_tok;
        }
        let total = off;
        let mut ids: Vec<GlobalId> = vec![0; total];
        // Map one sequence's ids into its span (`dst` starts at the
        // sequence's first occurrence).
        let write_seq = |b: usize, dst: &mut [GlobalId]| {
            let seq = &batch.sequences[b];
            let mut k = 0usize;
            for (f, &id) in seq.context.iter().enumerate() {
                let (_g, gid) = plan.global_id(&schema.context_features[f].name, id);
                dst[k] = gid;
                k += 1;
            }
            for tok in &seq.tokens {
                for (f, &id) in tok.iter().enumerate() {
                    let (_g, gid) = plan.global_id(&schema.token_features[f].name, id);
                    dst[k] = gid;
                    k += 1;
                }
            }
        };
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                let occ_start =
                    |b: usize| -> usize { if b < n { layout[b].0 } else { total } };
                let window = SharedSliceMut::new(&mut ids[..]);
                let window = &window;
                let write_seq = &write_seq;
                let layout = &layout;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    WorkerPool::chunk_ranges(n, p.threads())
                        .into_iter()
                        .map(|sr| {
                            let (o0, o1) = (occ_start(sr.start), occ_start(sr.end));
                            Box::new(move || {
                                // SAFETY: sequence chunks are disjoint
                                // and each owns the contiguous
                                // occurrence span [o0, o1).
                                let dst = unsafe { window.slice_mut(o0, o1 - o0) };
                                let mut cur = 0usize;
                                for b in sr {
                                    let span = n_ctx + layout[b].2 * n_tok;
                                    write_seq(b, &mut dst[cur..cur + span]);
                                    cur += span;
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                p.run_scope(tasks);
            }
            _ => {
                for b in 0..n {
                    let (start, _, len) = layout[b];
                    let span = n_ctx + len * n_tok;
                    write_seq(b, &mut ids[start..start + span]);
                }
            }
        }
        BatchIds {
            ids,
            layout,
            n_ctx,
            n_tok_feat: n_tok,
        }
    }

    pub fn num_sequences(&self) -> usize {
        self.layout.len()
    }

    /// Pool looked-up rows (occurrence-ordered, `dim` wide) into the
    /// padded (bucket_b, bucket_l, dim) embedding tensor. Sequences
    /// beyond `bucket_l` tokens are *not* truncated by this function —
    /// callers must have bucketized correctly (asserted).
    pub fn pool(
        &self,
        rows: &[f32],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
    ) -> Vec<f32> {
        let mut emb = Vec::new();
        self.pool_into(rows, dim, bucket_b, bucket_l, None, &mut emb);
        emb
    }

    /// Pool one sequence's rows into its (bucket_l, dim) slot.
    fn pool_one(&self, b: usize, rows: &[f32], dim: usize, bucket_l: usize, dst: &mut [f32]) {
        let (ctx_off, tok_off, len) = self.layout[b];
        assert!(len <= bucket_l, "sequence exceeds bucket length");
        // Pooled context embedding.
        let mut ctx = vec![0.0f32; dim];
        for c in 0..self.n_ctx {
            let r = &rows[(ctx_off + c) * dim..(ctx_off + c + 1) * dim];
            for (a, x) in ctx.iter_mut().zip(r) {
                *a += x;
            }
        }
        for t in 0..len {
            let e = &mut dst[t * dim..(t + 1) * dim];
            e.copy_from_slice(&ctx);
            for f in 0..self.n_tok_feat {
                let occ = tok_off + t * self.n_tok_feat + f;
                let r = &rows[occ * dim..(occ + 1) * dim];
                for (a, x) in e.iter_mut().zip(r) {
                    *a += x;
                }
            }
        }
    }

    /// [`pool`](Self::pool) into a caller-owned buffer (reused across
    /// steps — no allocation in steady state), fanning sequences across
    /// `pool` when supplied. Per-sequence output slots are disjoint, so
    /// the result is bit-identical for every pool size.
    pub fn pool_into(
        &self,
        rows: &[f32],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
        pool: Option<&WorkerPool>,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(rows.len(), self.ids.len() * dim);
        assert!(self.layout.len() <= bucket_b, "batch exceeds bucket");
        out.clear();
        out.resize(bucket_b * bucket_l * dim, 0.0);
        let n = self.layout.len();
        if n == 0 {
            return;
        }
        let stride = bucket_l * dim;
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                p.parallel_for_chunks_mut(&mut out[..n * stride], n, stride, |r, chunk| {
                    for (j, b) in r.enumerate() {
                        self.pool_one(b, rows, dim, bucket_l, &mut chunk[j * stride..(j + 1) * stride]);
                    }
                });
            }
            _ => {
                for b in 0..n {
                    self.pool_one(b, rows, dim, bucket_l, &mut out[b * stride..(b + 1) * stride]);
                }
            }
        }
    }

    /// Scatter one sequence's gradient into occurrence positions,
    /// relative to `base_occ` (the first occurrence index of `dst`).
    fn scatter_one(
        &self,
        b: usize,
        emb_grad: &[f32],
        dim: usize,
        bucket_l: usize,
        base_occ: usize,
        dst: &mut [f32],
    ) {
        let (ctx_off, tok_off, len) = self.layout[b];
        // Context occurrences accumulate the sequence-summed grad.
        let mut ctx_g = vec![0.0f32; dim];
        for t in 0..len {
            let src = (b * bucket_l + t) * dim;
            let g = &emb_grad[src..src + dim];
            for (a, x) in ctx_g.iter_mut().zip(g) {
                *a += x;
            }
            for f in 0..self.n_tok_feat {
                let occ = tok_off + t * self.n_tok_feat + f - base_occ;
                dst[occ * dim..(occ + 1) * dim].copy_from_slice(g);
            }
        }
        for c in 0..self.n_ctx {
            let occ = ctx_off + c - base_occ;
            dst[occ * dim..(occ + 1) * dim].copy_from_slice(&ctx_g);
        }
    }

    /// Scatter the model's embedding gradient (bucket_b, bucket_l, dim)
    /// back to occurrence order (matching `ids`).
    pub fn scatter_grad(
        &self,
        emb_grad: &[f32],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.scatter_grad_into(emb_grad, dim, bucket_b, bucket_l, None, &mut out);
        out
    }

    /// [`scatter_grad`](Self::scatter_grad) into a caller-owned buffer,
    /// fanning sequence chunks across `pool`. Each sequence owns a
    /// contiguous occurrence span (context ids then token ids, in batch
    /// order — the `build` layout), so chunk windows are disjoint and
    /// the result is bit-identical for every pool size.
    pub fn scatter_grad_into(
        &self,
        emb_grad: &[f32],
        dim: usize,
        bucket_b: usize,
        bucket_l: usize,
        pool: Option<&WorkerPool>,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(emb_grad.len(), bucket_b * bucket_l * dim);
        out.clear();
        out.resize(self.ids.len() * dim, 0.0);
        let n = self.layout.len();
        if n == 0 {
            return;
        }
        // First occurrence of each sequence chunk (spans are contiguous).
        let occ_start = |b: usize| -> usize {
            if b < n {
                self.layout[b].0
            } else {
                self.ids.len()
            }
        };
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                let window = SharedSliceMut::new(&mut out[..]);
                let window = &window;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    WorkerPool::chunk_ranges(n, p.threads())
                        .into_iter()
                        .map(|sr| {
                            let (o0, o1) = (occ_start(sr.start), occ_start(sr.end));
                            Box::new(move || {
                                // SAFETY: sequence chunks are disjoint
                                // and each owns the contiguous
                                // occurrence span [o0, o1).
                                let dst =
                                    unsafe { window.slice_mut(o0 * dim, (o1 - o0) * dim) };
                                for b in sr {
                                    self.scatter_one(b, emb_grad, dim, bucket_l, o0, dst);
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                p.run_scope(tasks);
            }
            _ => {
                for b in 0..n {
                    self.scatter_one(b, emb_grad, dim, bucket_l, 0, out);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Sequence;
    use crate::embedding::merge::MergePlan;

    fn setup() -> (Schema, MergePlan, Batch) {
        let schema = Schema::meituan_like(4, 1);
        let plan = MergePlan::build(&schema.all_features());
        let seqs = vec![
            Sequence {
                user_id: 1,
                context: vec![10, 20, 30],
                tokens: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
                labels: [1.0, 0.0],
            },
            Sequence {
                user_id: 2,
                context: vec![11, 21, 31],
                tokens: vec![vec![9, 10, 11, 12]],
                labels: [0.0, 0.0],
            },
        ];
        let tokens = seqs.iter().map(|s| s.len()).sum();
        (
            schema,
            plan,
            Batch {
                sequences: seqs,
                tokens,
            },
        )
    }

    #[test]
    fn occurrence_count_and_order() {
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        // 3 ctx + 2×4 tok for seq 0; 3 ctx + 1×4 for seq 1.
        assert_eq!(bi.ids.len(), 3 + 8 + 3 + 4);
        assert_eq!(bi.num_sequences(), 2);
        // Same local id in different features maps to different globals.
        let (_, item1) = plan.global_id("item_id", 1);
        assert_eq!(bi.ids[3], item1);
    }

    #[test]
    fn pool_sums_context_and_token_features() {
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let dim = 4;
        // rows[i] = constant i+1 so pooled values are countable.
        let rows: Vec<f32> = (0..bi.ids.len())
            .flat_map(|i| vec![(i + 1) as f32; dim])
            .collect();
        let emb = bi.pool(&rows, dim, 3, 4);
        assert_eq!(emb.len(), 3 * 4 * dim);
        // Seq 0 token 0 = ctx rows (1+2+3) + token rows (4+5+6+7) = 28.
        assert_eq!(emb[0], 28.0);
        // Seq 0 token 1 = 6 + (8+9+10+11) = 44.
        assert_eq!(emb[(0 * 4 + 1) * dim], 44.0);
        // Padded positions zero.
        assert_eq!(emb[(0 * 4 + 2) * dim], 0.0);
        assert_eq!(emb[(2 * 4) * dim], 0.0); // padded sequence slot
    }

    #[test]
    fn scatter_is_adjoint_of_pool() {
        // <pool(rows), g> == <rows, scatter(g)> over random data.
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let dim = 4;
        let mut rng = crate::util::rng::Xoshiro256::new(2);
        let rows: Vec<f32> = (0..bi.ids.len() * dim)
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let g: Vec<f32> = (0..3 * 4 * dim).map(|_| rng.next_f32() - 0.5).collect();
        let emb = bi.pool(&rows, dim, 3, 4);
        let occ_g = bi.scatter_grad(&g, dim, 3, 4);
        let lhs: f64 = emb.iter().zip(&g).map(|(a, b)| (*a * *b) as f64).sum();
        let rhs: f64 = rows.iter().zip(&occ_g).map(|(a, b)| (*a * *b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn build_pooled_bit_identical_for_every_pool_size() {
        // A batch large enough that several chunks form at 4 threads,
        // with ragged lengths so span boundaries are nontrivial.
        let schema = Schema::meituan_like(4, 1);
        let plan = MergePlan::build(&schema.all_features());
        let seqs: Vec<Sequence> = (0..37)
            .map(|i| Sequence {
                user_id: i as u64,
                context: vec![i as u64, 2 * i as u64, 3 * i as u64],
                tokens: vec![vec![i as u64, 1, 2, 3]; 1 + (i * 7) % 13],
                labels: [0.0, 1.0],
            })
            .collect();
        let tokens = seqs.iter().map(|s| s.len()).sum();
        let batch = Batch {
            sequences: seqs,
            tokens,
        };
        let serial = BatchIds::build(&batch, &schema, &plan);
        for threads in [1usize, 2, 4] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            let pooled = BatchIds::build_pooled(&batch, &schema, &plan, Some(&pool));
            assert_eq!(pooled.ids, serial.ids, "{threads} threads: ids diverged");
            assert_eq!(pooled.layout, serial.layout, "{threads} threads: layout");
            assert_eq!(pooled.num_sequences(), serial.num_sequences());
        }
    }

    #[test]
    #[should_panic(expected = "exceeds bucket")]
    fn oversized_batch_rejected() {
        let (schema, plan, batch) = setup();
        let bi = BatchIds::build(&batch, &schema, &plan);
        let rows = vec![0.0; bi.ids.len() * 4];
        let _ = bi.pool(&rows, 4, 1, 4); // 2 sequences into bucket_b = 1
    }
}
